"""Quickstart: build learned indexes over a SOSD surrogate, look keys up,
compare the Pareto points — the paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import base, validate
from repro.core.search import SEARCH_FNS
from repro.data import sosd

N = 200_000
keys = sosd.generate("amzn", N, seed=1)           # sorted uint64 keys
q = sosd.make_queries(keys, 20_000, seed=2)       # mixed present/absent
truth = np.searchsorted(keys, q)

print(f"{'index':14s} {'size':>10s} {'log2(err)':>10s} {'exact':>6s}")
for name, hyper in [
    ("rmi", dict(branching=4096)),
    ("pgm", dict(eps=64)),
    ("radix_spline", dict(eps=32, radix_bits=16)),
    ("btree", dict(sample=8)),
    ("rbs", dict(radix_bits=16)),
    ("binary_search", dict()),
]:
    index = base.REGISTRY[name](keys, **hyper)

    # 1) index inference: key -> search bound containing lower_bound(key)
    lo, hi = index.lookup(index.state, jnp.asarray(q))

    # 2) last-mile search inside the bound
    pos = SEARCH_FNS["binary"](jnp.asarray(keys), jnp.asarray(q), lo, hi,
                               index.meta["max_err"])
    exact = bool((np.asarray(pos) == truth).all())

    stats = validate.check_bounds(index, keys, q)
    print(f"{name:14s} {index.size_bytes:>10,d} {stats['log2_err']:>10.2f} "
          f"{str(exact):>6s}")

print("\nEvery structure maps key -> (lo, hi) with lower_bound(key) inside "
      "(paper §2); smaller index => wider bound => longer last mile.")
