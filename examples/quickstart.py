"""Quickstart: declare learned indexes as `IndexSpec`s over a SOSD
surrogate, build + look keys up, compare the Pareto points — the
paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import spec, validate
from repro.core.search import SEARCH_FNS
from repro.data import sosd

N = 200_000
keys = sosd.generate("amzn", N, seed=1)           # sorted uint64 keys
q = sosd.make_queries(keys, 20_000, seed=2)       # mixed present/absent
truth = np.searchsorted(keys, q)

# Every build is a declarative, JSON-serializable spec (DESIGN.md §12):
SPECS = [
    '{"index": "rmi", "hyper": {"branching": 4096}}',
    '{"index": "pgm", "hyper": {"eps": 64}}',
    '{"index": "radix_spline", "hyper": {"eps": 32, "radix_bits": 16}}',
    '{"index": "btree", "hyper": {"sample": 8}}',
    '{"index": "rbs", "hyper": {"radix_bits": 16}}',
    '{"index": "binary_search"}',
]

print(f"{'index':14s} {'size':>10s} {'log2(err)':>10s} {'exact':>6s}")
for text in SPECS:
    s = spec.IndexSpec.from_json(text)            # validated before building
    index = spec.build(s, keys)

    # 1) index inference: key -> search bound containing lower_bound(key)
    lo, hi = index.lookup(index.state, jnp.asarray(q))

    # 2) last-mile search inside the bound
    pos = SEARCH_FNS["binary"](jnp.asarray(keys), jnp.asarray(q), lo, hi,
                               index.meta["max_err"])
    exact = bool((np.asarray(pos) == truth).all())

    stats = validate.check_bounds(index, keys, q)
    print(f"{index.name:14s} {index.size_bytes:>10,d} "
          f"{stats['log2_err']:>10.2f} {str(exact):>6s}")

# Or let the budget tuner choose the spec (and backend) per dataset:
tuned = spec.Tuner(max_bytes=1 << 20, max_configs=3).tune(keys)
print(f"\ntuned under 1MiB: {tuned.spec.to_json()} "
      f"({tuned.build.size_bytes:,d} bytes, "
      f"{len(tuned.evaluated)} configs searched)")

print("\nEvery structure maps key -> (lo, hi) with lower_bound(key) inside "
      "(paper §2); smaller index => wider bound => longer last mile.")
