"""The paper's technique inside the framework: MoE token dispatch IS
sorted-array lower-bound search.

Shows: (1) router -> sorted expert ids, (2) segment boundaries via
lower_bound (paper §2), (3) a learned LINEAR model of the boundary
positions is near-exact because the router's aux loss flattens the id CDF
— the learned-index thesis applied to an LM subsystem.

    PYTHONPATH=src python examples/moe_dispatch_demo.py
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import moe

cfg = dataclasses.replace(get_smoke("deepseek-moe-16b"), n_experts=16,
                          top_k=2, dtype="float32")
p = moe.init_moe(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (512, cfg.d_model), jnp.float32)

top_p, top_i, aux = moe._router(cfg, p, x)
flat = np.sort(np.asarray(top_i).reshape(-1))
e = cfg.n_experts

# exact boundaries: the paper's lower_bound over sorted ids
seg = np.searchsorted(flat, np.arange(e), side="left")

# learned index over the same array: linear CDF model + verified error
slope = len(flat) / e
pred = np.arange(e) * slope
err = int(np.ceil(np.abs(pred - seg).max()))
print(f"{'expert':>6s} {'true_start':>10s} {'linear_pred':>11s}")
for i in range(0, e, 4):
    print(f"{i:>6d} {seg[i]:>10d} {pred[i]:>11.1f}")
print(f"\nmax |pred - true| = {err} slots over {len(flat)} assignments "
      f"(bound width {2*err+1} vs log2 search {int(np.log2(len(flat)))} probes)")

out, aux = moe.moe_ffn(cfg, p, x[None])
print(f"moe_ffn output {out.shape}, aux loss {float(aux):.4f} — the sorted "
      "dispatch runs this machinery inside every MoE cell (models/moe.py)")
