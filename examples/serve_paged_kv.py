"""End-to-end serving driver: batched requests through a smoke-size LM with
the paged KV cache + learned-index slot lookup (the paper's 'end-to-end
impact' ask).

    PYTHONPATH=src python examples/serve_paged_kv.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import model as M
from repro.serve.engine import ServeEngine

cfg = get_smoke("granite-3-2b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, max_batch=4, max_seq=96, page_size=8)

rng = np.random.default_rng(0)
rids = [engine.submit(list(rng.integers(2, cfg.vocab, rng.integers(3, 9))),
                      max_new=6) for _ in range(6)]
print(f"submitted {len(rids)} requests (continuous batching, "
      f"{engine.max_batch} slots)")

outs = engine.run(max_steps=64)
for rid in rids:
    print(f"request {rid}: generated {outs[rid]}")

print(f"\nKV pool utilization after drain: {engine.kv.alloc.utilization:.2f}")

# the learned-index slot lookup on a live batch layout
engine2 = ServeEngine(cfg, params, max_batch=4, max_seq=96, page_size=8)
for r in rids[:3]:
    engine2.submit([2, 3, 4, 5], max_new=8)
engine2.step()
idx = engine2.kv.slot_index()
slots = jnp.arange(9, dtype=jnp.int32)
print("flat slot -> request id (learned linear index + verified fixup):",
      np.asarray(idx.lookup(slots)))
