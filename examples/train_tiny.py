"""Train a reduced granite-family model end-to-end on the packed synthetic
pipeline: data -> train_step -> checkpoint -> restore -> resume.

    PYTHONPATH=src python examples/train_tiny.py [--steps 60]

(~100M-param configs train the same way on real hardware; on this 1-core
CPU container the example defaults to the smoke width so it finishes in
about a minute — pass --d-model/--layers to scale up.)
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models import model as M
from repro.train import checkpoint as CK
from repro.train import train_step as TS
from repro.train.optimizer import AdamW, cosine_schedule

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--d-model", type=int, default=128)
ap.add_argument("--layers", type=int, default=4)
args = ap.parse_args()

cfg = dataclasses.replace(get_smoke("granite-3-2b"),
                          d_model=args.d_model, n_layers=args.layers)
pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=64,
                                    global_batch=8, seed=0))
opt = AdamW(lr=cosine_schedule(3e-3, warmup=10, total=args.steps))
params = M.init_params(cfg, jax.random.PRNGKey(0))
state = TS.TrainState(params, opt.init(params))
step_fn = jax.jit(TS.make_train_step(cfg, opt))

ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
t0 = time.time()
for step in range(args.steps):
    batch = jax.tree.map(jnp.asarray, pipe.batch(step))
    state, metrics = step_fn(state, batch)
    if step % 10 == 0:
        print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f} "
              f"lr {float(metrics['lr']):.2e}")
    if step == args.steps // 2:
        CK.save(ckpt_dir, step, state, async_=False)
        print(f"  checkpointed at step {step} -> {ckpt_dir}")

print(f"final loss {float(metrics['loss']):.4f} "
      f"({args.steps} steps in {time.time()-t0:.1f}s)")

# restart from the checkpoint (fault-tolerance path: fresh state tree)
latest = CK.latest_step(ckpt_dir)
like = TS.TrainState(M.init_params(cfg, jax.random.PRNGKey(1)),
                     opt.init(M.init_params(cfg, jax.random.PRNGKey(1))))
restored = CK.restore(ckpt_dir, latest, like)
batch = jax.tree.map(jnp.asarray, pipe.batch(latest + 1))  # resume stream
restored, metrics = step_fn(restored, batch)
print(f"restored at step {latest}, resumed: loss "
      f"{float(metrics['loss']):.4f} (restart path verified)")
