"""Serve learned-index lookups: async admission, micro-batching, hot-swap.

Four concurrent "clients" stream key lookups at a LookupService while it
micro-batches them into sharded fused dispatches; mid-stream the key set
is rebuilt and hot-swapped without draining a single in-flight batch.
The service runs the continuous-batching async executor (DESIGN.md §13):
a warmed executable cache, launch-without-blocking double buffering, and
a bounded in-flight slot ring — hot-swap invalidates and re-warms the
cache without pausing admission.

The run is fully observed (DESIGN.md §14): tracing is on, so every
request becomes a span from admission to completion and the hot-swap
shows up as lifecycle spans; the windowed metrics report the p99 *of the
trailing window* (with an SLO target and error-budget burn) next to the
lifetime aggregate; and the whole run is written out as a Chrome-trace
JSON you can open in chrome://tracing or https://ui.perfetto.dev.

    PYTHONPATH=src python examples/serve_lookup.py
"""
import os
import tempfile
import threading
import time

import numpy as np

from repro.core import base
from repro.core.spec import IndexSpec
from repro.data import sosd
from repro.serve.lookup import LookupService, LookupServiceConfig

N_KEYS = 100_000
N_CLIENTS = 4
REQUESTS_PER_CLIENT = 40
KEYS_PER_REQUEST = 64
SLO_P99_MS = 25.0

keys = sosd.generate("amzn", N_KEYS, seed=1)
svc = LookupService(keys, LookupServiceConfig(
    spec=IndexSpec("rmi", dict(branching=2048)),
    max_batch=1024, deadline_ms=1.0, executor="async",
    trace=True, slo_p99_ms=SLO_P99_MS))

errors = []


def client(cid: int):
    rng = np.random.default_rng(cid)
    for _ in range(REQUESTS_PER_CLIENT):
        gen = svc.generation            # which key set this client targets
        q = sosd.make_queries(np.asarray(gen.data), KEYS_PER_REQUEST,
                              seed=int(rng.integers(1 << 30)))
        pos = svc.submit(q).result(timeout=30.0)
        # the service may have hot-swapped after we sampled, in which case
        # the answer is correct w.r.t. the NEW generation — check both.
        truths = [base.lower_bound_oracle(np.asarray(g.data), q)
                  for g in {gen.version: gen,
                            svc.generation.version: svc.generation}.values()]
        if not any(np.array_equal(pos, t) for t in truths):
            errors.append(cid)
        time.sleep(0.002)


with svc:                               # background flusher thread
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_CLIENTS)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()

    time.sleep(0.15)                    # mid-stream: rebuild + hot-swap
    keys2 = sosd.generate("wiki", N_KEYS, seed=2)
    v0 = svc.generation.version
    t_swap = time.perf_counter()
    svc.swap_keys(keys2)
    swap_ms = (time.perf_counter() - t_swap) * 1e3
    print(f"hot-swapped amzn -> wiki (generation {v0} -> "
          f"{svc.generation.version}) in {swap_ms:.0f}ms, no drain")

    for t in threads:
        t.join()
    dt = time.perf_counter() - t0

snap = svc.metrics.snapshot()
n_req = N_CLIENTS * REQUESTS_PER_CLIENT
print(f"\n{n_req} requests x {KEYS_PER_REQUEST} keys from {N_CLIENTS} "
      f"clients in {dt:.2f}s")
print(f"  {snap['batches']} dispatched batches, "
      f"occupancy {snap['mean_occupancy']:.2f}, "
      f"{snap['lookups_per_s']/1e3:.1f} klookups/s")
print(f"  batch latency mean {snap['mean_batch_ms']:.2f}ms / "
      f"p99 {snap['p99_batch_ms']:.2f}ms; "
      f"queue p99 {snap['p99_queue_ms']:.2f}ms; "
      f"request p99 {snap['p99_request_ms']:.2f}ms")
print(f"  executable cache: hit rate {snap['cache_hit_rate']:.2f} "
      f"({snap['cache_hits']} hits, {snap['cache_misses']} misses, "
      f"{snap['warm_compiles']} warm compiles); "
      f"in-flight slots mean {snap['mean_inflight_slots']:.2f} / "
      f"max {snap['max_inflight_slots']}")

# windowed view (§14.2): the p99 of the trailing window, not of all time,
# plus the SLO error-budget burn a latency-aware operator would page on
w = svc.metrics.windowed(window_s=10.0)
print(f"  windowed({w['window_s']:.0f}s): p50 {w['p50_ms']:.2f}ms / "
      f"p99 {w['p99_ms']:.2f}ms, {w['lookups_per_s']/1e3:.1f} klookups/s; "
      f"SLO p99<{SLO_P99_MS:.0f}ms: {w['slo_violations']} violations, "
      f"budget burn {w['slo_budget_burn']:.2f}")

# the full run as a Chrome trace: request spans (admission -> completion),
# launches/finalizes, and the hot-swap's build+publish lifecycle spans
trace_path = os.path.join(tempfile.gettempdir(), "serve_lookup_trace.json")
svc.recorder.save(trace_path)
print(f"  trace: {len(svc.recorder)} spans ({svc.recorder.n_dropped} "
      f"dropped) -> {trace_path} (chrome://tracing, ui.perfetto.dev)")

print(f"  wrong answers: {len(errors)}")
assert not errors
