"""Serve learned-index lookups: async admission, micro-batching, hot-swap.

Four concurrent "clients" stream key lookups at a LookupService while it
micro-batches them into sharded fused dispatches; mid-stream the key set
is rebuilt and hot-swapped without draining a single in-flight batch.
The service runs the continuous-batching async executor (DESIGN.md §13):
a warmed executable cache, launch-without-blocking double buffering, and
a bounded in-flight slot ring — hot-swap invalidates and re-warms the
cache without pausing admission.

    PYTHONPATH=src python examples/serve_lookup.py
"""
import threading
import time

import numpy as np

from repro.core import base
from repro.core.spec import IndexSpec
from repro.data import sosd
from repro.serve.lookup import LookupService, LookupServiceConfig

N_KEYS = 100_000
N_CLIENTS = 4
REQUESTS_PER_CLIENT = 40
KEYS_PER_REQUEST = 64

keys = sosd.generate("amzn", N_KEYS, seed=1)
svc = LookupService(keys, LookupServiceConfig(
    spec=IndexSpec("rmi", dict(branching=2048)),
    max_batch=1024, deadline_ms=1.0, executor="async"))

errors = []


def client(cid: int):
    rng = np.random.default_rng(cid)
    for _ in range(REQUESTS_PER_CLIENT):
        gen = svc.generation            # which key set this client targets
        q = sosd.make_queries(np.asarray(gen.data), KEYS_PER_REQUEST,
                              seed=int(rng.integers(1 << 30)))
        pos = svc.submit(q).result(timeout=30.0)
        # the service may have hot-swapped after we sampled, in which case
        # the answer is correct w.r.t. the NEW generation — check both.
        truths = [base.lower_bound_oracle(np.asarray(g.data), q)
                  for g in {gen.version: gen,
                            svc.generation.version: svc.generation}.values()]
        if not any(np.array_equal(pos, t) for t in truths):
            errors.append(cid)
        time.sleep(0.002)


with svc:                               # background flusher thread
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_CLIENTS)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()

    time.sleep(0.15)                    # mid-stream: rebuild + hot-swap
    keys2 = sosd.generate("wiki", N_KEYS, seed=2)
    v0 = svc.generation.version
    t_swap = time.perf_counter()
    svc.swap_keys(keys2)
    swap_ms = (time.perf_counter() - t_swap) * 1e3
    print(f"hot-swapped amzn -> wiki (generation {v0} -> "
          f"{svc.generation.version}) in {swap_ms:.0f}ms, no drain")

    for t in threads:
        t.join()
    dt = time.perf_counter() - t0

snap = svc.metrics.snapshot()
n_req = N_CLIENTS * REQUESTS_PER_CLIENT
print(f"\n{n_req} requests x {KEYS_PER_REQUEST} keys from {N_CLIENTS} "
      f"clients in {dt:.2f}s")
print(f"  {snap['batches']} dispatched batches, "
      f"occupancy {snap['mean_occupancy']:.2f}, "
      f"{snap['lookups_per_s']/1e3:.1f} klookups/s")
print(f"  batch latency mean {snap['mean_batch_ms']:.2f}ms / "
      f"p99 {snap['p99_batch_ms']:.2f}ms; "
      f"queue p99 {snap['p99_queue_ms']:.2f}ms; "
      f"request p99 {snap['p99_request_ms']:.2f}ms")
print(f"  executable cache: hit rate {snap['cache_hit_rate']:.2f} "
      f"({snap['cache_hits']} hits, {snap['cache_misses']} misses, "
      f"{snap['warm_compiles']} warm compiles); "
      f"in-flight slots mean {snap['mean_inflight_slots']:.2f} / "
      f"max {snap['max_inflight_slots']}")
print(f"  wrong answers: {len(errors)}")
assert not errors
