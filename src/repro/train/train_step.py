"""Train step: loss -> grads -> AdamW, with microbatch accumulation.

The step is written in global (pjit) semantics: XLA SPMD inserts the
all-gathers for FSDP params and the reduce-scatters for data-parallel
gradients from the sharding annotations alone.  Microbatch accumulation
(for the train_4k cells whose per-device activation footprint would not
fit otherwise) is a lax.scan over microbatches accumulating f32 grads.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamW, AdamWState


@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: AdamWState

    def tree_flatten(self):
        return (self.params, self.opt), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda aux, leaves: TrainState(*leaves),
)


def make_train_step(cfg: ModelConfig, opt: AdamW, microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        return loss, grads

    def step(state: TrainState, batch):
        if microbatches == 1:
            loss, grads = grads_of(state.params, batch)
        else:
            def reshape_mb(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(reshape_mb, batch)

            def acc_fn(carry, mb_batch):
                loss_acc, g_acc = carry
                loss, g = grads_of(state.params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros(()), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        params, opt_state, gnorm = opt.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt.lr(opt_state.step)}
        return TrainState(params, opt_state), metrics

    return step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        return M.loss_fn(cfg, params, batch)

    return eval_step
