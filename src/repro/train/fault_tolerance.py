"""Fault tolerance & straggler mitigation for the multi-pod launcher.

This container has one host, so node failure and stragglers are driven
through a simulation hook (tests inject failures), but the POLICY code is
the real thing a 1000-node deployment runs:

  * heartbeat ledger: every host stamps each step; a host late by more than
    `straggler_factor` x median step time is a straggler, missing for
    `dead_after` consecutive steps is dead.
  * straggler response: log + (optionally) re-dispatch the step with the
    backup-worker policy (synchronous training tolerates K slow hosts by
    over-provisioning K spares; we model the bookkeeping).
  * death response: shrink the mesh to the largest (pods', data', model)
    grid that the remaining hosts cover, restore the latest checkpoint onto
    it (checkpoint.restore is mesh-elastic), continue.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class HostState:
    last_step: int = -1
    last_time: float = 0.0
    step_times: List[float] = dataclasses.field(default_factory=list)


class HeartbeatLedger:
    def __init__(self, n_hosts: int, straggler_factor: float = 2.0,
                 dead_after: int = 3):
        self.hosts: Dict[int, HostState] = {i: HostState() for i in range(n_hosts)}
        self.straggler_factor = straggler_factor
        self.dead_after = dead_after

    def beat(self, host: int, step: int, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        h = self.hosts[host]
        if h.last_step >= 0 and step > h.last_step:
            h.step_times.append((now - h.last_time) / (step - h.last_step))
            h.step_times = h.step_times[-32:]
        h.last_step, h.last_time = step, now

    def median_step_time(self) -> float:
        times = [t for h in self.hosts.values() for t in h.step_times]
        return float(np.median(times)) if times else 0.0

    def classify(self, step: int, now: Optional[float] = None
                 ) -> Tuple[List[int], List[int]]:
        """Returns (stragglers, dead) host ids at `step`."""
        now = time.monotonic() if now is None else now
        med = self.median_step_time()
        stragglers, dead = [], []
        for i, h in self.hosts.items():
            behind = step - h.last_step
            if behind >= self.dead_after:
                dead.append(i)
            elif med > 0 and (now - h.last_time) > self.straggler_factor * med:
                stragglers.append(i)
        return stragglers, dead


def shrink_mesh_shape(shape: Tuple[int, ...], axes: Tuple[str, ...],
                      lost_hosts: int, hosts_per_pod: int
                      ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Elastic policy: drop whole pods first (cheapest re-shard: the pod
    axis only carries DP), else halve the data axis."""
    shape = list(shape)
    lost_pods = -(-lost_hosts // hosts_per_pod)  # ceil
    if "pod" in axes:
        pi = axes.index("pod")
        if shape[pi] > lost_pods:
            shape[pi] -= lost_pods
            return tuple(shape), axes
        # all pods but one gone: collapse the pod axis entirely
        remaining = [s for i, s in enumerate(shape) if i != pi]
        return tuple(remaining), tuple(a for a in axes if a != "pod")
    di = axes.index("data")
    shape[di] = max(1, shape[di] // 2)
    return tuple(shape), axes


@dataclasses.dataclass
class RecoveryPlan:
    new_shape: Tuple[int, ...]
    new_axes: Tuple[str, ...]
    restore_step: Optional[int]
    global_batch_scale: float    # keep global batch via more grad accum


def plan_recovery(ledger: HeartbeatLedger, step: int, mesh_shape, mesh_axes,
                  hosts_per_pod: int, ckpt_latest: Optional[int]
                  ) -> Optional[RecoveryPlan]:
    _, dead = ledger.classify(step)
    if not dead:
        return None
    new_shape, new_axes = shrink_mesh_shape(
        tuple(mesh_shape), tuple(mesh_axes), len(dead), hosts_per_pod)
    old = int(np.prod(mesh_shape))
    new = int(np.prod(new_shape))
    return RecoveryPlan(new_shape, new_axes, ckpt_latest, old / new)
