"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):

  ckpt_dir/step_000123.tmp/        written first
    manifest.json                  step, mesh shape, tree structure, shapes
    shard_<k>.npz                  one file per host (here: one), arrays
                                   saved UNSHARDED-equivalent (gathered)
  ckpt_dir/step_000123/            atomic rename after fsync -> commit

Restore re-shards onto WHATEVER mesh is active — a checkpoint written on
(2,16,16) restores onto (16,16) after losing a pod (elastic scaling); the
values are mesh-independent, sharding is re-derived from the logical rules.
Writes run on a background thread (training never blocks on disk).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def _to_savable(a: np.ndarray):
    """numpy can't serialize ml_dtypes (bf16 etc.) — view as uint bits."""
    if a.dtype.kind == "V" or a.dtype.name in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8), \
            a.dtype.name
    return a, a.dtype.name


def _from_savable(a: np.ndarray, dtype_name: str):
    if a.dtype.name != dtype_name:
        import ml_dtypes

        return a.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return a


def save(ckpt_dir: str, step: int, state, extra: Optional[dict] = None,
         async_: bool = True):
    """Serialize `state` (pytree of arrays) at `step`."""
    keys, vals, _ = _flatten_with_paths(state)
    # gather to host (device_get handles sharded arrays)
    host_vals = [np.asarray(jax.device_get(v)) for v in vals]
    host_vals, dtype_names = zip(*[_to_savable(v) for v in host_vals]) \
        if host_vals else ((), ())

    def write():
        tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{k: v for k, v in zip(keys, host_vals)})
        manifest = {
            "step": step,
            "keys": keys,
            "shapes": [list(v.shape) for v in host_vals],
            "dtypes": list(dtype_names),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Load into the structure of `like` (pytree of arrays or SDS).

    `shardings`: optional matching tree of NamedSharding for the ACTIVE
    mesh — this is the elastic path: values are put onto the new mesh
    regardless of what mesh wrote them.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    keys, vals, treedef = _flatten_with_paths(like)
    assert keys == manifest["keys"], "checkpoint/tree structure mismatch"
    loaded = [_from_savable(data[k], dn)
              for k, dn in zip(keys, manifest["dtypes"])]
    if shardings is not None:
        _, shard_flat, _ = _flatten_with_paths(shardings)
        loaded = [jax.device_put(v, s) for v, s in zip(loaded, shard_flat)]
    else:
        loaded = [jax.numpy.asarray(v) for v in loaded]
    return jax.tree_util.tree_unflatten(treedef, loaded)
