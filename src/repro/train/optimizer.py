"""AdamW with decoupled weight decay + schedules (no external deps).

State layout mirrors params (m, v in f32), so optimizer state inherits the
params' FSDP+TP sharding via tree-mapped specs — the per-device optimizer
footprint scales 1/(data*model), the ZeRO-style property the 104B/398B
cells need (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: Any
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Any], Any]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip (f32 accumulation)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step, new_m, new_v), gnorm


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def opt_state_specs(param_specs_tree):
    """Logical names for AdamWState mirroring the param specs."""
    return AdamWState(step=(),
                      m=param_specs_tree, v=param_specs_tree)
