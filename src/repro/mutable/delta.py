"""Sorted delta buffer: the write side of a mutable learned index.

A `DeltaBuffer` is an immutable snapshot of the keys inserted since the
base index was last built: sorted, unique, and disjoint from the base
key set (set semantics — re-inserting a present key is a no-op).  Every
mutation returns a NEW buffer, so a reader that grabbed a snapshot keeps
a consistent view while writers race ahead — the same
publish-by-pointer-swap discipline as the serving registry.

The device form pads the sorted keys to a power-of-two bucket with
``UINT64_MAX`` sentinels.  Lower-bound semantics make that pad exact,
not approximate: ``LB_delta(q)`` counts delta keys ``< q``, and no
uint64 query is ever ``> UINT64_MAX``, so pad lanes can never be
counted.  (A *real* ``UINT64_MAX`` key is indistinguishable from pad to
the device search and still correct for the same reason; it lives in
``keys_np`` and survives compaction like any other key.)  Pow-2 padding
bounds the jit compile-cache at O(log max_delta) shapes, mirroring the
dispatcher's query-side buckets.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import numpy as np

__all__ = ["UINT64_MAX", "DeltaBuffer"]

UINT64_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)

#: Smallest device pad: matches the dispatcher's 128-lane quantum.
PAD_QUANTUM = 128


def _pad_size(n: int, quantum: int = PAD_QUANTUM) -> int:
    p = quantum
    while p < n:
        p <<= 1
    return p


def _membership(sorted_arr: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Vectorized `k in sorted_arr` (both uint64, arr sorted unique)."""
    if sorted_arr.size == 0:
        return np.zeros(k.shape, dtype=bool)
    p = np.searchsorted(sorted_arr, k, side="left")
    return (p < sorted_arr.size) & (sorted_arr[np.minimum(p, sorted_arr.size - 1)] == k)


@dataclasses.dataclass(frozen=True)
class DeltaBuffer:
    """Immutable sorted-unique delta snapshot + its padded device copy."""

    keys_np: np.ndarray        # sorted unique uint64, disjoint from base
    device: Any                # jnp uint64, pow2-padded with UINT64_MAX
    pad_quantum: int = PAD_QUANTUM

    @property
    def count(self) -> int:
        return int(self.keys_np.size)

    @staticmethod
    def _to_device(keys_np: np.ndarray, quantum: int):
        import jax.numpy as jnp

        padded = np.full(_pad_size(keys_np.size, quantum), UINT64_MAX,
                         dtype=np.uint64)
        padded[:keys_np.size] = keys_np
        return jnp.asarray(padded)

    @classmethod
    def empty(cls, pad_quantum: int = PAD_QUANTUM) -> "DeltaBuffer":
        keys = np.empty(0, dtype=np.uint64)
        return cls(keys_np=keys, device=cls._to_device(keys, pad_quantum),
                   pad_quantum=pad_quantum)

    def with_inserted(self, base_np: np.ndarray,
                      k: np.ndarray) -> Tuple["DeltaBuffer", np.ndarray]:
        """Admit new keys (dedup vs base, this delta, and within-batch:
        first occurrence wins).  Returns (new buffer, 0/1 admitted flag
        per input key)."""
        k = np.asarray(k, dtype=np.uint64).ravel()
        fresh = ~(_membership(base_np, k) | _membership(self.keys_np, k))
        admitted = fresh.copy()
        if fresh.any():
            idx = np.flatnonzero(fresh)
            uniq, first = np.unique(k[idx], return_index=True)
            keep = np.zeros(idx.size, dtype=bool)
            keep[first] = True
            admitted[idx[~keep]] = False
            merged = np.empty(self.keys_np.size + uniq.size, dtype=np.uint64)
            pos = np.searchsorted(self.keys_np, uniq, side="left")
            # stable two-way merge of two disjoint sorted arrays
            new_slots = pos + np.arange(uniq.size)
            mask = np.zeros(merged.size, dtype=bool)
            mask[new_slots] = True
            merged[mask] = uniq
            merged[~mask] = self.keys_np
            new = DeltaBuffer(keys_np=merged,
                              device=self._to_device(merged, self.pad_quantum),
                              pad_quantum=self.pad_quantum)
        else:
            new = self
        return new, admitted.astype(np.int64)

    def minus(self, snapshot: "DeltaBuffer") -> "DeltaBuffer":
        """Drop every key present in ``snapshot`` (the subset a finished
        compaction folded into the new base); keeps keys admitted after
        the snapshot was taken."""
        if snapshot.count == 0:
            return self
        keep = self.keys_np[~_membership(snapshot.keys_np, self.keys_np)]
        return DeltaBuffer(keys_np=keep,
                           device=self._to_device(keep, self.pad_quantum),
                           pad_quantum=self.pad_quantum)
