"""Mutable learned index: base generation + delta, merged by rank sum.

The merged lookup is one jitted program per base generation:

    LB_merged(q) = LB_base(q) + LB_delta(q)

`LB_base` is the generation's `LookupPlan` (predict + bounded last-mile,
`repro.core.plan`) inlined through the plan's `compile_merged` transform
— which means the mutable read path runs on whatever backend the
generation serves with (jnp or Pallas kernels) for free; `LB_delta` is a
vectorized `searchsorted` over the padded device delta.  Base and delta
are disjoint sorted sets, so the two lower bounds add exactly — every
position the read path returns is identical to a lookup over the fully
merged sorted array (the invariant `tests/test_workloads_mutable.py`
pins against `oracle_replay` for every LB-capable index type x dataset).

Construction is declarative (DESIGN.md §12): the index is addressed by
an `IndexSpec` (pass one directly, or the legacy index/hyper/backend
arguments are folded into one), every build runs through `spec.build`,
and an optional `Tuner` makes compaction ADAPTIVE — each fold re-runs
the budget search against the delta-merged key set, so the spec (and
backend) can change when the data distribution does (the ROADMAP's
delta-aware retuning item).

Concurrency model (DESIGN.md §10.3): the only mutable cell is one
`MutableView` pointer.  Inserts and compaction-publish replace it under
a mutation lock; readers grab the current view with one lock-free-ish
read and keep a fully consistent (generation, delta) PAIR for the whole
batch — swapping either half atomically with the other is exactly what
prevents double counting when a compaction folds delta keys into a new
base.  Compaction itself (merge + rebuild, plus the optional retune)
runs outside every lock and publishes through
`IndexRegistry.build_and_publish` / `publish`, the serving registry's
atomic hot-swap.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.core import spec as spec_mod
from repro.mutable.delta import PAD_QUANTUM, DeltaBuffer
from repro.serve.lookup.registry import (DEFAULT_NAME, Generation,
                                         IndexRegistry)

__all__ = ["LB_INDEXES", "MutableIndex", "MutableView", "make_merged_fn"]

#: Index types with lower-bound semantics — the ones a delta can merge
#: with by rank correction.  `robin_hash` is point-only (no LB, paper
#: §4.1.1) and stays read-only.
LB_INDEXES = ("rmi", "pgm", "radix_spline", "btree", "ibtree", "rbs",
              "binary_search")


def make_merged_fn(plan, backend: str = "jnp") -> Callable:
    """jit'd merged lookup: (queries, padded delta) -> merged positions.

    A thin name over the plan's delta rank-correction transform
    (`LookupPlan.compile_merged`): the base LB expression is inlined for
    the chosen backend and the delta is an ARGUMENT, not a closure
    constant — the compile cache keys on (query bucket, delta bucket)
    shapes only, so insert traffic re-uses the compiled program until
    the delta crosses a pow-2 pad boundary."""
    return plan.compile_merged(backend=backend)


@dataclasses.dataclass(frozen=True)
class MutableView:
    """One immutable (generation, delta) snapshot — the unit readers pin."""

    generation: Generation
    base_np: np.ndarray        # host copy of the generation's sorted keys
    delta: DeltaBuffer
    merged_fn: Callable        # shared per generation across delta updates

    def lookup(self, q):
        """Device merged lookup; `q` is a jnp/np uint64 batch."""
        return self.merged_fn(q, self.delta.device)

    def scan_fn(self, m: int) -> Callable:
        """Merged-view scan executable ``(q, delta) -> (pos, window)`` —
        the plan's `compile_merged_scan` transform, cached per
        (m, backend) on the generation's plan."""
        return self.generation.plan.compile_merged_scan(
            m, backend=self.generation.backend)

    @property
    def n_keys(self) -> int:
        """Logical key count of the merged view."""
        return int(self.base_np.size) + self.delta.count


class MutableIndex:
    """Delta-buffered writes + merged reads over one registry name."""

    def __init__(self, keys: np.ndarray, index: str = "rmi",
                 hyper: Optional[Dict[str, Any]] = None,
                 last_mile: Optional[str] = None,
                 backend: str = "jnp",
                 compact_threshold: int = 4096,
                 registry: Optional[IndexRegistry] = None,
                 name: str = DEFAULT_NAME,
                 pad_quantum: int = PAD_QUANTUM,
                 spec: Optional[spec_mod.IndexSpec] = None,
                 tuner: Optional[spec_mod.Tuner] = None):
        if compact_threshold < 1:
            raise ValueError("compact_threshold must be >= 1")
        if spec is not None:
            self.spec = spec_mod.coerce(spec, hyper)   # spec wins wholesale
        else:
            self.spec = spec_mod.coerce(index, hyper, backend=backend,
                                        last_mile=last_mile)
        self.tuner = tuner
        self.compact_threshold = int(compact_threshold)
        self.registry = registry if registry is not None else IndexRegistry()
        self.name = name
        self.pad_quantum = int(pad_quantum)
        self._mu = threading.Lock()          # view-pointer mutations
        self._compact_mu = threading.Lock()  # one compaction at a time
        self._view: Optional[MutableView] = None
        self.reset(keys)

    # -- spec-derived views (kept in sync across retunes) -----------------
    @property
    def index(self) -> str:
        return self.spec.index

    @property
    def hyper(self) -> Dict[str, Any]:
        return dict(self.spec.hyper)

    @property
    def last_mile(self) -> Optional[str]:
        return self.spec.last_mile

    @property
    def backend(self) -> str:
        return self.spec.backend

    # -- lifecycle -------------------------------------------------------
    def _publish_base(self, keys: np.ndarray) -> MutableView:
        keys = np.asarray(keys, dtype=np.uint64)
        gen = self.registry.build_and_publish(self.spec, keys,
                                              name=self.name)
        return MutableView(generation=gen, base_np=keys,
                           delta=DeltaBuffer.empty(self.pad_quantum),
                           merged_fn=make_merged_fn(gen.plan, self.backend))

    def reset(self, keys: np.ndarray) -> MutableView:
        """Replace the whole key set: fresh base, empty delta."""
        view = self._publish_base(keys)
        with self._mu:
            self._view = view
        return view

    # -- read side -------------------------------------------------------
    def view(self) -> MutableView:
        with self._mu:
            return self._view

    def lookup(self, q) -> np.ndarray:
        """Host convenience: merged LB positions as int64 numpy."""
        import jax.numpy as jnp

        q = jnp.asarray(np.asarray(q, dtype=np.uint64))
        return np.asarray(self.view().lookup(q), dtype=np.int64)

    # -- write side ------------------------------------------------------
    def insert(self, keys) -> np.ndarray:
        """Admit keys into the delta (set semantics); returns the 0/1
        admitted flag per input key."""
        with self._mu:
            view = self._view
            delta, admitted = view.delta.with_inserted(view.base_np, keys)
            if delta is not view.delta:
                self._view = dataclasses.replace(view, delta=delta)
        return admitted

    @property
    def delta_count(self) -> int:
        return self.view().delta.count

    @property
    def needs_compaction(self) -> bool:
        return self.delta_count >= self.compact_threshold

    # -- autotune apply --------------------------------------------------
    def republish(self, spec, build=None) -> Optional[Generation]:
        """Hot-swap the base generation to a new spec WITHOUT folding
        the delta — the autotune retuner's apply path (DESIGN.md §17).

        The base key set is unchanged, so the caller's oracle-verified
        build for it can be published as-is; the delta is carried over
        verbatim, so inserts admitted at any point survive, and reads
        stay consistent because the mutable read path pins (generation,
        delta) PAIRS — the swap is one view-pointer assignment like
        compaction's.  Returns None if a reset/compaction replaced the
        base mid-flight (the verified build no longer matches the
        serving base — the caller must re-tune, not force the swap).
        """
        with self._compact_mu:
            snap = self.view()
            new_spec = spec_mod.coerce(spec)
            b = build if build is not None \
                else spec_mod.build(new_spec, snap.base_np)
            b.meta["spec"] = new_spec
            with self._mu:
                if self._view.generation is not snap.generation:
                    return None
                gen = self.registry.publish(b, snap.generation.data,
                                            name=self.name,
                                            last_mile=new_spec.last_mile,
                                            backend=new_spec.backend,
                                            spec=new_spec)
                self.spec = new_spec
                self._view = MutableView(
                    generation=gen, base_np=snap.base_np,
                    delta=self._view.delta,
                    merged_fn=make_merged_fn(gen.plan, new_spec.backend))
            return gen

    # -- compaction ------------------------------------------------------
    def compact(self) -> Optional[Generation]:
        """Fold the current delta into a fresh base generation.

        Snapshot -> merge -> (retune) -> rebuild -> hot-swap publish.
        With a `Tuner` configured, the rebuild's spec is CHOSEN against
        the delta-merged key set (DESIGN.md §12.4) — the budget search
        runs where the rebuild cost is already being paid, so a drifted
        key distribution gets a freshly-tuned spec+backend and the
        chosen build is published as-is (tuned builds are bit-identical
        to direct builds of the same spec, so results cannot move).

        The rebuild (seconds of host numpy) runs outside every lock;
        the publish + pointer swap hold the mutation lock and are
        cheap, so inserts admitted DURING the rebuild are preserved:
        the new view keeps exactly the keys the snapshot did not cover.
        If a `reset` replaced the whole key set mid-rebuild, the
        snapshot's generation is no longer current and the rebuild is
        DISCARDED — publishing it would resurrect the discarded key
        set.  Returns the new generation, or None if the delta was
        empty or the rebuild was abandoned.
        """
        import jax.numpy as jnp

        with self._compact_mu:
            snap = self.view()
            if snap.delta.count == 0:
                return None
            merged_keys = np.concatenate([snap.base_np, snap.delta.keys_np])
            merged_keys.sort(kind="stable")
            if self.tuner is not None:
                result = self.tuner.tune(merged_keys)
                new_spec, build = result.spec, result.build
                # the tuner decides what it was ASKED to decide: with a
                # single candidate backend it performed no backend
                # selection, so the index's serving backend survives the
                # retune; an unset last-mile likewise stays configured
                if len(self.tuner.backends) == 1:
                    new_spec = new_spec.replace(backend=self.spec.backend)
                if new_spec.last_mile is None and \
                        self.spec.last_mile is not None:
                    new_spec = new_spec.replace(
                        last_mile=self.spec.last_mile)
                build.meta["spec"] = new_spec
            else:
                new_spec = self.spec
                build = spec_mod.build(new_spec, merged_keys)
            data = jnp.asarray(merged_keys)
            with self._mu:
                if self._view.generation is not snap.generation:
                    return None   # reset() raced the rebuild: stale, drop it
                gen = self.registry.publish(build, data, name=self.name,
                                            last_mile=new_spec.last_mile,
                                            backend=new_spec.backend,
                                            spec=new_spec)
                self.spec = new_spec
                leftover = self._view.delta.minus(snap.delta)
                self._view = MutableView(
                    generation=gen, base_np=merged_keys, delta=leftover,
                    merged_fn=make_merged_fn(gen.plan, new_spec.backend))
            return gen
