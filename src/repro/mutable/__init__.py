"""`repro.mutable` — a write path for the learned indexes (DESIGN.md §10).

The source paper's indexes are frozen at build time; this package adds
the standard delta-buffer design its successors benchmark: inserts land
in a small sorted `DeltaBuffer`, lookups merge the base index's fused
result with a bounded search over the delta by *rank correction*
(``LB_merged = LB_base + LB_delta`` — lower bounds over disjoint sorted
sets add), and a threshold-triggered compaction rebuilds base+delta into
a fresh generation published through the serving registry's atomic
hot-swap.
"""
from repro.mutable.delta import UINT64_MAX, DeltaBuffer
from repro.mutable.index import LB_INDEXES, MutableIndex, MutableView

__all__ = ["UINT64_MAX", "DeltaBuffer", "LB_INDEXES", "MutableIndex",
           "MutableView"]
