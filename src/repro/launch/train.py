"""Production-shaped train driver.

Single-host it runs a reduced config end-to-end (CI / this container);
multi-host the SAME loop runs under `jax.distributed.initialize()` with
the production mesh — the parts that matter at 1000 nodes are all here:
sharded state init, deterministic resumable data, async atomic
checkpoints, heartbeat ledger + elastic recovery planning.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get, get_smoke
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.dist import sharding as SH
from repro.models import model as M
from repro.train import checkpoint as CK
from repro.train import fault_tolerance as FT
from repro.train import train_step as TS
from repro.train.optimizer import AdamW, cosine_schedule, opt_state_specs


def build_state(cfg, opt, mesh=None):
    """Init params+opt, sharded onto `mesh` when given."""
    if mesh is None:
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        return TS.TrainState(params, opt.init(params))
    p_shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    p_shard = SH.shard_tree(p_shapes, M.param_specs(cfg), mesh)
    params = jax.jit(lambda: M.init_params(cfg, jax.random.PRNGKey(0)),
                     out_shardings=p_shard)()
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    o_shard = SH.shard_tree(o_shapes, opt_state_specs(M.param_specs(cfg)),
                            mesh)
    opt_state = jax.jit(opt.init, out_shardings=o_shard)(params)
    return TS.TrainState(params, opt_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        import numpy as np

        model_axis = 1
        mesh = jax.make_mesh((n_dev // model_axis, model_axis),
                             ("data", "model"))
    act_rules, param_rules = SH.select_rules(cfg)

    opt = AdamW(lr=cosine_schedule(args.lr, warmup=10, total=args.steps))
    pipe = TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch,
        seed=0, host_id=jax.process_index(), n_hosts=jax.process_count()))
    ledger = FT.HeartbeatLedger(jax.process_count())

    ctx = SH.axis_rules(mesh, act_rules, param_rules) if mesh else None
    if ctx:
        ctx.__enter__()
    try:
        state = build_state(cfg, opt, mesh)
        start = 0
        if args.resume and args.ckpt_dir:
            latest = CK.latest_step(args.ckpt_dir)
            if latest is not None:
                state = CK.restore(args.ckpt_dir, latest, state)
                start = latest + 1
                print(f"resumed from step {latest}")
        step_fn = jax.jit(TS.make_train_step(cfg, opt, args.microbatches),
                          donate_argnums=(0,))
        ckpt_thread = None
        for step in range(start, args.steps):
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, pipe.batch(step))
            state, metrics = step_fn(state, batch)
            ledger.beat(jax.process_index(), step)
            stragglers, dead = ledger.classify(step)
            if dead:
                plan = FT.plan_recovery(
                    ledger, step, mesh.devices.shape if mesh else (1,),
                    mesh.axis_names if mesh else ("data",),
                    hosts_per_pod=1,
                    ckpt_latest=CK.latest_step(args.ckpt_dir)
                    if args.ckpt_dir else None)
                print(f"!! dead hosts {dead}: recovery plan {plan}")
            if step % 10 == 0:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{time.time()-t0:.2f}s/step", flush=True)
            if args.ckpt_dir and step and step % args.ckpt_every == 0:
                if ckpt_thread is not None:
                    ckpt_thread.join()  # one in flight
                ckpt_thread = CK.save(args.ckpt_dir, step, state,
                                      extra={"arch": cfg.name})
        if ckpt_thread is not None:
            ckpt_thread.join()
        print(f"done: final loss {float(metrics['loss']):.4f}")
    finally:
        if ctx:
            ctx.__exit__(None, None, None)


if __name__ == "__main__":
    main()
