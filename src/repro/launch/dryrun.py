import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count on first init), so no `from __future__ import annotations`.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding rules produce a partitionable program (SPMD succeeds),
  * it fits (memory_analysis: per-device bytes),
  * and it yields the roofline inputs (cost_analysis FLOPs/bytes are
    PER-DEVICE post-partition on the CPU backend; collective bytes are
    parsed from the compiled HLO).

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all --out benchmarks/results/dryrun.json
  python -m repro.launch.dryrun --all --multi-pod        # 2x16x16 proof
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, SKIPS, get
from repro.dist import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamW, cosine_schedule, opt_state_specs
from repro.train import train_step as TS

# HLO collective ops whose operand bytes feed the roofline collective term.
_COLL_RE = re.compile(
    r"(\w+(?:\.\d+)?)\s*=\s*(\S+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in the compiled HLO."""
    totals = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(2), m.group(3)
        size = 0
        for dt, dims in _SHAPE_RE.findall(shape_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            size += n * _DTYPE_BYTES[dt]
        totals[op] = totals.get(op, 0) + size
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


def _shard_batch(shapes, names, mesh):
    return jax.tree.map(
        lambda s, n: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=SH.act_sharding(s.shape, n, mesh)),
        shapes, names,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, tuple)))


def lower_cell(cfg: ModelConfig, shape_name: str, mesh, microbatches: int = 1):
    """Lower + compile one (arch, shape) cell on `mesh`."""
    seq_len, global_batch, kind = SHAPES[shape_name]
    act_rules, param_rules = SH.select_rules(cfg)
    with SH.axis_rules(mesh, act_rules=act_rules, param_rules=param_rules):
        if kind in ("train", "prefill"):
            inputs = M.input_specs(cfg, seq_len, global_batch, kind)
            in_names = M.input_spec_names(cfg, kind)
            batch_sds = _shard_batch(inputs, in_names, mesh)

            params_shape = jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
            p_spec = SH.shard_tree(params_shape, M.param_specs(cfg), mesh)
            params_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                params_shape, p_spec)

            if kind == "train":
                opt = AdamW(lr=cosine_schedule(3e-4, 100, 10000))
                opt_shape = jax.eval_shape(opt.init, params_shape)
                o_spec = SH.shard_tree(
                    opt_shape, opt_state_specs(M.param_specs(cfg)), mesh)
                opt_sds = jax.tree.map(
                    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                       sharding=sh),
                    opt_shape, o_spec)
                state_sds = TS.TrainState(params_sds, opt_sds)
                step = TS.make_train_step(cfg, opt, microbatches=microbatches)
                fn = jax.jit(step, donate_argnums=(0,))
                lowered = fn.lower(state_sds, batch_sds)
            else:  # prefill: logits only (cache write shown by decode cells)
                fn = jax.jit(lambda p, b: M.forward(cfg, p, b)[0])
                lowered = fn.lower(params_sds, batch_sds)
        else:  # decode
            params_shape = jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
            p_spec = SH.shard_tree(params_shape, M.param_specs(cfg), mesh)
            params_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                params_shape, p_spec)
            cache_shape = M.cache_shapes(cfg, global_batch, seq_len)
            c_spec = SH.shard_tree(cache_shape, M.cache_specs(cfg), mesh,
                                   rules=act_rules)
            cache_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                cache_shape, c_spec)
            tok_sds = _shard_batch(
                M.input_specs(cfg, seq_len, global_batch, "decode"),
                M.input_spec_names(cfg, "decode"), mesh)
            fn = jax.jit(
                lambda p, c, t: M.decode_step(cfg, p, c, t),
                donate_argnums=(1,))
            lowered = fn.lower(params_sds, cache_sds, tok_sds["tokens"])

        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 1, cfg_override=None):
    # hlo_cost lives in benchmarks/ (repo root on sys.path when run as
    # `python -m repro.launch.dryrun` from the repo).
    from benchmarks import hlo_cost

    cfg = cfg_override or get(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, compiled = lower_cell(cfg, shape_name, mesh, microbatches)
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # jax 0.4.x returns a one-element list
        cost = cost[0]
    hlo_text = compiled.as_text()
    attributed = hlo_cost.analyze(hlo_text)   # trip-count-aware, per-device
    coll_naive = collective_bytes(hlo_text)   # body-once (sanity column)
    seq_len, global_batch, kind = SHAPES[shape_name]
    return {
        "arch": arch,
        "shape": shape_name,
        "kind": kind,
        "mesh": list(mesh.devices.shape),
        "multi_pod": multi_pod,
        "compile_s": round(compile_s, 1),
        # xla cost_analysis counts while bodies ONCE (see hlo_cost docstring)
        "xla_flops_body_once": cost.get("flops", 0.0),
        "xla_bytes_body_once": cost.get("bytes accessed", 0.0),
        "dot_flops_per_device": attributed["flops"],
        "collective_bytes_per_device": attributed["coll"],
        "collective_counts": attributed["counts"],
        "collective_bytes_total": attributed["coll_total"],
        "collective_bytes_naive": coll_naive["total"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens": seq_len * global_batch if kind != "decode" else global_batch,
        "status": "ok",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="lower the reduced smoke config instead of the "
                         "published one (CI: fast partitionability check "
                         "of the sharding rule tables on the 16x16 mesh)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    results = []
    for arch, shape in cells:
        if (arch, shape) in SKIPS:
            results.append({"arch": arch, "shape": shape, "status": "skip",
                            "reason": SKIPS[(arch, shape)]})
            print(f"SKIP {arch} x {shape}: {SKIPS[(arch, shape)]}", flush=True)
            continue
        try:
            override = None
            if args.smoke:
                from repro.configs import get_smoke
                override = get_smoke(arch)
            r = run_cell(arch, shape, args.multi_pod, args.microbatches,
                         cfg_override=override)
            results.append(r)
            print(f"OK   {arch} x {shape}: "
                  f"{r['dot_flops_per_device']:.3e} dot-flops/dev, "
                  f"temp {r['memory']['temp_bytes']/2**30:.2f} GiB, "
                  f"coll {r['collective_bytes_total']/2**20:.1f} MiB, "
                  f"compile {r['compile_s']}s", flush=True)
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            results.append({"arch": arch, "shape": shape, "status": "fail",
                            "error": f"{type(e).__name__}: {e}"})
            print(f"FAIL {arch} x {shape}: {type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc()

    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")

    n_fail = sum(r.get("status") == "fail" for r in results)
    if n_fail:
        raise SystemExit(f"{n_fail}/{len(results)} cells failed")


if __name__ == "__main__":
    main()
