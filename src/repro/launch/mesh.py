"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py sets
XLA_FLAGS for 512 placeholder devices before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic path: arbitrary shapes after fault-tolerance re-planning."""
    return jax.make_mesh(tuple(shape), tuple(axes))


# TPU v5e hardware constants used by the roofline analysis (benchmarks/).
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~4 links usable per chip)
