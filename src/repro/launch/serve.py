"""Serve driver: token generation and learned-index lookup serving.

Token mode (paged-KV continuous batching engine):

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
        --requests 8 --max-new 8

Lookup mode (routes through repro.serve.lookup: async admission,
micro-batching, sharded fused dispatch — DESIGN.md §9):

    PYTHONPATH=src python -m repro.launch.serve --mode lookup \
        --dataset amzn --index rmi --requests 200 --keys-per-request 64

Ops surface (DESIGN.md §14): ``--metrics-port`` starts the stdlib HTTP
exporter (GET /metrics for Prometheus text, /metrics.json for the
structured lifetime+windowed document, /trace.json for the live Chrome
trace, /health.json + /alerts.json for index health, /healthz for
liveness), ``--trace-out`` records the whole run and writes a
Chrome-trace JSON openable in chrome://tracing or Perfetto,
``--metrics-jsonl`` appends periodic metrics snapshots for offline
analysis, and ``--slo-p99-ms`` arms the windowed error-budget tracking:

    PYTHONPATH=src python -m repro.launch.serve --mode lookup \
        --metrics-port 9100 --trace-out /tmp/lookup_trace.json \
        --slo-p99-ms 20

Index health (DESIGN.md §15): lookup serving is instrumented by default
(``--no-health`` turns it off) — the run summary prints the model-facing
health line (displacement p99 vs the error bound, drift score) and the
alert verdict; ``--doctor`` exits nonzero when any alert is firing at
the end of the run, so a scripted health check is one command:

    PYTHONPATH=src python -m repro.launch.serve --mode lookup --doctor

Self-driving tuning (DESIGN.md §17): ``--autotune-daemon`` attaches the
shadow retuner — alert-triggered off-hot-path retunes under a
workload-aware objective, oracle-verified before the hot-swap — and
``--autotune-store DIR`` persists tuned specs across restarts;
``--doctor`` then also covers the daemon (last trigger/verdict in the
summary, nonzero exit on a dead retuner thread):

    PYTHONPATH=src python -m repro.launch.serve --mode lookup \\
        --autotune-daemon --autotune-store /tmp/specs --doctor
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax


def run_tokens(args):
    from repro.configs import get, get_smoke
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = [engine.submit(
        list(rng.integers(2, cfg.vocab, int(rng.integers(3, 10)))),
        max_new=args.max_new) for _ in range(args.requests)]
    outs = engine.run(max_steps=args.requests * (args.max_new + 12))
    dt = time.time() - t0
    n_tok = sum(len(v) for v in outs.values())
    for rid in rids:
        print(f"request {rid}: {outs[rid]}")
    print(f"\n{n_tok} tokens for {len(rids)} requests in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, continuous batching over "
          f"{args.max_batch} slots); kv pool util now "
          f"{engine.kv.alloc.utilization:.2f}")


def run_lookup(args):
    import contextlib

    from repro.core import base
    from repro.core.spec import IndexSpec
    from repro.data import sosd
    from repro.obs.export import JsonlMetricsLogger, MetricsServer
    from repro.serve.lookup import (LookupService, LookupServiceConfig,
                                    default_spec)

    keys = sosd.generate(args.dataset, args.n_keys, seed=1)
    # --spec takes one declarative IndexSpec (JSON) over the index name
    sp = (IndexSpec.from_json(args.spec) if args.spec
          else default_spec(args.index))
    at_cfg = None
    if args.autotune_daemon or args.autotune_store:
        from repro.autotune import AutotuneConfig
        at_cfg = AutotuneConfig(daemon=args.autotune_daemon,
                                store_dir=args.autotune_store)
    svc = LookupService(keys, LookupServiceConfig(
        spec=sp, max_batch=args.max_batch,
        deadline_ms=args.deadline_ms, executor=args.executor,
        shards=args.shards, replicas=args.replicas,
        trace=bool(args.trace_out), slo_p99_ms=args.slo_p99_ms,
        health=not args.no_health, autotune=at_cfg))
    print(f"serving spec: {svc.generation.spec.to_json()} "
          f"(executor={args.executor})")
    topo = getattr(svc.generation, "topology", None)
    if topo is not None:
        print(f"topology: {topo.describe()}")
    q = sosd.make_queries(keys, args.requests * args.keys_per_request, seed=2)

    with contextlib.ExitStack() as stack:
        if args.metrics_port is not None:
            server = stack.enter_context(
                MetricsServer(svc, port=args.metrics_port,
                              window_s=args.window_s))
            print(f"metrics: http://127.0.0.1:{server.port}/metrics "
                  f"(+ /metrics.json, /trace.json, /health.json, "
                  f"/alerts.json, /healthz)")
        if args.metrics_jsonl:
            stack.enter_context(JsonlMetricsLogger(
                svc, args.metrics_jsonl, interval_s=1.0,
                window_s=args.window_s))
        t0 = time.time()
        at_dead = False
        with svc:
            futs = [svc.submit(q[i * args.keys_per_request:
                                 (i + 1) * args.keys_per_request])
                    for i in range(args.requests)]
            outs = [f.result(timeout=120.0) for f in futs]
            # probe the retuner thread BEFORE stop() shuts it down on
            # purpose: --doctor must distinguish "died" from "stopped"
            at_dead = (svc.autotune is not None and svc.autotune.cfg.daemon
                       and not svc.autotune.alive)
        dt = time.time() - t0

    got = np.concatenate(outs)
    exact = bool(np.array_equal(got, base.lower_bound_oracle(keys, q)))
    snap = svc.metrics.snapshot()
    print(f"{len(q)} lookups / {args.requests} requests in {dt:.2f}s over "
          f"{svc.dispatcher.n_shards} shard(s): "
          f"{snap['lookups_per_s']/1e3:.1f} klookups/s, "
          f"{snap['batches']} batches, "
          f"occupancy {snap['mean_occupancy']:.2f}, "
          f"batch p99 {snap['p99_batch_ms']:.2f}ms, "
          f"queue p99 {snap['p99_queue_ms']:.2f}ms, "
          f"request p99 {snap['p99_request_ms']:.2f}ms, "
          f"cache hit rate {snap['cache_hit_rate']:.2f}")
    w = svc.metrics.windowed(args.window_s)
    line = (f"windowed({w['window_s']:.0f}s): p50 {w['p50_ms']:.2f}ms, "
            f"p99 {w['p99_ms']:.2f}ms, "
            f"{w['lookups_per_s']/1e3:.1f} klookups/s")
    if args.slo_p99_ms is not None:
        line += (f", SLO p99<{args.slo_p99_ms:.0f}ms: "
                 f"{w['slo_violations']} violations, "
                 f"budget burn {w['slo_budget_burn']:.2f}")
    print(line)
    if args.trace_out:
        svc.recorder.save(args.trace_out)
        print(f"wrote Chrome trace ({len(svc.recorder)} spans, "
              f"{svc.recorder.n_dropped} dropped) to {args.trace_out} — "
              f"open in chrome://tracing or https://ui.perfetto.dev")
    if args.metrics_jsonl:
        print(f"wrote metrics JSONL to {args.metrics_jsonl}")
    # §15 health verdict: evaluate the alert rules over the whole run
    events = svc.check_alerts(window_s=max(args.window_s, dt + 1.0))
    firing = svc.alerts.firing()
    if not args.no_health:
        h = svc.health_snapshot(max(args.window_s, dt + 1.0))
        gen = svc.generation
        max_err = int(getattr(gen, "max_err",
                              gen.plan.bounds.max_err))
        print(f"health: disp p99 {h['disp_p99']:.0f} of max_err "
              f"{max_err} "
              f"(bound utilization {h['bound_utilization_p99']:.2f}, "
              f"{h['disp_p99_ratio']:.2f}x build), "
              f"last-mile steps {h['mean_last_mile_steps']:.1f}, "
              f"drift TV {h['drift_tv']:.3f} over {h['drift_n']:.0f} "
              f"lookups")
    for e in events:
        print(f"alert {e['rule']} {e['state']}: {e['key']}={e['value']:.3g} "
              f"({e['op']} {e['threshold']:.3g}) — {e['action']}")
    print("alerts: " + (", ".join(firing) if firing else "none firing"))
    if svc.autotune is not None:
        st = svc.autotune.status()
        lt = st["last_trigger"]
        daemon_state = ("DEAD" if at_dead
                        else "up" if st["daemon"] else "off")
        print(f"autotune: daemon={daemon_state} "
              f"triggered={st['n_triggered']} swapped={st['n_swapped']} "
              f"rejected={st['n_rejected']}, "
              f"last trigger {lt['rule'] if lt else 'none'}, "
              f"last verdict {st['last_verdict'] or 'none'}")
        if at_dead:
            print(f"autotune: retuner thread died: "
                  f"{st['last_error'] or 'unknown error'}")
    print(f"exact vs lower_bound oracle: {exact}")
    if args.doctor and (firing or not exact or at_dead):
        raise SystemExit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("tokens", "lookup"), default="tokens")
    # token mode
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    # shared / lookup mode (default resolved per mode below: 4 decode
    # slots for tokens, 2048 keys per dispatch for lookups)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--dataset", default="amzn",
                    choices=sorted(("amzn", "face", "osm", "wiki")))
    ap.add_argument("--index", default="rmi")
    ap.add_argument("--spec", default=None,
                    help="IndexSpec JSON (overrides --index), e.g. "
                         '\'{"index": "pgm", "hyper": {"eps": 32}}\'')
    ap.add_argument("--n-keys", type=int, default=200_000)
    ap.add_argument("--keys-per-request", type=int, default=64)
    ap.add_argument("--deadline-ms", type=float, default=2.0)
    ap.add_argument("--executor", choices=("sync", "async"), default="async",
                    help="lookup dispatch engine (DESIGN.md §13): the "
                         "continuous-batching async executor (default) "
                         "or the serial sync reference loop")
    ap.add_argument("--shards", type=int, default=1,
                    help="range-routed serving topology (DESIGN.md §16): "
                         "partition the key space into this many "
                         "equal-count ranges with per-shard indexes and "
                         "scatter/gather dispatch (1 = broadcast)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="read fan-out per shard (routed topology only): "
                         "each shard's lookups round-robin over this many "
                         "replica lanes")
    # ops surface (lookup mode, DESIGN.md §14)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="start the HTTP metrics endpoint on this port "
                         "(0 = ephemeral): /metrics Prometheus text, "
                         "/metrics.json, /trace.json")
    ap.add_argument("--trace-out", default=None,
                    help="record request/lifecycle spans and write a "
                         "Chrome-trace JSON here (chrome://tracing, "
                         "Perfetto)")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append one metrics snapshot per second to this "
                         "JSONL file (offline analysis)")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="p99 latency SLO target: windowed snapshots "
                         "report violations + error-budget burn")
    ap.add_argument("--window-s", type=float, default=10.0,
                    help="rolling window the ops surfaces report over")
    ap.add_argument("--no-health", action="store_true",
                    help="disable index-health instrumentation "
                         "(DESIGN.md §15); reads dispatch the plain "
                         "executable with no stats reduction")
    ap.add_argument("--autotune-daemon", action="store_true",
                    help="start the shadow-retuner daemon (DESIGN.md "
                         "§17): workload-drift/error/SLO alerts trigger "
                         "an off-hot-path retune, verified bit-exact "
                         "against the oracle before hot-swapping")
    ap.add_argument("--autotune-store", default=None,
                    help="spec-artifact store directory: tuned specs "
                         "persist keyed by (dataset fingerprint, byte "
                         "budget, workload signature) so a restart on "
                         "the same workload skips the ladder sweep")
    ap.add_argument("--doctor", action="store_true",
                    help="one-shot health check: exit 1 when any alert "
                         "is firing, the oracle check fails, or the "
                         "autotune daemon thread died during the run")
    args = ap.parse_args()

    if args.mode == "lookup":
        if args.max_batch is None:
            args.max_batch = 2048
        run_lookup(args)
    else:
        if args.max_batch is None:
            args.max_batch = 4
        run_tokens(args)


if __name__ == "__main__":
    main()
