"""Serve driver: batched requests through the paged-KV engine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
        --requests 8 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get, get_smoke
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = [engine.submit(
        list(rng.integers(2, cfg.vocab, int(rng.integers(3, 10)))),
        max_new=args.max_new) for _ in range(args.requests)]
    outs = engine.run(max_steps=args.requests * (args.max_new + 12))
    dt = time.time() - t0
    n_tok = sum(len(v) for v in outs.values())
    for rid in rids:
        print(f"request {rid}: {outs[rid]}")
    print(f"\n{n_tok} tokens for {len(rids)} requests in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, continuous batching over "
          f"{args.max_batch} slots); kv pool util now "
          f"{engine.kv.alloc.utilization:.2f}")


if __name__ == "__main__":
    main()
