"""Microbatched pipeline parallelism over the `model` mesh axis.

GPipe-style circular schedule (DESIGN.md §8.2): the L-layer stack is split
into P = mesh.shape[axis] contiguous stages of L/P layers; M microbatches
stream through, one boundary `ppermute` per tick.  Tick t has stage i
working on microbatch t - i, so the whole batch drains in M + P - 1 ticks
and the idle ("bubble") fraction is (P-1)/(M+P-1) — `bubble_fraction`
below, the planning number the scaling benchmark quotes.

Parity is exact, not approximate: each microbatch traverses the same
layers in the same order as the sequential stack, as one [B, D] block per
stage, so the pipeline result matches `sequential_apply` to float
round-off (tests/test_pipeline_parallel.py asserts it, and asserts the
lowering really contains collective-permute boundary transfers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# version shim lives in the package __init__ (defined before submodule
# imports, so no cycle)
from repro.dist import shard_map as _shard_map


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule: (P-1)/(M+P-1)."""
    if n_stages <= 1:
        return 0.0
    return (n_stages - 1) / (n_micro + n_stages - 1)


def sequential_apply(body, ws, x):
    """Reference: scan every layer over every microbatch, no mesh.

    ws: [L, ...] stacked per-layer weights; x: [M, B, D] microbatches.
    Processes one [B, D] microbatch at a time (lax.map, not vmap) so the
    op sequence per microbatch is identical to the pipeline's stages.
    """
    def one(xb):
        return jax.lax.scan(lambda a, w: (body(a, w), None), xb, ws)[0]

    return jax.lax.map(one, x)


def pipeline_apply(body, ws, x, mesh, axis: str = "model"):
    """Run `body` layer-wise as a P-stage pipeline on `mesh[axis]`.

    body: (activation [B, D], layer weights) -> activation [B, D]
    ws:   [L, ...] stacked weights, L divisible by P; stage i owns the
          contiguous block ws[i*L/P:(i+1)*L/P]
    x:    [M, B, D] microbatches, replicated in and out

    Degenerates to the sequential schedule at P == 1 (same code path, the
    boundary permute is the identity).
    """
    n_stages = dict(mesh.shape)[axis]
    n_layers, n_micro = ws.shape[0], x.shape[0]
    if n_layers % n_stages:
        raise ValueError(
            f"{n_layers} layers not divisible into {n_stages} stages")
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def stage_fn(w_local, xs):
        # w_local: this stage's [L/P, ...] block; xs: all microbatches.
        idx = jax.lax.axis_index(axis)

        def run_stage(a):
            return jax.lax.scan(lambda c, w: (body(c, w), None), a,
                                w_local)[0]

        def tick(carry, t):
            state, outs = carry
            prev = jax.lax.ppermute(state, axis, perm)
            # stage 0 ingests microbatch t (clip: past the end it chews a
            # stale copy whose result is never recorded)
            feed = xs[jnp.clip(t, 0, n_micro - 1)]
            state = run_stage(jnp.where(idx == 0, feed, prev))
            # last stage finishes microbatch t-(P-1) at tick t; predicate
            # only the written slice (a whole-buffer select would copy all
            # M microbatches per tick)
            done = t - (n_stages - 1)
            record = (idx == n_stages - 1) & (done >= 0)
            slot = jnp.clip(done, 0, n_micro - 1)
            outs = outs.at[slot].set(jnp.where(record, state, outs[slot]))
            return (state, outs), None

        init = (jnp.zeros_like(xs[0]), jnp.zeros_like(xs))
        (_, outs), _ = jax.lax.scan(tick, init,
                                    jnp.arange(n_micro + n_stages - 1))
        # only the last stage holds real outputs; zero-mask + psum
        # replicates them so out_specs can be P() on every device
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return _shard_map(stage_fn, mesh=mesh, in_specs=(P(axis), P()),
                      out_specs=P())(ws, x)
