"""Int8 gradient compression with error feedback (DESIGN.md §8.3).

The cross-pod gradient all-reduce is the one collective that rides the
slow (DCN) links; the candidate fix is int8 payloads: symmetric linear
quantization, scale = max|g| / 127, with the per-step rounding residual
carried forward and added back before the next quantization (error
feedback / EF-SGD).  This module implements the NUMERICS of that scheme
— what training actually observes — so its convergence cost can be
measured on any backend; the reduce itself runs over the dequantized f32
values (see `compressed_psum` for why, and for what a real int8
transport additionally needs).  Two invariants the tests pin down:

  round-trip   dequantize(q) + residual == input, exactly (the residual
               is DEFINED as the difference, so this holds to float
               round-off whatever the input — zeros, huge finite values);
  one-step     |residual| <= scale/2 elementwise (round-to-nearest);
  unbiased     with feedback enabled the residual never accumulates, so
               sum_t dequantize(q_t) tracks sum_t g_t to O(scale), not
               O(T * scale).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    """Int8 payload + f32 scale: the wire format of compressed_psum."""
    q: jax.Array       # int8, same shape as the input
    scale: jax.Array   # f32 scalar


def quantize(x, err: Optional[jax.Array] = None
             ) -> Tuple[Compressed, jax.Array]:
    """Quantize x (+ carried error) to int8; returns (payload, residual).

    Pass the returned residual back as `err` next step for error
    feedback.  scale = max|x + err| / 127 keeps every value inside the
    int8 range, so no clipping ever occurs and the one-step error bound
    |residual| <= scale/2 is exact round-to-nearest.
    """
    y = x if err is None else x + err
    y32 = y.astype(jnp.float32)
    amax = jnp.max(jnp.abs(y32))
    # tiny floor: an all-zero tensor quantizes to zeros, not NaN
    scale = jnp.maximum(amax, jnp.float32(1e-30)) / jnp.float32(127.0)
    q = jnp.round(y32 / scale).astype(jnp.int8)
    residual = (y32 - q.astype(jnp.float32) * scale).astype(y.dtype)
    return Compressed(q, scale), residual


def dequantize(c: Compressed) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def compressed_psum(x, axis_name: str, err: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """psum of the int8-quantized x over `axis_name` (inside shard_map).

    Returns (sum, residual); thread the residual back in as `err` on the
    next step.

    Transport note: this dequantizes BEFORE the psum, so the collective
    itself still moves f32 — it models the numerics of a compressed
    all-reduce (quantization error + error feedback), not the wire
    bytes.  A real int8 transport needs a shared scale (pmax) plus an
    integer-accumulating reduce, which XLA does not expose as a psum;
    wiring that through a ragged all-to-all is an open roadmap item.
    """
    c, residual = quantize(x, err)
    return jax.lax.psum(dequantize(c), axis_name), residual
