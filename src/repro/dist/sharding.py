"""Logical-axis sharding: name -> mesh-axis resolution (DESIGN.md §8.1).

Model code never mentions devices.  It annotates tensors with *logical*
axis names ("batch", "heads", "expert_fsdp", ...) and this module resolves
those names against the active mesh through an ordered rule table:

  rules:  logical name -> tuple of candidate mesh-axis groups, best first.
          A group is a tuple of mesh axes sharded jointly (e.g. the FSDP
          storage rule ("model", "data") = 256-way on the production mesh).

Resolution (`resolve_spec`) walks the tensor dims in order and takes, per
dim, the first candidate that survives three filters:

  1. presence  — axes missing from the mesh, or of size 1, drop out of the
                 group (an elastic 8x16 mesh reuses the 16x16 tables);
  2. reuse     — a mesh axis already consumed by an earlier dim of the SAME
                 tensor drops out (XLA forbids axis reuse within one spec);
  3. divisible — what remains must divide the dim size evenly, else the
                 whole candidate is rejected and the next one is tried.

A dim whose candidates all fail is replicated (None) — the "divisibility
fallback" that lets starcoder2's 24 heads run on a 16-way TP axis by
moving the shards onto head_dim instead.

The rule tables are module-level constants so the dry-run, the train
driver and the tests all agree on one source of truth; `axis_rules()`
installs them (plus the mesh) in a thread-local context that
`logical_constraint` / `act_sharding` / `dispatch_groups` read at trace
time.  With no context installed everything is a no-op, which is what
keeps the single-device unit tests oblivious to this module.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

Rules = Mapping[str, Tuple[Tuple[str, ...], ...]]

# ---------------------------------------------------------------------------
# rule tables (DESIGN.md §8.1 reproduces these with rationale per row)
# ---------------------------------------------------------------------------

#: Activations, TP regime: batch is data-parallel, contraction outputs are
#: tensor-parallel over `model`.  `seq` and `embed` deliberately have no
#: rule — embed is the residual-stream dim (sharding it would put an
#: all-gather in front of every matmul) and seq only shards in the FSDP
#: regime below.
ACT_RULES: Rules = {
    "batch": (("pod", "data"), ("data",)),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "mlp": (("model",),),
    "vocab": (("model",),),
    "experts": (("model",),),
    "moe_cap_tp": (("model",),),
    "expert_mlp": (("model",),),
    "ssm_inner": (("model",),),
}

#: Parameters: TP on the output-feature dims (heads/mlp/vocab/experts),
#: FSDP storage on the non-contraction dims (head_dim / expert_fsdp pick
#: up whatever axes TP left free).  `embed` is the contraction dim of
#: every projection, so it carries no rule: sharding it would all-gather
#: activations instead of weights at every use site (see moe_specs).
PARAM_RULES: Rules = {
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (("data", "model"), ("data",), ("model",)),
    "mlp": (("model", "data"), ("model",), ("data",)),
    "vocab": (("model",),),
    "experts": (("model",),),
    "expert_fsdp": (("model", "data"), ("model",), ("data",)),
    "ssm_inner": (("model", "data"), ("model",), ("data",)),
}

#: Activations, FSDP regime (cfg.parallelism == "fsdp"): pure data
#: parallelism — batch shards over every mesh axis it divides, and `seq`
#: picks up whatever the batch couldn't use (sequence parallelism), so a
#: prefill_32k batch of 32 on a 16x16 mesh still fills all 256 devices.
FSDP_ACT_RULES: Rules = {
    **ACT_RULES,
    "batch": (("pod", "data", "model"), ("data", "model"), ("data",)),
    "seq": (("model",), ("pod",)),
}


# ---------------------------------------------------------------------------
# thread-local context installed by axis_rules()
# ---------------------------------------------------------------------------
class _Context(threading.local):
    def __init__(self):
        self.mesh = None
        self.act_rules: Optional[Rules] = None
        self.param_rules: Optional[Rules] = None


_CTX = _Context()


@contextlib.contextmanager
def axis_rules(mesh, act_rules: Optional[Rules] = None,
               param_rules: Optional[Rules] = None):
    """Install (mesh, rule tables) for logical_constraint / act_sharding.

    Re-entrant and thread-local: jit tracing happens on the caller's
    thread, so constraints inside a traced model body see the context the
    driver entered.
    """
    prev = (_CTX.mesh, _CTX.act_rules, _CTX.param_rules)
    _CTX.mesh = mesh
    _CTX.act_rules = ACT_RULES if act_rules is None else act_rules
    _CTX.param_rules = PARAM_RULES if param_rules is None else param_rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.act_rules, _CTX.param_rules = prev


def select_rules(cfg) -> Tuple[Rules, Rules]:
    """(act_rules, param_rules) for a ModelConfig.

    `parallelism="fsdp"` swaps in the pure-DP activation table (mixtral:
    8 experts can't split a 16-way model axis, so TP buys nothing and the
    dispatch all-to-all is cheapest fully data-parallel).  "tp" and "auto"
    use the TP tables — PARAM_RULES already stores weights FSDP-style on
    the non-contraction dims, so "tp" is the safe general default.
    """
    mode = getattr(cfg, "parallelism", "auto")
    if mode == "fsdp":
        return FSDP_ACT_RULES, PARAM_RULES
    return ACT_RULES, PARAM_RULES


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------
def _mesh_shape(mesh) -> Mapping[str, int]:
    # jax.sharding.Mesh has .shape as an OrderedDict; tests also pass bare
    # objects with a dict .shape (resolution only needs axis sizes).
    return dict(mesh.shape)


def resolve_spec(shape: Sequence[int], names: Sequence[Optional[str]],
                 mesh, rules: Rules) -> P:
    """Resolve one tensor's logical names to a PartitionSpec (see module
    docstring for the three filters)."""
    sizes = _mesh_shape(mesh)
    used: set = set()
    spec = []
    for dim, name in zip(shape, names):
        entry = None
        for cand in (rules.get(name, ()) if name is not None else ()):
            axes = tuple(a for a in cand
                         if sizes.get(a, 1) > 1 and a not in used)
            if not axes:
                continue
            n_shards = 1
            for a in axes:
                n_shards *= sizes[a]
            if dim % n_shards:
                continue
            entry = axes
            break
        if entry is None:
            spec.append(None)
        else:
            used.update(entry)
            spec.append(entry[0] if len(entry) == 1 else entry)
    return P(*spec)


def logical_constraint(x, names: Sequence[Optional[str]]):
    """with_sharding_constraint by logical names; identity with no context.

    The single entry point the model code uses — it stays importable and
    free of side effects on machines with one device and no mesh.
    """
    mesh = _CTX.mesh
    if mesh is None:
        return x
    rules = _CTX.act_rules if _CTX.act_rules is not None else ACT_RULES
    spec = resolve_spec(x.shape, names, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def act_sharding(shape: Sequence[int], names: Sequence[Optional[str]],
                 mesh) -> NamedSharding:
    """NamedSharding for one input/activation leaf (dry-run batch specs)."""
    rules = _CTX.act_rules if _CTX.act_rules is not None else ACT_RULES
    return NamedSharding(mesh, resolve_spec(shape, names, mesh, rules))


def shard_tree(shapes: Any, names: Any, mesh, rules: Optional[Rules] = None):
    """Map a ShapeDtypeStruct tree + matching logical-name tree to
    NamedShardings.  Default rules: the context's param rules (params and
    optimizer state); pass `rules=act_rules` for the decode cache."""
    if rules is None:
        rules = _CTX.param_rules if _CTX.param_rules is not None else PARAM_RULES

    def one(s, n):
        return NamedSharding(mesh, resolve_spec(tuple(s.shape), tuple(n),
                                                mesh, rules))

    return jax.tree.map(one, shapes, names)


def dispatch_groups(tokens: Optional[int] = None, *, mesh=None,
                    rules: Optional[Rules] = None) -> int:
    """Shard count of the first applicable `batch` rule candidate; 1 with
    no mesh.  Two consumers, one rule walk: the MoE dispatch group count
    (moe._n_groups, which halves it until it divides the token count) and
    the serve-layer dispatcher's batch-shard count
    (`repro.serve.lookup.dispatch`).

    Must return a Python int (it sizes a reshape at trace time).  `mesh`
    and `rules` default to the thread-local context installed by
    axis_rules() — pass them explicitly to resolve against a mesh with no
    context (the serving path).
    """
    del tokens
    if mesh is None:
        mesh = _CTX.mesh
    if mesh is None:
        return 1
    if rules is None:
        rules = _CTX.act_rules if _CTX.act_rules is not None else ACT_RULES
    sizes = _mesh_shape(mesh)
    for cand in rules.get("batch", ()):
        axes = tuple(a for a in cand if sizes.get(a, 1) > 1)
        if axes:
            g = 1
            for a in axes:
                g *= sizes[a]
            return g
    return 1


def shard_replica_groups(devices, replicas):
    """Assign each shard a round-robin group of physical devices.

    ``replicas[s]`` devices per shard, walked over ``devices`` with a
    running pointer modulo the device count — with S shards on S devices
    at one replica each, shard s lands exactly on device s; with more
    replica seats than devices the groups wrap, spreading hot shards over
    distinct devices first.  Returns a list of per-shard device lists.
    """
    devices = list(devices)
    if not devices:
        raise ValueError("shard_replica_groups needs at least one device")
    groups = []
    ptr = 0
    for r in replicas:
        r = int(r)
        if r < 1:
            raise ValueError("every shard needs at least one replica")
        groups.append([devices[(ptr + i) % len(devices)] for i in range(r)])
        ptr += r
    return groups
