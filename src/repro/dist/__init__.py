"""Distributed execution layer: sharding rules, pipeline schedule, comms.

Three orthogonal pieces (DESIGN.md §8):

  sharding           logical-axis-name -> PartitionSpec resolution over the
                     launch/mesh.py mesh (GSPMD; the model code only names
                     axes, never touches device topology)
  pipeline_parallel  microbatched GPipe schedule over the `model` mesh axis
                     with exact parity against the sequential stack
  compression        int8 gradient all-reduce with error feedback

`shard_map` is re-exported here behind a version shim: jax moved it from
`jax.experimental.shard_map` to the top-level namespace, and this repo runs
on both sides of that move.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

from repro.dist import compression, pipeline_parallel, sharding  # noqa: F401,E402

__all__ = ["compression", "pipeline_parallel", "sharding", "shard_map"]
