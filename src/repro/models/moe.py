"""Mixture-of-Experts FFN with learned-index-style sorted dispatch.

Dispatch modes (cfg.moe_dispatch):

  dense    GShard-style dense compute: every expert runs every token, the
           router mask selects outputs.  FLOP cost = E/k times the useful
           work — the paper-agnostic baseline recorded in §Perf.

  sorted   The production path, built exactly from the paper's machinery:
           sort tokens by expert id, find the per-expert segment boundaries
           with ``lower_bound(sorted_ids, e)`` (the paper's §2 operation —
           here the ids' CDF is learned by the router's own load-balancing,
           making a *linear* index model near-exact), then gather tokens
           into [E, C] capacity slots and run one batched matmul per stack.

Both paths share router + aux losses (Switch load-balance + router z-loss).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.dist.sharding import logical_constraint


def init_moe(cfg: ModelConfig, key) -> dict:
    d, h, e = cfg.d_model, cfg.moe_hidden, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    s_in, s_out = d ** -0.5, h ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d, h)) * s_in).astype(dt),
        "wg": (jax.random.normal(ks[2], (e, d, h)) * s_in).astype(dt),
        "wo": (jax.random.normal(ks[3], (e, h, d)) * s_out).astype(dt),
    }
    if cfg.n_shared_experts:
        hs = h * cfg.n_shared_experts
        p["shared_wi"] = (jax.random.normal(ks[4], (d, hs)) * s_in).astype(dt)
        p["shared_wg"] = (jax.random.normal(
            jax.random.fold_in(ks[4], 1), (d, hs)) * s_in).astype(dt)
        p["shared_wo"] = (jax.random.normal(
            jax.random.fold_in(ks[4], 2), (hs, d)) * s_out).astype(dt)
    return p


def moe_specs(cfg: ModelConfig) -> dict:
    # Expert weights deliberately do NOT use the embed/data FSDP rule: a
    # data-sharded contraction dim plus the data-sharded dispatch batch dim
    # makes SPMD all-gather the (huge) expert activations instead of the
    # (small) weights.  Sharding the hidden dim over (model, data) keeps
    # FSDP storage 256-way while the use-site gather is weights-only.
    p = {
        "router": ("embed", "experts"),
        "wi": ("experts", None, "expert_fsdp"),
        "wg": ("experts", None, "expert_fsdp"),
        "wo": ("experts", "expert_fsdp", None),
    }
    if cfg.n_shared_experts:
        p["shared_wi"] = ("embed", "mlp")
        p["shared_wg"] = ("embed", "mlp")
        p["shared_wo"] = ("mlp", "embed")
    return p


def _router(cfg: ModelConfig, p, x):
    """Returns (topk probs [T,k], topk ids [T,k], aux losses)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch load-balance loss: E * sum_e f_e * p_e
    e = cfg.n_experts
    dispatch = jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32)
    f = dispatch.mean(0)
    pbar = probs.mean(0)
    aux = e * jnp.sum(f * pbar) * cfg.aux_loss_coef
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_coef
    return top_p, top_i, aux + z


def _expert_ffn(cfg: ModelConfig, p, xs):
    """xs: [G, E, C, d] -> [G, E, C, d]; batched matmul per weight stack."""
    h = jnp.einsum("gecd,edf->gecf", xs, p["wi"])
    g = jnp.einsum("gecd,edf->gecf", xs, p["wg"])
    h = jax.nn.silu(g) * h
    h = logical_constraint(h, ("batch", "experts", "moe_cap_tp", "expert_mlp"))
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    return logical_constraint(out, ("batch", "experts", "moe_cap_tp", None))


def _n_groups(t: int) -> int:
    """Dispatch groups = data shards (1 without a mesh).

    Hierarchical dispatch is THE collective-volume fix: with one global
    dispatch the token->slot gather forces an all-gather of every token to
    every device (measured ~680 GiB/device/step on deepseek train_4k).
    Per-data-shard dispatch makes operand and indices share a sharded batch
    dim, so the gather partitions shard-locally and the only cross-device
    traffic left is the combine all-reduce over the model axis.
    """
    from repro.dist.sharding import dispatch_groups
    g = dispatch_groups(t)
    while t % g:  # always satisfiable; t is a power-of-two multiple
        g //= 2
    return max(g, 1)


# ---------------------------------------------------------------------------
# gather-only permutation primitives
#
# XLA SPMD partitions batched GATHERS shard-locally but replicates batched
# SCATTERS (measured: the scatter-add combine all-reduced the full [G,T,d]
# activation across the mesh, ~680 GiB/device/step on deepseek train_4k).
# The slot<->sorted mapping is a (partial) bijection, so every direction —
# forward AND backward — can be written as a gather; custom_vjp pins the
# transpose to the mirror gather instead of letting autodiff emit scatters.
# Index arrays are pure arithmetic off the sort (no scatter anywhere):
#   inv_slot[g, e*cap + c] = seg_start[g, e] + c   (J if slot empty)
#   flat_slot[g, j]        = e_sorted*cap + pos_in_seg   (masked by keep)
# ---------------------------------------------------------------------------
import functools
import numpy as _np


def _f0(x):
    """float0 zero cotangent for integer/bool primal args."""
    return _np.zeros(x.shape, jax.dtypes.float0)


@jax.custom_vjp
def _sorted_to_slots(vs_pad, inv_slot, flat_slot, keep):
    """[G, J+1, D] sorted-space (zero-padded row J) -> [G, S, D] slots."""
    return jnp.take_along_axis(vs_pad, inv_slot[..., None], axis=1)


def _s2s_fwd(vs_pad, inv_slot, flat_slot, keep):
    return _sorted_to_slots(vs_pad, inv_slot, flat_slot, keep), (
        inv_slot, flat_slot, keep)


def _s2s_bwd(res, ct):
    inv_slot, flat_slot, keep = res
    d = jnp.take_along_axis(ct, flat_slot[..., None], axis=1)
    d = d * keep[..., None].astype(d.dtype)
    d_pad = jnp.pad(d, ((0, 0), (0, 1), (0, 0)))
    return d_pad, _f0(inv_slot), _f0(flat_slot), _f0(keep)


_sorted_to_slots.defvjp(_s2s_fwd, _s2s_bwd)


@jax.custom_vjp
def _slots_to_sorted(ys, inv_slot, flat_slot, keep):
    """[G, S, D] slots -> [G, J, D] sorted space (dropped rows zero)."""
    out = jnp.take_along_axis(ys, flat_slot[..., None], axis=1)
    return out * keep[..., None].astype(out.dtype)


def _sl2s_fwd(ys, inv_slot, flat_slot, keep):
    return _slots_to_sorted(ys, inv_slot, flat_slot, keep), (
        inv_slot, flat_slot, keep)


def _sl2s_bwd(res, ct):
    inv_slot, flat_slot, keep = res
    ct_pad = jnp.pad(ct * keep[..., None].astype(ct.dtype),
                     ((0, 0), (0, 1), (0, 0)))
    return (jnp.take_along_axis(ct_pad, inv_slot[..., None], axis=1),
            _f0(inv_slot), _f0(flat_slot), _f0(keep))


_slots_to_sorted.defvjp(_sl2s_fwd, _sl2s_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _tokens_to_sorted(k, xg, tok_sorted, inv_perm):
    """[G, T, D] tokens -> [G, J=T*k, D] sorted space."""
    return jnp.take_along_axis(xg, tok_sorted[..., None], axis=1)


def _t2s_fwd(k, xg, tok_sorted, inv_perm):
    return _tokens_to_sorted(k, xg, tok_sorted, inv_perm), (
        tok_sorted, inv_perm, xg.shape)


def _t2s_bwd(k, res, ct):
    tok_sorted, inv_perm, shape = res
    g, t, d = shape
    un = jnp.take_along_axis(ct, inv_perm[..., None], axis=1)
    return un.reshape(g, t, k, d).sum(2), _f0(tok_sorted), _f0(inv_perm)


_tokens_to_sorted.defvjp(_t2s_fwd, _t2s_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sorted_to_tokens(k, vs, tok_sorted, inv_perm):
    """[G, J, D] sorted space -> [G, T, D] tokens (sum over k slots)."""
    g, j, d = vs.shape
    un = jnp.take_along_axis(vs, inv_perm[..., None], axis=1)
    return un.reshape(g, j // k, k, d).sum(2)


def _s2t_fwd(k, vs, tok_sorted, inv_perm):
    return _sorted_to_tokens(k, vs, tok_sorted, inv_perm), (
        tok_sorted, inv_perm)


def _s2t_bwd(k, res, ct):
    tok_sorted, inv_perm = res
    return (jnp.take_along_axis(ct, tok_sorted[..., None], axis=1),
            _f0(tok_sorted), _f0(inv_perm))


_sorted_to_tokens.defvjp(_s2t_fwd, _s2t_bwd)


def _dispatch_sorted(cfg: ModelConfig, p, x2d):
    """Sort-by-expert dispatch with capacity (the paper-machinery path)."""
    t = x2d.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    g = _n_groups(t)
    tl = t // g                                       # tokens per group
    j = tl * k
    cap = int(cfg.capacity_factor * tl * k / e)
    cap = max(8, ((cap + 7) // 8) * 8)

    top_p, top_i, aux = _router(cfg, p, x2d)
    xg = x2d.reshape(g, tl, -1)
    xg = logical_constraint(xg, ("batch", None, None))
    eg = top_i.reshape(g, j)                          # expert ids per group
    pg = top_p.reshape(g, j)

    order = jnp.argsort(eg, axis=-1)                  # sort tokens by expert
    inv_perm = jnp.argsort(order, axis=-1)            # inverse permutation
    e_sorted = jnp.take_along_axis(eg, order, axis=-1)
    p_sorted = jnp.take_along_axis(pg, order, axis=-1)
    tok_sorted = order // k                           # token of sorted entry

    # --- the paper's operation: segment starts = lower_bound(e_sorted, e) --
    # ids are integers in [0, E); their "CDF" is the router's load profile.
    # jnp.searchsorted is the oracle; kernels/bounded_search provides the
    # tiled TPU kernel for the same contract (used in serving, where the
    # token count is large and the cache page table reuses it).
    seg_start = jax.vmap(
        lambda es: jnp.searchsorted(es, jnp.arange(e), side="left"))(e_sorted)
    seg_end = jax.vmap(
        lambda es: jnp.searchsorted(es, jnp.arange(e), side="right"))(e_sorted)
    pos_in_seg = jnp.arange(j)[None] - jnp.take_along_axis(
        seg_start, e_sorted, axis=-1)
    keep = pos_in_seg < cap
    flat_slot = jnp.where(
        keep, e_sorted * cap + jnp.minimum(pos_in_seg, cap - 1), 0)

    # slot -> sorted-position index, arithmetically (J marks empty slots)
    c_off = jnp.arange(cap)[None, None]               # [1, 1, C]
    islot = seg_start[:, :, None] + c_off             # [G, E, C]
    valid = islot < jnp.minimum(seg_end, seg_start + cap)[:, :, None]
    inv_slot = jnp.where(valid, islot, j).reshape(g, e * cap)

    # dispatch: tokens -> sorted -> slots (gathers only, fwd and bwd)
    xs_sorted = _tokens_to_sorted(k, xg, tok_sorted, inv_perm)
    xs_pad = jnp.pad(xs_sorted, ((0, 0), (0, 1), (0, 0)))
    xs = _sorted_to_slots(xs_pad, inv_slot, flat_slot, keep)
    xs = xs.reshape(g, e, cap, -1)
    xs = logical_constraint(xs, ("batch", "experts", "moe_cap_tp", None))

    ys = _expert_ffn(cfg, p, xs)

    # combine: slots -> sorted (weighted) -> tokens
    ys_sorted = _slots_to_sorted(ys.reshape(g, e * cap, -1),
                                 inv_slot, flat_slot, keep)
    ys_sorted = ys_sorted * p_sorted[..., None].astype(ys_sorted.dtype)
    out = _sorted_to_tokens(k, ys_sorted, tok_sorted, inv_perm)
    out = logical_constraint(out, ("batch", None, None))
    return out.reshape(t, -1), aux


def _dispatch_dense(cfg: ModelConfig, p, x2d):
    """Baseline: all experts compute all tokens; mask-combine (E/k waste)."""
    t = x2d.shape[0]
    e = cfg.n_experts
    g = _n_groups(t)
    top_p, top_i, aux = _router(cfg, p, x2d)
    xs = jnp.broadcast_to(
        x2d.reshape(g, 1, t // g, -1), (g, e, t // g, x2d.shape[-1]))
    xs = logical_constraint(xs, ("batch", "experts", None, None))
    ys = _expert_ffn(cfg, p, xs)                     # [G, E, T/G, d]
    ys = ys.transpose(1, 0, 2, 3).reshape(e, t, -1)  # [E, T, d]
    combine = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], top_i].set(top_p)    # [T, E]
    out = jnp.einsum("etd,te->td", ys, combine.astype(ys.dtype))
    return out, aux


def moe_ffn(cfg: ModelConfig, p, x) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    if cfg.moe_dispatch == "dense":
        out, aux = _dispatch_dense(cfg, p, x2d)
    else:
        out, aux = _dispatch_sorted(cfg, p, x2d)
    if cfg.n_shared_experts:
        h = jnp.einsum("td,df->tf", x2d, p["shared_wi"])
        g = jnp.einsum("td,df->tf", x2d, p["shared_wg"])
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(g) * h, p["shared_wo"])
    return out.reshape(b, s, d), aux
