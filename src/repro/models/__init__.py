"""Model zoo: the 10 assigned architectures (dense / ssm / hybrid / moe /
enc-dec / vlm families) as pure-JAX modules with logical-axis sharding."""
