"""Model assembly for all families: dense / moe / ssm / hybrid / vlm.

One Block abstraction covers every layer: a mixer (attention | SSD) plus an
FFN (dense | MoE | none).  Families differ only in how blocks are stacked:

  dense, vlm        scan over L identical (attn, dense) blocks
  moe (mixtral)     scan over L identical (attn, moe) blocks
  moe (deepseek)    layer 0 unrolled (attn, wide dense), scan over the rest
  ssm (mamba2)      scan over L (ssd, none) blocks
  hybrid (jamba)    scan over L/8 super-blocks; inside: [attn, ssd x7] with
                    MoE on odd sublayers (1:7 interleave, MoE every 2)

Scan-over-layers keeps HLO size O(1) in depth — the only workable compile
strategy at 64-72 layers x 512 devices (DESIGN.md §5).  Remat policy per
config: none | dots | full.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import mamba2 as S
from repro.models.config import ModelConfig
from repro.dist.sharding import logical_constraint


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------
def init_block(cfg: ModelConfig, key, mixer: str, ffn: str,
               d_ff: int = 0) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": L.init_norm(cfg, cfg.d_model)}
    if mixer == "attn":
        p["attn"] = L.init_attention(cfg, k1)
    else:
        p["ssm"] = S.init_mamba(cfg, k1)
    if ffn != "none":
        p["norm2"] = L.init_norm(cfg, cfg.d_model)
    if ffn == "dense":
        p["mlp"] = L.init_mlp(cfg, k2, d_ff or cfg.d_ff)
    elif ffn == "moe":
        p["moe"] = M.init_moe(cfg, k2)
    return p


def block_specs(cfg: ModelConfig, mixer: str, ffn: str) -> Dict[str, Any]:
    norm = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        norm = {"scale": ("embed",), "bias": ("embed",)}
    p: Dict[str, Any] = {"norm1": dict(norm)}
    if mixer == "attn":
        attn = {
            "wq": ("embed", "heads", "head_dim"),
            "wk": ("embed", "kv_heads", "head_dim"),
            "wv": ("embed", "kv_heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed"),
        }
        if cfg.qkv_bias:
            attn.update(bq=("heads", "head_dim"), bk=("kv_heads", "head_dim"),
                        bv=("kv_heads", "head_dim"))
        if cfg.qk_norm:
            attn.update(q_norm=(None,), k_norm=(None,))
        p["attn"] = attn
    else:
        p["ssm"] = S.mamba_specs(cfg)
    if ffn != "none":
        p["norm2"] = dict(norm)
    if ffn == "dense":
        mlp = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
        if cfg.act == "swiglu":
            mlp["wg"] = ("embed", "mlp")
        p["mlp"] = mlp
    elif ffn == "moe":
        p["moe"] = M.moe_specs(cfg)
    return p


def apply_block(cfg: ModelConfig, p, x, positions, aux, mixer: str, ffn: str,
                causal: bool = True):
    h = L.norm(cfg, x, p["norm1"])
    if mixer == "attn":
        h = L.attention(cfg, p["attn"], h, positions, causal=causal)
    else:
        h = S.mamba_layer(cfg, p["ssm"], h)
    x = x + h
    if ffn == "none":
        return x, aux
    h = L.norm(cfg, x, p["norm2"])
    if ffn == "dense":
        h = L.mlp(cfg, p["mlp"], h)
    else:
        h, a = M.moe_ffn(cfg, p["moe"], h)
        aux = aux + a
    return x + h, aux


def apply_block_decode(cfg: ModelConfig, p, x, positions, cache, mixer: str,
                       ffn: str):
    """cache: dict with the block's decode state; returns updated copy."""
    h = L.norm(cfg, x, p["norm1"])
    new_cache = dict(cache)
    if mixer == "attn":
        h, ck, cv = L.attention_kv(cfg, p["attn"], h, positions,
                                   cache["k"], cache["v"], cache["len"])
        new_cache.update(k=ck, v=cv)
    else:
        h, st, cs = S.mamba_decode(cfg, p["ssm"], h, cache["ssm"],
                                   cache["conv"])
        new_cache.update(ssm=st, conv=cs)
    x = x + h
    if ffn != "none":
        h = L.norm(cfg, x, p["norm2"])
        if ffn == "dense":
            h = L.mlp(cfg, p["mlp"], h)
        else:
            h, _ = M.moe_ffn(cfg, p["moe"], h)
        x = x + h
    return x, new_cache


# ---------------------------------------------------------------------------
# stack plans: how each family composes blocks
# ---------------------------------------------------------------------------
def stack_plan(cfg: ModelConfig):
    """Returns (prologue, scan_unit, n_scan):
    prologue: list of (mixer, ffn, d_ff) unrolled before the scan;
    scan_unit: list of (mixer, ffn, d_ff) repeated n_scan times via lax.scan.
    """
    if cfg.family == "ssm":
        return [], [("ssm", "none", 0)], cfg.n_layers
    if cfg.hybrid_period:
        unit = []
        for j in range(cfg.hybrid_period):
            mixer = "attn" if j == 0 else "ssm"
            ffn = "moe" if cfg.is_moe_layer(j) else "dense"
            unit.append((mixer, ffn, 0))
        assert cfg.n_layers % cfg.hybrid_period == 0
        return [], unit, cfg.n_layers // cfg.hybrid_period
    if cfg.n_experts and cfg.dense_first_layer:
        return ([("attn", "dense", cfg.dense_first_d_ff)],
                [("attn", "moe", 0)], cfg.n_layers - 1)
    if cfg.n_experts:
        return [], [("attn", "moe", 0)], cfg.n_layers
    return [], [("attn", "dense", 0)], cfg.n_layers


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


# ---------------------------------------------------------------------------
# init / specs for the whole decoder stack
# ---------------------------------------------------------------------------
def init_decoder(cfg: ModelConfig, key) -> Dict[str, Any]:
    pro, unit, n_scan = stack_plan(cfg)
    params: Dict[str, Any] = {"embed": L.init_embed(cfg, jax.random.fold_in(key, 0))}
    for i, (mixer, ffn, dff) in enumerate(pro):
        params[f"pro{i}"] = init_block(cfg, jax.random.fold_in(key, 100 + i),
                                       mixer, ffn, dff)

    def init_unit(k):
        ks = jax.random.split(k, len(unit))
        return {f"sub{j}": init_block(cfg, ks[j], m, f, dff)
                for j, (m, f, dff) in enumerate(unit)}

    keys = jax.random.split(jax.random.fold_in(key, 1), n_scan)
    params["blocks"] = jax.vmap(init_unit)(keys)
    params["final_norm"] = L.init_norm(cfg, cfg.d_model)
    return params


def decoder_specs(cfg: ModelConfig) -> Dict[str, Any]:
    pro, unit, _ = stack_plan(cfg)
    emb = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb["head"] = ("embed", "vocab")
    specs: Dict[str, Any] = {"embed": emb}
    for i, (mixer, ffn, _) in enumerate(pro):
        specs[f"pro{i}"] = block_specs(cfg, mixer, ffn)

    def add_layer_dim(tree):
        return jax.tree.map(
            lambda names: ("layers",) + names, tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    specs["blocks"] = {
        f"sub{j}": add_layer_dim(block_specs(cfg, m, f))
        for j, (m, f, _) in enumerate(unit)}
    norm = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        norm["bias"] = ("embed",)
    specs["final_norm"] = norm
    return specs


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def decoder_forward(cfg: ModelConfig, params, tokens, causal: bool = True):
    """tokens [B, S] -> (logits [B, S, V], aux loss scalar)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = L.embed(cfg, params["embed"], tokens)
    x = logical_constraint(x, ("batch", "seq", None))
    aux = jnp.zeros((), jnp.float32)

    pro, unit, n_scan = stack_plan(cfg)
    for i, (mixer, ffn, _) in enumerate(pro):
        x, aux = apply_block(cfg, params[f"pro{i}"], x, positions, aux,
                             mixer, ffn, causal)

    def unit_body(carry, unit_params):
        x, aux = carry
        for j, (mixer, ffn, _) in enumerate(unit):
            x, aux = apply_block(cfg, unit_params[f"sub{j}"], x, positions,
                                 aux, mixer, ffn, causal)
        return (x, aux), None

    body = _remat(cfg, unit_body)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
    else:
        for i in range(n_scan):
            unit_params = jax.tree.map(lambda a: a[i], params["blocks"])
            (x, aux), _ = body((x, aux), unit_params)

    x = L.norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    logits = logical_constraint(logits, ("batch", "seq", "vocab"))
    return logits, aux


# ---------------------------------------------------------------------------
# decode (one token, full cache)
# ---------------------------------------------------------------------------
def init_cache_shapes(cfg: ModelConfig, batch: int, s_max: int):
    """ShapeDtypeStructs for the decode cache (used by dryrun/serving)."""
    pro, unit, n_scan = stack_plan(cfg)
    dt = jnp.dtype(cfg.dtype)
    kv = (batch, s_max, cfg.n_kv_heads, cfg.hd)
    ssm = (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim)
    conv = (batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state)

    def unit_cache(stack: int):
        out = {}
        for j, (mixer, _, _) in enumerate(unit):
            if mixer == "attn":
                out[f"sub{j}"] = {
                    "k": jax.ShapeDtypeStruct((stack,) + kv, dt),
                    "v": jax.ShapeDtypeStruct((stack,) + kv, dt),
                }
            else:
                out[f"sub{j}"] = {
                    "ssm": jax.ShapeDtypeStruct((stack,) + ssm, jnp.float32),
                    "conv": jax.ShapeDtypeStruct((stack,) + conv, dt),
                }
        return out

    cache = {"blocks": unit_cache(n_scan),
             "len": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    for i, (mixer, _, _) in enumerate(pro):
        cache[f"pro{i}"] = (
            {"k": jax.ShapeDtypeStruct(kv, dt), "v": jax.ShapeDtypeStruct(kv, dt)}
            if mixer == "attn" else
            {"ssm": jax.ShapeDtypeStruct(ssm, jnp.float32),
             "conv": jax.ShapeDtypeStruct(conv, dt)})
    return cache


def cache_specs(cfg: ModelConfig):
    """Logical-axis names for the decode cache (kv_seq gives SP decode)."""
    pro, unit, _ = stack_plan(cfg)
    kv = ("batch", "kv_seq", "kv_heads", None)
    ssm = ("batch", "heads", None, None)
    conv = ("batch", None, "ssm_inner")

    def unit_spec(prefix):
        out = {}
        for j, (mixer, _, _) in enumerate(unit):
            if mixer == "attn":
                out[f"sub{j}"] = {"k": prefix + kv, "v": prefix + kv}
            else:
                out[f"sub{j}"] = {"ssm": prefix + ssm, "conv": prefix + conv}
        return out

    cache = {"blocks": unit_spec(("layers",)), "len": (None,)}
    for i, (mixer, _, _) in enumerate(pro):
        cache[f"pro{i}"] = ({"k": kv, "v": kv} if mixer == "attn"
                            else {"ssm": ssm, "conv": conv})
    return cache


def decoder_decode(cfg: ModelConfig, params, cache, tokens):
    """One decode step.  tokens [B, 1]; returns (logits [B, V], new cache)."""
    b = tokens.shape[0]
    positions = cache["len"][:, None]
    x = L.embed(cfg, params["embed"], tokens)
    pro, unit, n_scan = stack_plan(cfg)
    new_cache = dict(cache)

    for i, (mixer, ffn, _) in enumerate(pro):
        c = dict(cache[f"pro{i}"])
        c["len"] = cache["len"]
        x, c = apply_block_decode(cfg, params[f"pro{i}"], x, positions, c,
                                  mixer, ffn)
        c.pop("len")
        new_cache[f"pro{i}"] = c

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_unit_cache = {}
        for j, (mixer, ffn, _) in enumerate(unit):
            c = dict(unit_cache[f"sub{j}"])
            c["len"] = cache["len"]
            x, c = apply_block_decode(cfg, unit_params[f"sub{j}"], x,
                                      positions, c, mixer, ffn)
            c.pop("len")
            new_unit_cache[f"sub{j}"] = c
        return x, new_unit_cache

    x, new_blocks = jax.lax.scan(unit_body, x,
                                 (params["blocks"], cache["blocks"]))
    new_cache["blocks"] = new_blocks
    new_cache["len"] = cache["len"] + 1

    x = L.norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)[:, 0]
    return logits, new_cache
