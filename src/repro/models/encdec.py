"""Encoder-decoder (whisper-tiny backbone).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs()`` supplies precomputed frame embeddings
[B, S_enc, d_model].  The backbone is faithful: bidirectional encoder with
learned positions, causal decoder with cross-attention, layernorm + gelu,
MHA (n_kv == n_heads), no RoPE.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.dist.sharding import logical_constraint


def _init_xattn(cfg: ModelConfig, key):
    return L.init_attention(cfg, key)


def init_encdec(cfg: ModelConfig, key) -> Dict[str, Any]:
    p: Dict[str, Any] = {
        "embed": L.init_embed(cfg, jax.random.fold_in(key, 0)),
        "enc_pos": (jax.random.normal(jax.random.fold_in(key, 1),
                                      (cfg.encoder_seq, cfg.d_model)) * 0.02
                    ).astype(jnp.dtype(cfg.dtype)),
        "dec_pos": (jax.random.normal(jax.random.fold_in(key, 2),
                                      (32768, cfg.d_model)) * 0.02
                    ).astype(jnp.dtype(cfg.dtype)),
    }

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": L.init_norm(cfg, cfg.d_model),
            "attn": L.init_attention(cfg, k1),
            "norm2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(cfg, k2),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": L.init_norm(cfg, cfg.d_model),
            "attn": L.init_attention(cfg, k1),
            "norm_x": L.init_norm(cfg, cfg.d_model),
            "xattn": _init_xattn(cfg, k2),
            "norm2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(cfg, k3),
        }

    p["enc"] = jax.vmap(enc_layer)(
        jax.random.split(jax.random.fold_in(key, 3), cfg.encoder_layers))
    p["dec"] = jax.vmap(dec_layer)(
        jax.random.split(jax.random.fold_in(key, 4), cfg.n_layers))
    p["enc_norm"] = L.init_norm(cfg, cfg.d_model)
    p["final_norm"] = L.init_norm(cfg, cfg.d_model)
    return p


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    norm = {"scale": ("embed",), "bias": ("embed",)} if cfg.norm == "layernorm" \
        else {"scale": ("embed",)}
    attn = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        attn = dict(attn, bq=("heads", "head_dim"), bk=("kv_heads", "head_dim"),
                    bv=("kv_heads", "head_dim"))
    mlp = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if cfg.act == "swiglu":
        mlp["wg"] = ("embed", "mlp")

    def ld(tree):  # add scan "layers" dim
        return jax.tree.map(lambda n: ("layers",) + n, tree,
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                isinstance(e, (str, type(None))) for e in x))

    emb = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb["head"] = ("embed", "vocab")
    return {
        "embed": emb,
        "enc_pos": (None, "embed"),
        "dec_pos": (None, "embed"),
        "enc": ld({"norm1": norm, "attn": attn, "norm2": norm, "mlp": mlp}),
        "dec": ld({"norm1": norm, "attn": attn, "norm_x": norm, "xattn": attn,
                   "norm2": norm, "mlp": mlp}),
        "enc_norm": dict(norm),
        "final_norm": dict(norm),
    }


def encode(cfg: ModelConfig, params, frames):
    """frames: [B, S_enc, d] stub embeddings -> encoder states."""
    x = frames + params["enc_pos"][None, : frames.shape[1]]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2])

    def body(x, lp):
        h = L.norm(cfg, x, lp["norm1"])
        h = L.attention(cfg, lp["attn"], h, positions, causal=False)
        x = x + h
        h = L.norm(cfg, x, lp["norm2"])
        return x + L.mlp(cfg, lp["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.norm(cfg, x, params["enc_norm"])


def encdec_forward(cfg: ModelConfig, params, frames, tokens):
    """Training/prefill: (frames [B,Se,d], tokens [B,Sd]) -> (logits, aux)."""
    enc_out = encode(cfg, params, frames)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["dec_pos"][None, :s]
    x = logical_constraint(x, ("batch", "seq", None))

    def body(x, lp):
        h = L.norm(cfg, x, lp["norm1"])
        h = L.attention(cfg, lp["attn"], h, positions, causal=True)
        x = x + h
        h = L.norm(cfg, x, lp["norm_x"])
        x = x + L.cross_attention(cfg, lp["xattn"], h, enc_out)
        h = L.norm(cfg, x, lp["norm2"])
        return x + L.mlp(cfg, lp["mlp"], h), None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = L.norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    return logits, jnp.zeros((), jnp.float32)


def encdec_cache_shapes(cfg: ModelConfig, batch: int, s_max: int):
    dt = jnp.dtype(cfg.dtype)
    kv = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jax.ShapeDtypeStruct(kv, dt),
        "v": jax.ShapeDtypeStruct(kv, dt),
        "enc_out": jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), dt),
        "len": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def encdec_cache_specs(cfg: ModelConfig):
    kv = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {"k": kv, "v": kv, "enc_out": ("batch", None, None), "len": (None,)}


def encdec_decode(cfg: ModelConfig, params, cache, tokens):
    """One decode step with cached encoder states + decoder KV cache."""
    b = tokens.shape[0]
    positions = cache["len"][:, None]
    x = L.embed(cfg, params["embed"], tokens)
    pos_emb = jnp.take(params["dec_pos"], jnp.clip(cache["len"], 0, 32767),
                       axis=0)
    x = x + pos_emb[:, None]
    enc_out = cache["enc_out"]

    def body(x, scanned):
        lp, ck, cv = scanned
        h = L.norm(cfg, x, lp["norm1"])
        h, ck, cv = L.attention_kv(cfg, lp["attn"], h, positions, ck, cv,
                                   cache["len"])
        x = x + h
        h = L.norm(cfg, x, lp["norm_x"])
        x = x + L.cross_attention(cfg, lp["xattn"], h, enc_out)
        h = L.norm(cfg, x, lp["norm2"])
        x = x + L.mlp(cfg, lp["mlp"], h)
        return x, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(body, x,
                                     (params["dec"], cache["k"], cache["v"]))
    x = L.norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)[:, 0]
    new_cache = dict(cache, k=new_k, v=new_v, len=cache["len"] + 1)
    return logits, new_cache
