"""Shared model layers: norms, RoPE, GQA attention, MLPs.

All layers are pure functions over param pytrees; sharding is expressed via
logical-axis annotations attached at init time (dist/sharding.py) plus
with_sharding_constraint on the few activation points that matter.

Attention is flash-style pure JAX: online-softmax over KV chunks inside a
lax.scan over Q chunks — O(S * chunk) live memory instead of O(S^2), which
is what lets the 32k prefill cells compile inside a v5e HBM budget without
a hand-written attention kernel (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.dist.sharding import logical_constraint

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias=None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm(cfg: ModelConfig, x, p):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p.get("bias"))


def init_norm(cfg: ModelConfig, d: int):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, key) -> dict:
    d, hd = cfg.d_model, cfg.hd
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": (jax.random.normal(k1, (d, nh, hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, nkv, hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, nkv, hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (nh, hd, d)) * s).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh, hd), dt)
        p["bk"] = jnp.zeros((nkv, hd), dt)
        p["bv"] = jnp.zeros((nkv, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(cfg: ModelConfig, p, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _pick_chunk(s_len: int, target: int) -> int:
    """Largest divisor of s_len <= target (>= 128); whole-seq if none —
    non-power-of-two sequences (whisper's 1500 frames) fall back cleanly."""
    if s_len <= target:
        return s_len
    for d in range(target, 127, -1):
        if s_len % d == 0:
            return d
    return s_len


def _flash_body(q, k, v, q_pos, k_pos, causal: bool, window: int, scale):
    """One (q-block, kv-chunk) online-softmax step.

    q: [B, Qb, H, D]; k/v: [B, Kb, G, D] (GQA groups broadcast).
    Returns unnormalized accumulators (m, l, acc).
    """
    b, qb, h, d = q.shape
    g = k.shape[2]
    rep = h // g
    qg = q.reshape(b, qb, g, rep, d)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.ones((qb, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                          # [b,g,r,q]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bgrqk,bkgd->bgrqd", p, v.astype(jnp.float32))
    return m, l, acc


def attention(cfg: ModelConfig, p, x, positions, causal: bool = True):
    """Flash-style attention: online softmax over a lax.scan of KV chunks,
    with the FULL query axis vectorized.

    Q stays a real (shardable) tensor dim, so sequence parallelism shards
    the quadratic work across the mesh; only KV is scanned.  (Scanning Q
    too — the first implementation — sliced a sharded dim, which SPMD can
    only handle by replicating: measured 16x HLO-FLOP inflation on the
    seq-parallel prefill cells.)  K/V are constrained seq-UNSHARDED here:
    the one all-gather per layer this induces is the standard SP cost and
    is what the roofline collective term charges.
    """
    b, s_len, _ = x.shape
    q, k, v = _qkv(cfg, p, x, positions)
    q = logical_constraint(q, ("batch", "seq", "heads", None))
    k = logical_constraint(k, ("batch", None, "kv_heads", None))
    v = logical_constraint(v, ("batch", None, "kv_heads", None))

    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g, rep = nkv, nh // nkv
    scale = hd ** -0.5
    ck = _pick_chunk(s_len, cfg.attn_chunk)
    n_chunks = s_len // ck

    kc = k.reshape(b, n_chunks, ck, nkv, hd).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, ck, nkv, hd).swapaxes(0, 1)
    q_pos = jnp.arange(s_len)

    def kv_chunk(acc, ki):
        kb, vb, k_idx = ki
        m_p, l_p, a_p = acc
        k_pos = k_idx * ck + jnp.arange(ck)
        m_n, l_n, a_n = _flash_body(q, kb, vb, q_pos, k_pos, causal,
                                    cfg.attn_window, scale)
        m = jnp.maximum(m_p, m_n)
        c_p = jnp.exp(m_p - m)
        c_n = jnp.exp(m_n - m)
        l = l_p * c_p + l_n * c_n
        a = a_p * c_p[..., None] + a_n * c_n[..., None]
        return (m, l, a), None

    init = (
        jnp.full((b, g, rep, s_len), NEG_INF, jnp.float32),
        jnp.zeros((b, g, rep, s_len), jnp.float32),
        jnp.zeros((b, g, rep, s_len, hd), jnp.float32),
    )
    (m, l, a), _ = jax.lax.scan(kv_chunk, init,
                                (kc, vc, jnp.arange(n_chunks)))
    out = a / jnp.maximum(l, 1e-30)[..., None]              # [b,g,r,s,hd]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s_len, nh, hd)
    out = out.astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return logical_constraint(y, ("batch", "seq", None))


def attention_kv(cfg: ModelConfig, p, x, positions, cache_k, cache_v,
                 cache_len):
    """Decode step: one new token per sequence attending to the cache.

    x: [B, 1, d]; cache_k/v: [B, S_max, n_kv, hd]; cache_len: fill per seq.
    The new K/V is scattered into the cache in place (the caller donates the
    buffers), then attention runs over the whole cache with a length mask —
    no cache copy, O(S_max) bytes touched.
    """
    b = x.shape[0]
    q, k, v = _qkv(cfg, p, x, positions)
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g, rep = nkv, nh // nkv
    s_max = cache_k.shape[1]

    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, cache_len].set(k[:, 0])
    cache_v = cache_v.at[bidx, cache_len].set(v[:, 0])
    cache_k = logical_constraint(cache_k, ("batch", "kv_seq", "kv_heads", None))
    cache_v = logical_constraint(cache_v, ("batch", "kv_seq", "kv_heads", None))

    qg = q.reshape(b, 1, g, rep, hd)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) * (hd ** -0.5)
    k_pos = jnp.arange(s_max)
    valid = k_pos[None] <= cache_len[:, None]
    if cfg.attn_window:
        valid &= (positions[:, -1:] - k_pos[None]) < cfg.attn_window
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bgrqd", w, cache_v.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, nh, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, cache_k, cache_v


def cross_attention(cfg: ModelConfig, p, x, enc_out):
    """Encoder-decoder cross attention (whisper), q-chunked: the encoder
    context is short (1500 frames) but the decoder can be 32k, so scores
    are materialized one q-block at a time."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g, rep = nkv, nh // nkv
    b, sq = x.shape[0], x.shape[1]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_block(qb):                                # [b, ck, nh, hd]
        qg = qb.reshape(b, qb.shape[1], g, rep, hd)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                       kf) * (hd ** -0.5)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bgrqk,bkgd->bgrqd", w, vf)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, qb.shape[1], nh, hd)

    ck = _pick_chunk(sq, 512)
    if ck == sq:
        out = one_block(q)
    else:
        qc = q.reshape(b, sq // ck, ck, nh, hd).swapaxes(0, 1)
        out = jax.lax.map(one_block, qc).swapaxes(0, 1).reshape(b, sq, nh, hd)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    if cfg.act == "swiglu":
        return {
            "wi": (jax.random.normal(ks[0], (d, f)) * s_in).astype(dt),
            "wg": (jax.random.normal(ks[1], (d, f)) * s_in).astype(dt),
            "wo": (jax.random.normal(ks[2], (f, d)) * s_out).astype(dt),
        }
    return {
        "wi": (jax.random.normal(ks[0], (d, f)) * s_in).astype(dt),
        "wo": (jax.random.normal(ks[2], (f, d)) * s_out).astype(dt),
    }


def mlp(cfg: ModelConfig, p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = logical_constraint(h, ("batch", "seq", "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def init_embed(cfg: ModelConfig, key):
    dt = jnp.dtype(cfg.dtype)
    p = {
        "tok": (jax.random.normal(key, (cfg.vocab_padded, cfg.d_model))
                * cfg.d_model ** -0.5).astype(dt)
    }
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_padded))
            * cfg.d_model ** -0.5).astype(dt)
    return p


def embed(cfg: ModelConfig, p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["tok"])
    return jnp.einsum("bsd,dv->bsv", x, p["head"])
