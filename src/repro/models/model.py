"""Unified model API: one entry point per (arch, step kind).

  init_params(cfg, rng)               params pytree
  param_specs(cfg)                    matching logical-axis names pytree
  forward(cfg, params, batch)         logits + aux (train / prefill)
  loss_fn(cfg, params, batch)         scalar loss (train)
  decode_step(cfg, params, cache, t)  one-token serve step
  cache_shapes / cache_specs          decode-state shapes + sharding names
  input_specs(cfg, shape)             ShapeDtypeStructs for every input
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec as E
from repro.models import transformer as T
from repro.models.config import ModelConfig


def init_params(cfg: ModelConfig, rng) -> Dict[str, Any]:
    if cfg.family == "encdec":
        return E.init_encdec(cfg, rng)
    return T.init_decoder(cfg, rng)


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family == "encdec":
        return E.encdec_specs(cfg)
    return T.decoder_specs(cfg)


def forward(cfg: ModelConfig, params, batch):
    if cfg.family == "encdec":
        return E.encdec_forward(cfg, params, batch["frames"], batch["tokens"])
    return T.decoder_forward(cfg, params, batch["tokens"])


def loss_fn(cfg: ModelConfig, params, batch):
    """Next-token cross entropy (+ MoE aux) with f32 logits math."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux


def decode_step(cfg: ModelConfig, params, cache, tokens):
    if cfg.family == "encdec":
        return E.encdec_decode(cfg, params, cache, tokens)
    return T.decoder_decode(cfg, params, cache, tokens)


def cache_shapes(cfg: ModelConfig, batch: int, s_max: int):
    if cfg.family == "encdec":
        return E.encdec_cache_shapes(cfg, batch, s_max)
    return T.init_cache_shapes(cfg, batch, s_max)


def cache_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return E.encdec_cache_specs(cfg)
    return T.cache_specs(cfg)


def input_specs(cfg: ModelConfig, seq_len: int, batch: int,
                kind: str = "train") -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    if kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        }
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    raise ValueError(kind)


def input_spec_names(cfg: ModelConfig, kind: str = "train"):
    names = {"tokens": ("batch", "seq") if kind != "decode" else ("batch", None)}
    if kind == "train":
        names["labels"] = ("batch", "seq")
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        names["frames"] = ("batch", None, None)
    return names
