"""Mamba-2 SSD layer (state-space duality, arXiv:2405.21060), TPU-adapted.

The chunked SSD algorithm is already the matmul formulation the MXU wants:
within a chunk, the output is a masked [Q, Q] "attention" matmul; across
chunks, a small recurrence over per-chunk states [H, P, N].  We implement
exactly that: einsums for the intra-chunk quadratic part and chunk-state
computation, one lax.scan over S/Q chunk states for the recurrence.

Decode is the SSD recurrence specialized to one step: h <- da*h + dt*B x,
y = C.h — constant state per layer ([B, H, P, N]), no KV growth, which is
why mamba2/jamba run the long_500k cell (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.dist.sharding import logical_constraint


def init_mamba(cfg: ModelConfig, key) -> dict:
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt_ = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        # input projection -> [x (di), z gate (di), B (ns), C (ns), dt (nh)]
        "w_in": (jax.random.normal(ks[0], (d, 2 * di + 2 * ns + nh)) * s).astype(dt_),
        "w_out": (jax.random.normal(ks[1], (di, d)) * di ** -0.5).astype(dt_),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, di + 2 * ns)) * 0.1
                   ).astype(dt_),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
    }


def mamba_specs(cfg: ModelConfig) -> dict:
    return {
        "w_in": ("embed", "ssm_inner"),
        "w_out": ("ssm_inner", "embed"),
        "conv_w": ("conv", "ssm_inner"),
        "A_log": ("state",),
        "D": ("state",),
        "dt_bias": ("state",),
        "norm_scale": ("ssm_inner",),
    }


def _split_proj(cfg: ModelConfig, proj):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    x = proj[..., :di]
    z = proj[..., di:2 * di]
    Bm = proj[..., 2 * di:2 * di + ns]
    Cm = proj[..., 2 * di + ns:2 * di + 2 * ns]
    dt = proj[..., 2 * di + 2 * ns:]
    return x, z, Bm, Cm, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv (kernel K) via shifted adds; shard-friendly.

    x: [B, S, F]; w: [K, F].  state (decode): [B, K-1, F] trailing inputs.
    """
    k = w.shape[0]
    if state is None:
        out = x * w[-1]
        for i in range(1, k):
            shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
            out = out + shifted * w[-1 - i]
        return out
    hist = jnp.concatenate([state, x], axis=1)       # [B, K, F]
    out = jnp.einsum("bkf,kf->bf", hist, w)[:, None]
    return out, hist[:, 1:]


def ssd_chunked(cfg: ModelConfig, xh, Bm, Cm, dt, A_log, D):
    """Chunked SSD scan.

    xh: [B, S, H, P]; Bm/Cm: [B, S, N]; dt: [B, S, H] (softplus'd).
    Returns y: [B, S, H, P].
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, f"seq {s} % ssm_chunk {q} != 0"
    c = s // q

    # f32 throughout (explicit: callers/tests may run under jax x64)
    A_log = A_log.astype(jnp.float32)
    D = D.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    a = -jnp.exp(A_log)                              # [H], negative decay
    dta = (dt * a[None, None, :]).reshape(b, c, q, h)
    xc = xh.reshape(b, c, q, h, p).astype(jnp.float32)
    Bc = Bm.reshape(b, c, q, n).astype(jnp.float32)
    Cc = Cm.reshape(b, c, q, n).astype(jnp.float32)
    dtc = dt.reshape(b, c, q, h).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(hprev, inp):
        """One chunk: intra-chunk quadratic part + inter-chunk state carry.
        Everything [B, Q, ...]-shaped — the [Q, Q, H] decay gate only ever
        exists for a single chunk (live memory O(S*Q), not O(S^2))."""
        xq, Bq, Cq, dtq, daq = inp
        seg = jnp.cumsum(daq, axis=1)                         # [B,Q,H]
        decay = seg[:, :, None, :] - seg[:, None, :, :]       # [B,Q,Q,H]
        # mask BEFORE exp: the upper triangle has decay > 0, exp overflows
        # to inf, and inf * 0 in the VJP of where() poisons the gradient
        gate = jnp.exp(jnp.where(causal[None, :, :, None], decay, -1e30))
        cb = jnp.einsum("bin,bjn->bij", Cq, Bq)
        y = jnp.einsum("bij,bijh,bjh,bjhp->bihp", cb, gate, dtq, xq)
        # inter-chunk contribution from the carried state
        in_gate = jnp.exp(seg)                                # [B,Q,H]
        y = y + jnp.einsum("bqn,bhnp,bqh->bqhp", Cq, hprev, in_gate)
        # new chunk state
        last = seg[:, -1:, :]                                 # [B,1,H]
        sgate = jnp.exp(last - seg)                           # [B,Q,H]
        states = jnp.einsum("bqh,bqh,bqn,bqhp->bhnp", sgate, dtq, Bq, xq)
        hnew = hprev * jnp.exp(last[:, 0])[:, :, None, None] + states
        return hnew, y

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(
        chunk_step, h0,
        (xc.swapaxes(0, 1), Bc.swapaxes(0, 1), Cc.swapaxes(0, 1),
         dtc.swapaxes(0, 1), dta.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    y = y + xh.astype(jnp.float32) * D[None, None, :, None]
    return y


def mamba_layer(cfg: ModelConfig, p, x):
    """x: [B, S, d] -> [B, S, d] (training / prefill path)."""
    b, s, _ = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    proj = logical_constraint(proj, ("batch", "seq", "ssm_inner"))
    xi, z, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"]))
    xi, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + ns],
                  conv_out[..., di + ns:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xi.reshape(b, s, nh, hd)
    y = ssd_chunked(cfg, xh, Bm, Cm, dt, p["A_log"], p["D"])
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    from repro.models.layers import rmsnorm
    y = rmsnorm(y, p["norm_scale"])
    return jnp.einsum("bse,ed->bsd", y, p["w_out"])


def mamba_decode(cfg: ModelConfig, p, x, ssm_state, conv_state):
    """One decode step.  x: [B, 1, d]; ssm_state: [B, H, N, P];
    conv_state: [B, K-1, di+2ns].  Returns (y, ssm_state, conv_state)."""
    b = x.shape[0]
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi, z, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)          # [B,1,F]
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xi, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + ns],
                  conv_out[..., di + ns:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a[None])                                # [B,H]
    xh = xi.reshape(b, nh, hd).astype(jnp.float32)
    Bf = Bm[:, 0].astype(jnp.float32)                         # [B,N]
    Cf = Cm[:, 0].astype(jnp.float32)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, Bf, xh)
    ssm_state = ssm_state * da[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cf, ssm_state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype) * jax.nn.silu(z)
    from repro.models.layers import rmsnorm
    y = rmsnorm(y, p["norm_scale"])
    return jnp.einsum("bse,ed->bsd", y, p["w_out"]), ssm_state, conv_state
