"""Architecture configuration (one instance per assigned arch)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | ssm | hybrid | moe | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    # attention
    rope: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False        # chameleon
    attn_window: int = 0         # sliding-window size; 0 = full causal
    attn_chunk: int = 1024       # flash-style KV chunk (pure-JAX online softmax)
    # block
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE FFN on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0
    moe_d_ff: int = 0            # per-expert hidden dim (fine-grained MoE)
    capacity_factor: float = 1.25
    dense_first_layer: bool = False   # deepseek-moe: layer 0 is a dense FFN
    dense_first_d_ff: int = 0
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2
    moe_dispatch: str = "sorted"      # sorted | dense  (§Perf baseline = dense)
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hybrid (jamba): within each block of `hybrid_period` layers, layer 0 is
    # attention, the rest are SSM; MoE on every `moe_every`-th layer.
    hybrid_period: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0         # stub frontend: precomputed frame embeddings
    # vlm (chameleon): early fusion — VQ image tokens share the vocab; the
    # tokenizer stub means input_specs() is token ids, nothing else changes.
    # numerics / compile strategy
    dtype: str = "bfloat16"
    remat: str = "dots"          # none | dots | full
    scan_layers: bool = True
    parallelism: str = "auto"    # auto | fsdp | tp  (dist/sharding.select_rules)
    # notes for DESIGN/EXPERIMENTS
    source: str = ""
    notes: Tuple[str, ...] = ()

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Pad vocab to a multiple of 256 so the logits dim shards over any
        mesh axis (production-standard embedding padding)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def moe_hidden(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        if self.dense_first_layer and i == 0:
            return False
        return (i % self.moe_every) == self.moe_offset

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.hybrid_period:
            return (i % self.hybrid_period) == 0
        return True

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), used for the
        MODEL_FLOPS = 6*N*D roofline term."""
        hd = self.hd
        d = self.d_model
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.n_layers):
            if self.is_attn_layer(i):
                qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                total += qkv + (self.n_heads * hd) * d
            else:  # ssm layer
                di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * ns + nh) + di * d + 3 * di
            if self.is_moe_layer(i):
                h = self.moe_hidden
                total += self.n_experts * (3 * d * h) + d * self.n_experts
                total += self.n_shared_experts * 3 * d * h
            elif not self.is_attn_layer(i) and self.family == "hybrid":
                total += 3 * d * self.d_ff
            else:
                ff = (self.dense_first_d_ff
                      if (self.dense_first_layer and i == 0 and self.dense_first_d_ff)
                      else self.d_ff)
                n_mats = 3 if self.act == "swiglu" else 2
                total += n_mats * d * ff
        if self.encoder_layers:
            qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
            enc = self.encoder_layers * (2 * qkv + 2 * (self.n_heads * hd) * d
                                         + 2 * d * self.d_ff)
            total += enc
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        h = self.moe_hidden
        d = self.d_model
        n_moe = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        inactive = n_moe * (self.n_experts - self.top_k) * 3 * d * h
        return total - inactive
