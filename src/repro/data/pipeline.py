"""Deterministic sharded token pipeline for the train driver.

Synthetic-corpus pipeline with the production-shaped surface: seeded
shuffling, per-host sharding, packed fixed-length rows, resumable cursor
(step -> sample ids are pure functions of (seed, step), so checkpoint
restore resumes the stream exactly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.data.packing import PackedIndex


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_docs: int = 4096
    mean_doc_len: int = 512
    host_id: int = 0
    n_hosts: int = 1


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.doc_lens = np.maximum(
            rng.geometric(1.0 / cfg.mean_doc_len, cfg.n_docs), 8)
        self.packed = PackedIndex(self.doc_lens)
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def _tokens_at(self, offsets: np.ndarray) -> np.ndarray:
        """Content-addressed synthetic tokens: doc-seeded hash stream."""
        doc, within = self.packed.locate_oracle(offsets % self.packed.total)
        h = (doc.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
             + within.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9))
        return ((h >> np.uint64(33)) % np.uint64(self.cfg.vocab - 2) + 2
                ).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host) — resumable by construction."""
        c = self.cfg
        base = (step * c.global_batch + self.cfg.host_id * self.local_batch)
        rows = np.arange(self.local_batch) + base
        offsets = (rows[:, None] * c.seq_len
                   + np.arange(c.seq_len + 1)[None, :])
        toks = self._tokens_at(offsets.reshape(-1)).reshape(
            self.local_batch, c.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
