"""Surrogate generators for the paper's four real-world datasets (§4.1.2).

The container is offline, so we synthesize key sets that reproduce the
documented CDF *shape* of each dataset (Figure 6 and the text):

  amzn  book popularity counts — smooth heavy-tailed CDF, locally near-linear
  face  user IDs ~ uniform over (0, 2^50) plus ~100 outliers in (2^59, 2^64)
        (the outliers that break RBS's prefix bits, §4.2 "Performance of RBS")
  osm   Hilbert-curve cell ids of clustered 2-D locations — globally smooth,
        locally erratic ("lack of local structure ... artifact of the
        technique used to project the Earth into one-dimensional space")
  wiki  edit timestamps — bursty arrival process with periodic rate

All generators return exactly ``n`` sorted unique uint64 keys, fully
determined by ``seed``.  EXPERIMENTS.md flags every paper comparison as
surrogate-based.

Real datasets: when ``REPRO_SOSD_DIR`` points at a directory holding the
published SOSD uint64 binaries (books/fb/osm_cellids/wiki_ts,
https://github.com/learnedsystems/SOSD), ``generate`` loads and
deterministically subsamples the real keys instead — see ``load_real``.
"""
from __future__ import annotations

import hashlib
import os
import shutil
import subprocess
import warnings

import numpy as np

__all__ = ["DATASETS", "SOSD_SOURCES", "SOSD_URL_BASE", "fetch_real",
           "generate", "load_real", "make_queries"]


def _finalize(raw: np.ndarray, n: int, rng: np.random.Generator) -> np.ndarray:
    keys = np.unique(raw.astype(np.uint64))
    while len(keys) < n:  # top up collisions
        extra = rng.integers(1, 1 << 62, size=(n - len(keys)) * 2, dtype=np.uint64)
        keys = np.unique(np.concatenate([keys, extra]))
    if len(keys) > n:
        sel = rng.choice(len(keys), size=n, replace=False)
        keys = np.sort(keys[sel])
    return keys


def gen_amzn(n: int, seed: int = 0) -> np.ndarray:
    """Popularity counts: lognormal body + Pareto tail, scaled to ~2^47."""
    rng = np.random.default_rng(seed)
    m = int(n * 1.25)
    body = rng.lognormal(mean=10.0, sigma=2.2, size=m)
    tail = (rng.pareto(1.1, size=m // 20) + 1.0) * np.exp(14.0)
    raw = np.concatenate([body, tail])
    raw = raw / raw.max() * (2.0**47)
    return _finalize(np.maximum(raw, 1.0), n, rng)


def gen_face(n: int, seed: int = 0) -> np.ndarray:
    """Uniform IDs in (0, 2^50) with ~100 extreme outliers in (2^59, 2^64)."""
    rng = np.random.default_rng(seed)
    raw = rng.integers(1, 1 << 50, size=int(n * 1.05), dtype=np.uint64)
    n_out = 100
    outliers = rng.integers(1 << 59, (1 << 63) + ((1 << 63) - 1), size=n_out,
                            dtype=np.uint64)
    keys = _finalize(raw, n - n_out, rng)
    return np.sort(np.concatenate([keys, np.unique(outliers)]))[:n]


def _hilbert_xy2d(order: int, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Vectorized Hilbert curve distance (uint64), standard xy2d."""
    d = np.zeros(x.shape, np.uint64)
    x = x.astype(np.uint64).copy()
    y = y.astype(np.uint64).copy()
    side = np.uint64(1) << np.uint64(order)
    s = np.uint64(1) << np.uint64(order - 1)
    one = np.uint64(1)
    while s > 0:
        rx = ((x & s) > 0).astype(np.uint64)
        ry = ((y & s) > 0).astype(np.uint64)
        d += s * s * ((np.uint64(3) * rx) ^ ry)
        # rotate quadrant (classic rot(): reflection uses the full side)
        swap = ry == 0
        flip = swap & (rx == 1)
        x_f = np.where(flip, side - one - x, x)
        y_f = np.where(flip, side - one - y, y)
        x, y = np.where(swap, y_f, x_f), np.where(swap, x_f, y_f)
        s >>= one
    return d


def gen_osm(n: int, seed: int = 0, order: int = 24) -> np.ndarray:
    """Hilbert cell ids of clustered 2-D points (cities + background)."""
    rng = np.random.default_rng(seed)
    m = int(n * 1.3)
    n_clusters = 256
    side = float(1 << order)
    cx = rng.uniform(0, side, n_clusters)
    cy = rng.uniform(0, side, n_clusters)
    weights = rng.pareto(1.0, n_clusters) + 0.05
    weights /= weights.sum()
    assign = rng.choice(n_clusters, size=m, p=weights)
    sx = side / 400.0
    x = np.clip(cx[assign] + rng.normal(0, sx, m), 0, side - 1).astype(np.uint64)
    y = np.clip(cy[assign] + rng.normal(0, sx, m), 0, side - 1).astype(np.uint64)
    bg = rng.random(m) < 0.08  # uniform background points
    x[bg] = rng.integers(0, int(side), size=int(bg.sum()), dtype=np.uint64)
    y[bg] = rng.integers(0, int(side), size=int(bg.sum()), dtype=np.uint64)
    d = _hilbert_xy2d(order, x, y)
    return _finalize(d, n, rng)


def gen_wiki(n: int, seed: int = 0) -> np.ndarray:
    """Edit timestamps: exponential gaps, rate modulated daily + bursts."""
    rng = np.random.default_rng(seed)
    m = int(n * 1.15)
    t = np.arange(m, dtype=np.float64)
    rate = 1.0 + 0.8 * np.sin(2 * np.pi * t / 86400.0) ** 2
    burst_at = rng.choice(m, size=m // 200, replace=False)
    burst = np.zeros(m)
    burst[burst_at] = rng.exponential(50.0, size=len(burst_at))
    rate = rate + burst
    gaps = rng.exponential(1.0, size=m) / rate * 1000.0
    ts = np.cumsum(gaps) + 1.0e9
    return _finalize(ts, n, rng)


DATASETS = {
    "amzn": gen_amzn,
    "face": gen_face,
    "osm": gen_osm,
    "wiki": gen_wiki,
}

# ---------------------------------------------------------------------------
# Real SOSD binaries (env-gated; the container itself is offline)
# ---------------------------------------------------------------------------

#: our dataset name -> published SOSD file name (uint64 variants; the
#: format is an 8-byte little-endian count followed by `count` uint64 keys)
SOSD_SOURCES = {
    "amzn": "books_200M_uint64",
    "face": "fb_200M_uint64",
    "osm": "osm_cellids_200M_uint64",
    "wiki": "wiki_ts_200M_uint64",
}


def _sha256(path: str, chunk: int = 1 << 22) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                break
            h.update(block)
    return h.hexdigest()


def _check_sidecar(path: str) -> None:
    """Verify `path` against a ``<file>.sha256`` sidecar if one exists
    (``sha256sum`` format: hex digest, whitespace, filename).  A missing
    sidecar is accepted — the digests aren't shipped with the binaries —
    but a PRESENT sidecar that disagrees is corruption, not a fallback
    case, so it raises."""
    sidecar = path + ".sha256"
    if not os.path.exists(sidecar):
        return
    with open(sidecar) as f:
        tokens = f.read().split()
    if not tokens or len(tokens[0]) != 64:
        raise ValueError(f"malformed sha256 sidecar {sidecar}")
    expected = tokens[0].lower()
    got = _sha256(path)
    if got != expected:
        raise ValueError(
            f"checksum mismatch for {path}: expected {expected}, got {got}")


def load_real(name: str, n: int, sosd_dir: str, seed: int = 0) -> np.ndarray:
    """Load + deterministically subsample one published SOSD binary.

    Returns exactly ``n`` sorted unique uint64 keys: the file's unique
    keys taken at evenly spaced ranks (``floor(i * L / n)``, strictly
    increasing for L >= n), which preserves the CDF shape the indexes
    are benchmarked against.  ``seed`` is accepted for signature parity
    with the surrogates and ignored — the subsample is rank-determined.
    """
    del seed
    path = os.path.join(sosd_dir, SOSD_SOURCES[name])
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    _check_sidecar(path)
    with open(path, "rb") as f:
        count = int(np.fromfile(f, dtype="<u8", count=1)[0])
    held = (os.path.getsize(path) - 8) // 8
    if held < count:
        raise ValueError(
            f"{path}: header promises {count} keys, file holds {held}")
    # memmap the 1.6GB published files instead of reading them wholesale;
    # np.unique materializes the one sorted copy we actually need.
    mm = np.memmap(path, dtype="<u8", mode="r", offset=8, shape=(count,))
    keys = np.unique(mm).astype(np.uint64, copy=False)  # sorted unique
    if len(keys) < n:
        raise ValueError(
            f"{path}: only {len(keys)} unique keys, {n} requested")
    if len(keys) == n:
        return keys
    pos = (np.arange(n, dtype=np.float64) * (len(keys) / n)).astype(np.int64)
    return keys[pos]


# ---------------------------------------------------------------------------
# Online fetch (env-gated: REPRO_SOSD_FETCH=1; CI never takes this path)
# ---------------------------------------------------------------------------

#: Host publishing the zstd-compressed SOSD binaries (the same one the
#: SOSD repo's own `scripts/download.sh` pulls from).  Override with
#: ``REPRO_SOSD_URL`` for a mirror.
SOSD_URL_BASE = "https://dataset.dws.informatik.uni-mannheim.de/sosd/data/"


def _decompress_zstd(src: str, dst: str) -> None:
    """Decompress ``src`` (.zst) to ``dst`` via whichever zstd the host
    has: the `zstandard` module, else the `zstd` CLI.  The container
    bakes in neither a network nor zstd, so this is a gated capability,
    not a dependency — a clear error beats a silent pip install."""
    try:
        import zstandard  # optional; never installed by us
    except ImportError:
        zstandard = None
    if zstandard is not None:
        with open(src, "rb") as fin, open(dst, "wb") as fout:
            zstandard.ZstdDecompressor().copy_stream(fin, fout)
        return
    cli = shutil.which("zstd")
    if cli:
        subprocess.run([cli, "-d", "-f", "-o", dst, src], check=True)
        return
    raise RuntimeError(
        "no zstd decompressor available (install the `zstandard` module "
        "or the `zstd` CLI to use the SOSD online fetch)")


def fetch_real(name: str, dest_dir: str, url_base: str | None = None,
               force: bool = False, chunk: int = 1 << 20) -> str:
    """Download + decompress one published SOSD binary into ``dest_dir``.

    Writes the decompressed uint64 binary under its canonical
    `SOSD_SOURCES` name plus a ``<file>.sha256`` sidecar (the digest
    `load_real` verifies on every subsequent load), both via
    temp-then-rename so a killed download can't masquerade as a
    complete file.  Returns the binary's path.  Network access happens
    only here — `generate` calls this solely when ``REPRO_SOSD_FETCH``
    is set, so CI and offline hosts never touch the network path.
    """
    import urllib.request

    path = os.path.join(dest_dir, SOSD_SOURCES[name])
    if os.path.exists(path) and not force:
        return path
    os.makedirs(dest_dir, exist_ok=True)
    base = url_base or os.environ.get("REPRO_SOSD_URL") or SOSD_URL_BASE
    url = base + SOSD_SOURCES[name] + ".zst"
    zst_tmp, bin_tmp = path + ".zst.part", path + ".part"
    try:
        with urllib.request.urlopen(url) as resp, open(zst_tmp, "wb") as out:
            while True:
                block = resp.read(chunk)
                if not block:
                    break
                out.write(block)
        _decompress_zstd(zst_tmp, bin_tmp)
        digest = _sha256(bin_tmp)
        with open(path + ".sha256", "w") as f:
            f.write(f"{digest}  {SOSD_SOURCES[name]}\n")
        os.replace(bin_tmp, path)
    finally:
        for tmp in (zst_tmp, bin_tmp):
            if os.path.exists(tmp):
                os.remove(tmp)
    return path


def generate(name: str, n: int, seed: int = 0) -> np.ndarray:
    """``n`` sorted unique uint64 keys: the real SOSD dataset when
    ``REPRO_SOSD_DIR`` is set and holds the binary (with
    ``REPRO_SOSD_FETCH=1``, downloading it first), else the surrogate."""
    sosd_dir = os.environ.get("REPRO_SOSD_DIR")
    if sosd_dir:
        try:
            return load_real(name, n, sosd_dir, seed=seed)
        except FileNotFoundError:
            if os.environ.get("REPRO_SOSD_FETCH"):
                try:
                    fetch_real(name, sosd_dir)
                    return load_real(name, n, sosd_dir, seed=seed)
                except Exception as e:  # noqa: BLE001 — offline host: fall through
                    warnings.warn(
                        f"SOSD fetch of {SOSD_SOURCES[name]} failed ({e}); "
                        f"using the {name} surrogate", stacklevel=2)
                    return DATASETS[name](n, seed)
            warnings.warn(
                f"REPRO_SOSD_DIR={sosd_dir} has no {SOSD_SOURCES[name]}; "
                f"using the {name} surrogate", stacklevel=2)
    return DATASETS[name](n, seed)


def make_queries(
    keys: np.ndarray,
    m: int,
    seed: int = 0,
    present_frac: float = 0.8,
) -> np.ndarray:
    """Lookup workload: sampled present keys + uniform absent keys (paper
    samples lookups from the key set; absent keys exercise the §2 validity
    definition for all integers).

    Delegates to the seeded `repro.workloads` generator — the uniform
    draw sequence is bit-identical to what this function historically
    produced in-line, so every benchmark's query stream is unchanged
    (pinned by tests/test_workloads_mutable.py)."""
    from repro.workloads import make_point_queries

    return make_point_queries(keys, m, seed=seed + 1,
                              present_frac=present_frac, dist="uniform")
