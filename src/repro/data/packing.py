"""Sequence packing via learned-index offset lookup.

Packing N documents into fixed-length training rows needs, for every token
offset in the packed stream, the id of the document that owns it:
``doc = upper_bound(cum_lens, offset) - 1`` — the paper's §2 operation over
the cumulative-length array.  For millions of documents this lookup is the
packing bottleneck; an RMI over ``cum_lens`` turns each probe into O(1)
predict + tiny fixup, exactly the paper's pitch, measured end-to-end in
benchmarks/pareto.py's companion (examples/packing_pipeline.py).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.core import rmi as rmi_mod
from repro.core import base as core_base


class PackedIndex:
    """Offset -> (doc id, within-doc position) via an RMI over cum_lens."""

    def __init__(self, doc_lens: np.ndarray, branching: int = 1024):
        self.doc_lens = np.asarray(doc_lens, np.int64)
        self.cum = np.concatenate([[0], np.cumsum(self.doc_lens)])
        self.total = int(self.cum[-1])
        # index the cumulative starts (sorted, unique since lens > 0)
        self.index = rmi_mod.build(self.cum.astype(np.uint64),
                                   branching=branching)

    def locate(self, offsets: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized: packed offsets -> (doc ids, within-doc positions)."""
        import jax.numpy as jnp
        from repro.core import search

        q = jnp.asarray(offsets.astype(np.uint64))
        lo, hi = self.index.lookup(self.index.state, q)
        # LB gives first cum >= offset; owner doc = LB - 1 when cum < offset
        pos = np.asarray(search.bounded_binary(
            jnp.asarray(self.cum.astype(np.uint64)), q, lo, hi,
            self.index.meta["max_err"]))
        exact = self.cum[np.minimum(pos, len(self.cum) - 1)] == offsets
        doc = np.where(exact, pos, pos - 1).astype(np.int64)
        within = offsets - self.cum[doc]
        return doc, within

    def locate_oracle(self, offsets: np.ndarray):
        pos = np.searchsorted(self.cum, offsets, side="left")
        exact = self.cum[np.minimum(pos, len(self.cum) - 1)] == offsets
        doc = np.where(exact, pos, pos - 1).astype(np.int64)
        return doc, offsets - self.cum[doc]


def pack_documents(doc_tokens, seq_len: int, pad_id: int = 0,
                   eod_id: int = 1) -> Iterator[np.ndarray]:
    """Greedy-concatenate documents into fixed rows with EOD separators."""
    buf: list = []
    for doc in doc_tokens:
        buf.extend(list(doc))
        buf.append(eod_id)
        while len(buf) >= seq_len:
            yield np.asarray(buf[:seq_len], np.int32)
            buf = buf[seq_len:]
    if buf:
        row = buf + [pad_id] * (seq_len - len(buf))
        yield np.asarray(row, np.int32)
