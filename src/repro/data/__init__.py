"""Datasets (SOSD surrogates) and the LM data pipeline."""
