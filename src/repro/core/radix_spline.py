"""RadixSpline index (paper §3.2, Kipf et al. [18]).

One-pass error-bounded linear spline over the CDF + a radix table over
r-bit key prefixes that bounds the binary search for the spline segment.
Lookup: radix probe (bit shift + two table loads) -> bounded search over
spline knots -> linear interpolation -> bound of width 2*(eps+1).

The spline fit guarantees interpolation error <= eps at every data point
(chord-in-corridor construction, see _pla.greedy_spline); knots are data
points so interpolation is monotone and the +1 gap argument (DESIGN.md §2)
extends validity to absent keys.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import base, _pla, search, spec

spec.register_schema(
    "radix_spline",
    fields=[
        spec.HyperField("eps", int, 32, lo=1, hi=1 << 20),
        spec.HyperField("radix_bits", int, 16, lo=1, hi=28),
    ],
    # smallest -> largest size: eps down (more knots) + radix bits up
    ladder=[dict(eps=e, radix_bits=r)
            for (e, r) in ((1024, 8), (512, 10), (256, 12), (128, 14),
                           (64, 16), (32, 16), (16, 18), (8, 20))],
)


@base.register("radix_spline")
def build(
    keys: np.ndarray,
    eps: int = 32,
    radix_bits: int = 16,
    last_mile: str = "binary",
) -> base.IndexBuild:
    keys = np.asarray(keys)
    n = len(keys)
    x = base.np_keys_to_f64(keys)
    y = np.arange(n, dtype=np.float64)
    xu, y_first, span = _pla.group_rounded(x, y)

    kx, ky = _pla.greedy_spline(xu, y_first, float(eps))
    m = len(kx)

    # ---- radix table over (key - min) >> shift ----
    kmin = np.uint64(keys[0])
    key_range = int(keys[-1]) - int(keys[0])
    sig_bits = max(1, key_range.bit_length())
    r = int(min(radix_bits, sig_bits))
    shift = sig_bits - r
    # prefixes of the spline KNOTS (uint64 domain; knots are data points, but
    # kx is f64 — recover prefixes from the original keys via searchsorted).
    knot_pos = np.searchsorted(x, kx, side="left")
    knot_keys = keys[np.clip(knot_pos, 0, n - 1)]
    prefixes = ((knot_keys - kmin) >> np.uint64(shift)).astype(np.int64)
    table = np.searchsorted(prefixes, np.arange((1 << r) + 1), side="left")
    table = np.minimum(table, m - 1).astype(np.int64)
    max_gap = int(np.max(table[1:] - table[:-1])) if r > 0 else m

    state = {
        "kx": jnp.asarray(kx),
        "ky": jnp.asarray(ky),
        "table": jnp.asarray(table),
        "kmin": jnp.uint64(kmin),
    }
    size = base.nbytes(kx, ky, table)
    e = int(eps) + span + 1
    max_err = 2 * e + 2

    def lookup(state, q) -> base.SearchBound:
        qf = q.astype(jnp.float64)
        qi = q.astype(jnp.uint64)
        delta = jnp.where(qi > state["kmin"], qi - state["kmin"], jnp.uint64(0))
        p = jnp.clip((delta >> shift).astype(jnp.int64), 0, (1 << r) - 1)
        slo = jnp.take(state["table"], p)
        shi = jnp.take(state["table"], p + 1)
        # segment = last knot <= q (upper_bound - 1), searched inside [slo,shi]
        ub = search.bounded_binary(state["kx"], qf, slo, shi, max_gap + 2, side="right")
        seg = jnp.clip(ub - 1, 0, m - 2)
        x0 = jnp.take(state["kx"], seg)
        x1 = jnp.take(state["kx"], seg + 1)
        y0 = jnp.take(state["ky"], seg)
        y1 = jnp.take(state["ky"], seg + 1)
        dx = x1 - x0
        t = jnp.where(dx > 0, (qf - x0) / jnp.where(dx == 0, 1.0, dx), 0.0)
        t = jnp.clip(t, 0.0, 1.0)
        pred = y0 + t * (y1 - y0)
        lo = jnp.floor(pred).astype(jnp.int64) - e
        hi = jnp.ceil(pred).astype(jnp.int64) + e
        return base.clip_bound(lo, hi, n)

    return base.IndexBuild(
        name="radix_spline",
        state=state,
        lookup=lookup,
        size_bytes=size,
        hyper=dict(eps=eps, radix_bits=r, last_mile=last_mile),
        meta={"max_err": max_err, "levels": 2, "n": n, "knots": m,
              "radix_max_gap": max_gap},
    )
