"""Explanatory analysis (paper §4.3), adapted to the dry-run setting.

The paper regresses lookup latency on HW counters (cache misses, branch
misses, instructions).  This container has no TPU counters, so we use the
model-derived equivalents defined in DESIGN.md §7:

  bytes_touched   bytes of index state + data window gathered per lookup
                  (the HBM-traffic analogue of cache misses)
  probes          dependent gather rounds (levels + last-mile trips —
                  the latency-chain analogue of pointer hops)
  flops           arithmetic per lookup (instruction-count analogue)
  log2_err        paper's log2 of bound width
  size_bytes      paper's model size

``regress`` reproduces the paper's multi-metric linear regression with
standardized coefficients and R².
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import base


def describe(build: base.IndexBuild, widths: np.ndarray) -> Dict:
    """Per-lookup descriptive metrics for one built index."""
    name = build.name
    h = build.hyper
    levels = build.meta.get("levels", 1)
    avg_width = float(np.mean(widths))
    log2_err = float(np.mean(np.log2(np.maximum(widths, 1))))

    # bytes of index state the lookup path touches (model inference)
    if name == "rmi":
        inference_bytes = 2 * 8 + 3 * 8  # stage1 coeffs + one stage2 row
        flops = 8
    elif name == "pgm":
        inference_bytes = levels * 3 * 8 + build.meta.get("segments", 0) // max(
            build.meta.get("segments", 1), 1)
        flops = levels * 6 + levels * int(np.ceil(np.log2(h.get("eps_internal", 8) + 2))) * 2
    elif name == "radix_spline":
        inference_bytes = 2 * 8 + 4 * 8
        flops = 10 + int(np.ceil(np.log2(build.meta.get("radix_max_gap", 2) + 2))) * 2
    elif name in ("btree", "ibtree"):
        # identical node layout; ibtree's interpolation probe swaps the
        # node-wide rank count for one multiply + the same node gather
        inference_bytes = levels * (h.get("fanout", 128) + 1) * 8
        flops = (levels * (h.get("fanout", 128) + 1) if name == "btree"
                 else levels * 8)
    elif name == "rbs":
        inference_bytes = 2 * 8
        flops = 3
    else:  # binary_search
        inference_bytes = 0
        flops = 0

    last_mile_probes = int(np.ceil(np.log2(max(2, avg_width))))
    bytes_touched = inference_bytes + last_mile_probes * 8
    return {
        "name": name,
        "size_bytes": build.size_bytes,
        "log2_err": log2_err,
        "avg_width": avg_width,
        "probes": levels + last_mile_probes,
        "bytes_touched": bytes_touched,
        "flops": flops + last_mile_probes * 2,
    }


#: Per-unit latency weights turning the §7 metrics into one scalar
#: nanosecond PROXY (DESIGN.md §12.3): a dependent probe round costs a
#: memory-latency-ish 30ns, a byte of traffic 0.25ns, a flop 0.5ns.
#: The absolute scale is nominal — the tuner only ranks candidates and
#: compares against a caller-chosen ``target_ns`` stated in the same
#: units — but the RATIOS encode the paper's §4.3 finding that data
#: movement dominates, instruction count least.
COST_NS_WEIGHTS = {"probes": 30.0, "bytes_touched": 0.25, "flops": 0.5}


def cost_ns(metrics: Dict, calibration: float = 1.0) -> float:
    """Scalar per-lookup latency proxy of one `describe()` record — the
    objective `repro.core.spec.Tuner` minimizes / budgets against.

    ``calibration`` is a measured/proxy ratio (``obs.profiler``'s
    ``cost_model_ratio``): the proxy trusts its nominal weights only up
    to a per-index-family constant, so a live measurement can rescale
    a family's proxy before cross-family ranking.  1.0 = trust proxy.
    """
    return float(calibration) * float(
        sum(w * metrics[k] for k, w in COST_NS_WEIGHTS.items()))


def regress(records: List[Dict], y_key: str = "ns_per_lookup",
            x_keys=("bytes_touched", "probes", "flops")) -> Dict:
    """Standardized linear regression of latency on metrics (paper §4.3)."""
    y = np.array([r[y_key] for r in records], np.float64)
    X = np.array([[r[k] for k in x_keys] for r in records], np.float64)
    Xs = (X - X.mean(0)) / np.maximum(X.std(0), 1e-12)
    ys = (y - y.mean()) / max(y.std(), 1e-12)
    A = np.concatenate([Xs, np.ones((len(y), 1))], axis=1)
    coef, *_ = np.linalg.lstsq(A, ys, rcond=None)
    pred = A @ coef
    ss_res = float(((ys - pred) ** 2).sum())
    ss_tot = float((ys**2).sum())
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    return {
        "r2": r2,
        "coef": {k: float(c) for k, c in zip(x_keys, coef[:-1])},
        "n": len(records),
    }


def single_metric_r2(records: List[Dict], y_key: str = "ns_per_lookup") -> Dict:
    """R² of each metric alone — the paper's 'no single metric explains it'."""
    out = {}
    for k in ("size_bytes", "log2_err", "bytes_touched", "probes", "flops"):
        out[k] = regress(records, y_key=y_key, x_keys=(k,))["r2"]
    return out
