"""Implicit vector B-tree (the paper's BTree/FAST stand-in, TPU-adapted).

FAST [16] argues a tree node should match the SIMD width; the TPU analogue
is a 128-lane node: each descent step is one dynamic-slice gather + one
vector rank count, no pointers.  The size/performance knob is the paper's
§2.1 technique — index every s-th key — which yields an error bound of
exactly s with zero stored error metadata.

Levels are built bottom-up: L0 = keys[::s]; L_{j+1} = L_j[::fanout].
Lookup descends coarse->fine with a (fanout+1)-wide window rank count per
level, then maps the sampled position to a width-s bound over the data.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import base, spec

_BTREE_FIELDS = [
    spec.HyperField("sample", int, 1, lo=1, hi=1 << 20),
    spec.HyperField("fanout", int, 128, lo=2, hi=4096),
]

spec.register_schema(
    "btree",
    fields=_BTREE_FIELDS,
    # smallest -> largest size: coarser sampling = fewer stored keys
    ladder=[dict(sample=s) for s in (1024, 256, 64, 32, 16, 8, 4, 2, 1)],
)

spec.register_schema(
    "ibtree",
    fields=_BTREE_FIELDS,
    ladder=[dict(sample=s) for s in (256, 64, 16, 4, 1)],
)


@base.register("btree")
def build(
    keys: np.ndarray,
    sample: int = 1,
    fanout: int = 128,
    last_mile: str = "binary",
) -> base.IndexBuild:
    keys = np.asarray(keys)
    n = len(keys)
    s = max(1, int(sample))
    F = int(fanout)

    levels_np = [keys[::s]]
    while len(levels_np[-1]) > F:
        levels_np.append(levels_np[-1][::F])
    levels_np = levels_np[::-1]  # coarse -> fine
    m = len(levels_np[-1])

    state = {"levels": [jnp.asarray(l) for l in levels_np]}
    size = sum(base.nbytes(l) for l in levels_np)
    depth = len(levels_np)

    def lookup(state, q) -> base.SearchBound:
        lv = state["levels"]
        top = lv[0]
        # LB within the (<= F wide) root: one vector rank count
        idx = jnp.sum(top[None, :] < q[:, None], axis=-1).astype(jnp.int64)
        for j in range(1, depth):
            child = lv[j]
            cn = child.shape[0]
            w = jnp.maximum((idx - 1) * F, 0)
            offs = jnp.arange(F + 1, dtype=jnp.int64)
            gidx = w[:, None] + offs[None, :]
            oob = gidx >= cn
            window = jnp.take(child, jnp.clip(gidx, 0, cn - 1), mode="clip")
            less = jnp.where(oob, False, window < q[:, None])
            idx = w + jnp.sum(less, axis=-1).astype(jnp.int64)
        lo = jnp.maximum((idx - 1) * s + 1, 0)
        hi = idx * s
        return base.clip_bound(lo, hi, n)

    return base.IndexBuild(
        name="btree",
        state=state,
        lookup=lookup,
        size_bytes=size,
        hyper=dict(sample=s, fanout=F, last_mile=last_mile),
        meta={"max_err": s + 1, "levels": depth, "n": n, "root": m},
    )


@base.register("ibtree")
def build_ibtree(
    keys: np.ndarray,
    sample: int = 1,
    fanout: int = 128,
    **_,
) -> base.IndexBuild:
    """Interpolating B-tree (paper Table 1, Graefe [15]): identical layout
    to the vector B-tree, but each node probe INTERPOLATES between the
    node's end keys instead of rank-counting — one multiply replaces the
    node-wide compare, at the cost of a per-node verify window.  On TPU the
    rank count is already a single vector op, so IBTree's win is smaller
    than on a CPU (recorded as-is in the Pareto tables)."""
    inner = build(keys, sample=sample, fanout=fanout, last_mile="interpolation")
    b = base.IndexBuild(
        name="ibtree",
        state=inner.state,
        lookup=inner.lookup,
        size_bytes=inner.size_bytes,
        hyper=dict(sample=sample, fanout=fanout, last_mile="interpolation"),
        meta=dict(inner.meta),
    )
    return b
