"""Vectorized "last mile" searches (paper §2 / §4.2.3).

Each function locates ``LB(q)`` inside a search bound ``[lo, hi]`` (hi
inclusive) produced by an index.  All are branchless, fixed-trip-count
``lax`` loops vectorized over a query batch — the TPU-native adaptation of
the paper's binary / linear / interpolation last-mile search.

The CPU version of these is latency-bound (each probe is a dependent cache
miss); here every probe is a batched gather and every comparison is a vector
op, so cost scales with *bytes moved*, not round trips.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.common import branchless_lower_bound


def bounded_binary(data, q, lo, hi, max_width: int, side: str = "left"):
    """Branchless lower/upper bound in [lo, hi] (hi inclusive).

    ``max_width`` is a static bound on ``hi - lo + 1`` (from the index's error
    guarantee); it fixes the trip count so the loop lowers to a fixed-depth
    HLO with no data-dependent control flow.  One shared implementation with
    the kernel overflow fallback (`repro.kernels.common`), run in int64 here.
    """
    return branchless_lower_bound(
        data, q, lo, hi, max_width, side=side, index_dtype=jnp.int64)


def bounded_linear(data, q, lo, hi, max_width: int, chunk: int = 4096):
    """Vector "linear search": gather the whole window, count keys < q.

    The window has static width (next multiple of 128 >= max_width), so this
    is one gather + one vector reduction per query — the TPU analogue of a
    sequential scan within the bound.  Windows wider than ``chunk`` are
    streamed in fixed-size chunks to bound the materialized gather.
    """
    del hi
    n = data.shape[0]
    width = int(np.ceil(max(1, int(max_width)) / 128.0)) * 128

    def count_chunk(start_off, acc):
        idx = lo[:, None] + start_off + jnp.arange(min(width, chunk), dtype=jnp.int64)[None, :]
        oob = idx >= n
        window = jnp.take(data, jnp.clip(idx, 0, n - 1), mode="clip")
        # Out-of-bounds entries must compare as >= q (they are "+inf").
        less = jnp.where(oob, False, window < q[:, None])
        return acc + jnp.sum(less, axis=-1).astype(jnp.int64)

    if width <= chunk:
        return lo + count_chunk(0, jnp.zeros_like(lo))
    n_chunks = (width + chunk - 1) // chunk
    total = jax.lax.fori_loop(
        0, n_chunks,
        lambda i, acc: count_chunk(i * chunk, acc),
        jnp.zeros_like(lo),
    )
    return lo + total


def bounded_interpolation(data, q, lo, hi, max_width: int, iters: int = 2):
    """Interpolation probes shrink [lo, hi]; binary search finishes.

    Matches the paper's finding setup: interpolation helps when the data is
    locally smooth (amzn) and hurts on erratic data (osm) — here the "hurt"
    shows up as wasted probes before the binary fallback.
    """
    n = data.shape[0]
    lo = lo.astype(jnp.int64)
    hi = jnp.maximum(hi.astype(jnp.int64), lo)
    qf = q.astype(jnp.float64)

    for _ in range(iters):
        dlo = jnp.take(data, jnp.clip(lo, 0, n - 1), mode="clip").astype(jnp.float64)
        dhi = jnp.take(data, jnp.clip(hi, 0, n - 1), mode="clip").astype(jnp.float64)
        denom = dhi - dlo
        frac = jnp.where(denom > 0, (qf - dlo) / jnp.where(denom == 0, 1.0, denom), 0.5)
        frac = jnp.clip(frac, 0.0, 1.0)
        mid = lo + jnp.clip(
            jnp.round(frac * (hi - lo).astype(jnp.float64)).astype(jnp.int64),
            0,
            jnp.maximum(hi - lo, 0),
        )
        probe = jnp.take(data, jnp.clip(mid, 0, n - 1), mode="clip")
        probe_lt = jnp.logical_and(probe < q, mid < n)
        lo = jnp.where(probe_lt, mid + 1, lo)
        hi = jnp.where(probe_lt, hi, mid)

    return bounded_binary(data, q, lo, hi, max_width)


SEARCH_FNS = {
    "binary": bounded_binary,
    "linear": bounded_linear,
    "interpolation": bounded_interpolation,
}


def fused_lookup_fn(build, data_jnp, last_mile: str = "binary",
                    backend: str = "jnp"):
    """Back-compat shim: lower to a `LookupPlan` and compile it.

    The canonical lookup pipeline lives in `repro.core.plan` — every
    consumer (serving registry, mutable merge, benchmark matrix) lowers
    through it; this wrapper exists for callers that still think in
    (build, data) pairs.  The returned callable is closed over the index
    state, so jit's compile cache keys only on the query-batch shape;
    the serving dispatcher exploits that by padding batches to
    power-of-two buckets.
    """
    from repro.core import plan as plan_mod

    return plan_mod.lower(
        build, data_jnp, last_mile=last_mile).compile(backend=backend)


def full_binary(data, q):
    """Unbounded baseline (the paper's BS, size == 0)."""
    n = data.shape[0]
    lo = jnp.zeros(q.shape, jnp.int64)
    hi = jnp.full(q.shape, n - 1, jnp.int64)
    return bounded_binary(data, q, lo, hi, max_width=n)
