"""Two-stage Recursive Model Index (paper §3.1, Kraska et al. [19]).

Stage 1 (linear | cubic) makes a coarse CDF prediction that selects one of B
stage-2 linear models; the selected model refines the prediction, and its
stored worst-case error yields the search bound.  Trained top-down, exactly
as the paper describes (Eq. 1 / Eq. 2), with closed-form least squares.

Validity for ABSENT keys: stage-2 slopes are clipped to >= 0 and each
bucket's error is computed over (a) every key mapping to the bucket and
(b) the boundary key preceding the bucket (target = first position of the
bucket).  With a monotone stage-1 this makes the bound valid for every
integer query — see DESIGN.md §2 and tests/test_core_validity.py.

Implementation note: bucket selection and stage-2 prediction are evaluated
through the SAME jitted expressions at build time and at lookup time.  A
numpy-side replica can differ by 1 ulp (XLA may contract a*u+b into an FMA),
which near a bucket boundary silently assigns a key's error to the wrong
model — observed as rare validity violations on the face/osm surrogates.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import base, spec

spec.register_schema(
    "rmi",
    fields=[
        spec.HyperField("branching", int, 1024, lo=2, hi=2**22),
        spec.HyperField("stage1", str, "linear",
                        choices=("linear", "cubic", "minmax")),
    ],
    # CDFShop ladder, smallest -> largest size (size tracks branching;
    # the cubic rungs slot in at their branching factor)
    ladder=[dict(branching=2**6), dict(branching=2**8),
            dict(branching=2**10), dict(branching=2**10, stage1="cubic"),
            dict(branching=2**12), dict(branching=2**14),
            dict(branching=2**14, stage1="cubic"),
            dict(branching=2**16), dict(branching=2**18)],
)


def _fit_linear(u: np.ndarray, y: np.ndarray):
    """Closed-form least squares y ~ a*u + b (f64)."""
    n = len(u)
    su, sy = u.sum(), y.sum()
    suu, suy = (u * u).sum(), (u * y).sum()
    denom = n * suu - su * su
    if denom <= 0:
        return 0.0, float(y.mean()) if n else 0.0
    a = (n * suy - su * sy) / denom
    b = (sy - a * su) / n
    return float(a), float(b)


def _stage1_bucket(coeffs, x0, inv_range, scale, B, q):
    """jnp: query/key -> (normalized u, stage-1 prediction, bucket)."""
    u = (q.astype(jnp.float64) - x0) * inv_range
    p1 = jnp.zeros_like(u)
    for i in range(coeffs.shape[0]):
        p1 = p1 * u + coeffs[i]
    bkt = jnp.clip(jnp.floor(p1 * scale), 0, B - 1).astype(jnp.int64)
    return u, bkt


def _stage2_pred(a2, b2, u, bkt):
    """jnp: the exact arithmetic the lookup path runs."""
    return jnp.take(a2, bkt) * u + jnp.take(b2, bkt)


@base.register("rmi")
def build(
    keys: np.ndarray,
    branching: int = 1024,
    stage1: str = "linear",
    last_mile: str = "binary",
) -> base.IndexBuild:
    keys = np.asarray(keys)
    n = len(keys)
    x = base.np_keys_to_f64(keys)
    y = np.arange(n, dtype=np.float64)

    # Normalize keys to [0, 1] for conditioning; constants live in the state.
    x0, x1 = float(x[0]), float(x[-1])
    inv_range = 1.0 / (x1 - x0) if x1 > x0 else 1.0
    u_np = (x - x0) * inv_range

    # ---- stage 1 (fit in numpy; inference always through the jnp path) ----
    if stage1 == "linear":
        a, b = _fit_linear(u_np, y)
        coeffs = np.array([max(a, 0.0), b], np.float64)
    elif stage1 == "cubic":
        coeffs = np.polyfit(u_np, y, 3).astype(np.float64)
        # The absent-key guarantee needs a monotone stage 1.  Keep the cubic
        # only if its derivative is >= 0 on [0, 1] (checked at the endpoints
        # and the vertex), else fall back to linear — CDFShop-style model
        # selection keeps only valid candidates.
        c3, c2, c1_, _ = coeffs
        dvals = [c1_, 3 * c3 + 2 * c2 + c1_]
        if abs(c3) > 1e-30:
            v = -c2 / (3 * c3)
            if 0.0 < v < 1.0:
                dvals.append(3 * c3 * v * v + 2 * c2 * v + c1_)
        if min(dvals) < 0:
            a, b = _fit_linear(u_np, y)
            coeffs = np.array([max(a, 0.0), b], np.float64)
            stage1 = "linear"
    elif stage1 == "minmax":
        coeffs = np.array([float(n - 1), 0.0], np.float64)
    else:
        raise ValueError(f"unknown stage1 model {stage1!r}")

    B = int(branching)
    scale = B / n
    infer1 = jax.jit(functools.partial(
        _stage1_bucket, jnp.asarray(coeffs), jnp.float64(x0),
        jnp.float64(inv_range), scale, B))
    u_j, bkt_j = infer1(jnp.asarray(keys))
    u = np.asarray(u_j)  # f64, identical to what lookups will compute
    bucket = np.asarray(bkt_j)
    monotone = stage1 in ("linear", "minmax")
    if not monotone:
        bucket_mono = np.maximum.accumulate(bucket)
    else:
        bucket_mono = bucket

    # ---- stage 2: grouped closed-form least squares ----
    cnt = np.bincount(bucket, minlength=B).astype(np.float64)
    su = np.bincount(bucket, weights=u, minlength=B)
    sy = np.bincount(bucket, weights=y, minlength=B)
    suu = np.bincount(bucket, weights=u * u, minlength=B)
    suy = np.bincount(bucket, weights=u * y, minlength=B)
    denom = cnt * suu - su * su
    ok = denom > 1e-30
    a2 = np.where(ok, (cnt * suy - su * sy) / np.where(ok, denom, 1.0), 0.0)
    a2 = np.maximum(a2, 0.0)  # monotone within bucket
    with np.errstate(invalid="ignore"):
        b2 = np.where(cnt > 0, (sy - a2 * su) / np.where(cnt > 0, cnt, 1.0), 0.0)

    # Empty buckets: constant model at the first position of the next
    # non-empty bucket (exact LB for any query landing there; see DESIGN.md).
    first_pos = np.searchsorted(bucket_mono, np.arange(B), side="left").astype(np.float64)
    empty = cnt == 0
    b2 = np.where(empty, first_pos, b2)

    # ---- per-bucket worst-case error, through the lookup's arithmetic ----
    a2_j, b2_j = jnp.asarray(a2), jnp.asarray(b2)
    pred = np.asarray(jax.jit(_stage2_pred)(a2_j, b2_j, u_j, bkt_j))
    abs_err = np.abs(pred - y)
    err = np.zeros(B, np.float64)
    np.maximum.at(err, bucket, abs_err)
    # Boundary safety (both sides): a query in the gap between two buckets'
    # key ranges maps to one of them, so each bucket's model must also bound
    # (a) the key PRECEDING its first key (target = first position) and
    # (b) the key FOLLOWING its last key (target = that key's position).
    nonempty = np.flatnonzero(~empty)
    fp = first_pos[nonempty].astype(np.int64)
    has_prev = fp > 0
    ne, fpp = nonempty[has_prev], fp[has_prev]
    bpred = np.asarray(jax.jit(_stage2_pred)(
        a2_j, b2_j, jnp.asarray(u[fpp - 1]), jnp.asarray(ne)))
    np.maximum.at(err, ne, np.abs(bpred - fp.astype(np.float64)[has_prev]))
    lp = np.searchsorted(bucket_mono, nonempty, side="right") - 1  # last pos
    has_next = lp < n - 1
    ne2, lpn = nonempty[has_next], lp[has_next] + 1
    apred = np.asarray(jax.jit(_stage2_pred)(
        a2_j, b2_j, jnp.asarray(u[lpn]), jnp.asarray(ne2)))
    np.maximum.at(err, ne2, np.abs(apred - lpn.astype(np.float64)))

    # A bound of +-(n+1) already covers the whole array, so larger errors
    # carry no information — and uncapped they overflow the int64 cast on
    # key sets mixing tiny and ~2^64-scale keys (a steep stage-2 slope
    # evaluated at a far boundary key can reach ~1e20).
    err = np.minimum(err, float(n) + 1.0)
    err_i = np.ceil(err).astype(np.int64) + 1  # +1: interior-gap safety margin
    max_err = int(err_i.max()) if B else 1

    state: Dict[str, Any] = {
        "coeffs": jnp.asarray(coeffs),
        "a2": a2_j,
        "b2": b2_j,
        "err": jnp.asarray(err_i),
        "x0": jnp.float64(x0),
        "inv_range": jnp.float64(inv_range),
    }
    hyper = dict(branching=B, stage1=stage1, last_mile=last_mile)
    size = base.nbytes(coeffs, a2, b2, err_i.astype(np.int32)) + 16

    def lookup(state, q) -> base.SearchBound:
        uq, bkt = _stage1_bucket(
            state["coeffs"], state["x0"], state["inv_range"], scale, B, q)
        p2 = _stage2_pred(state["a2"], state["b2"], uq, bkt)
        # clamp in FLOAT space first: an extreme query (e.g. 2^64-1) can
        # predict ~1e19, which overflows the int64 cast and wraps the bound
        p2 = jnp.clip(p2, -1.0, float(n) + 1.0)
        e = jnp.take(state["err"], bkt)
        lo = jnp.floor(p2).astype(jnp.int64) - e
        hi = jnp.ceil(p2).astype(jnp.int64) + e
        return base.clip_bound(lo, hi, n)

    return base.IndexBuild(
        name="rmi",
        state=state,
        lookup=lookup,
        size_bytes=size,
        hyper=hyper,
        meta={"max_err": 2 * max_err + 2, "levels": 2, "n": n},
    )
