"""Declarative index construction: `IndexSpec` + schemas + the budget tuner.

The paper's headline claim rests on *well-tuned* implementations: every
structure is swept across ~10 configurations from minimum to maximum
size and only the Pareto frontier is reported (§3.1/§4.2 — the CDFShop
protocol SOSD formalizes as a dataset x configuration matrix).  Before
this module the repo could only *perform* a build (`REGISTRY[name](keys,
**hyper)` positional calls scattered across benchmarks, services, and
the mutable layer); nothing could *describe* one.  `IndexSpec` is that
description (DESIGN.md §12):

    IndexSpec(index, hyper, backend, last_mile)   # JSON-serializable
        --build(spec, keys)-->  IndexBuild        # validated, bit-identical
                                                  # to the direct call

Every builder registers a typed hyperparameter schema
(`register_schema`, next to its `base.register`) carrying field types,
bounds, defaults, and the CDFShop size ladder — `core.tuning.LADDERS`
and every sweep are *generated* from these schemas, so the registry and
the sweep matrix can never drift apart (pinned by
tests/test_spec.py::test_registry_schema_consistency).

`Tuner` searches the spec space per dataset under an explicit budget:
``max_bytes`` is a HARD cap (a spec whose build exceeds it is never
returned; `BudgetError` if no rung fits), ``target_ns`` a soft goal on
the `analysis.cost_ns` latency proxy (smallest index meeting it wins,
else the fastest feasible).  With more than one candidate backend the
winner spec's lookup is *measured* per backend and the fastest is
written into the returned spec — the autotuned per-dataset
spec+backend selection the ROADMAP called for.  `MutableIndex` re-runs
the tuner against delta-merged keys at compaction time, closing the
delta-aware-retuning item.
"""
from __future__ import annotations

import dataclasses
import json
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core import analysis, base, search

__all__ = [
    "BudgetError", "HyperField", "IndexSchema", "IndexSpec", "SpecError",
    "Tuner", "TuneResult", "SCHEMAS", "build", "coerce", "get_schema",
    "register_schema", "spec_ladder", "stride_sample", "sweep_names",
]

#: The plan-backend axis (mirrors `repro.core.plan.BACKENDS`; duplicated
#: as a literal so the spec layer stays importable below the plan IR).
BACKENDS = ("jnp", "pallas")


class SpecError(ValueError):
    """An `IndexSpec` that does not satisfy its index's schema."""


class BudgetError(ValueError):
    """No candidate spec fits the tuner's hard byte budget."""


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HyperField:
    """One typed hyperparameter: type, default, and admissible values."""

    name: str
    type: type                          # int | float | str
    default: Any
    choices: Optional[Tuple] = None     # enum constraint (str fields)
    lo: Optional[float] = None          # inclusive numeric bounds
    hi: Optional[float] = None

    def coerce(self, index: str, value: Any) -> Any:
        """Validate + canonicalize one value (bool is NOT an int here)."""
        if self.type is int:
            if isinstance(value, bool) or not isinstance(
                    value, (int, np.integer)):
                raise SpecError(
                    f"{index}.{self.name}: expected int, got {value!r}")
            value = int(value)
        elif self.type is float:
            if isinstance(value, bool) or not isinstance(
                    value, (int, float, np.integer, np.floating)):
                raise SpecError(
                    f"{index}.{self.name}: expected float, got {value!r}")
            value = float(value)
        elif self.type is str:
            if not isinstance(value, str):
                raise SpecError(
                    f"{index}.{self.name}: expected str, got {value!r}")
        if self.choices is not None and value not in self.choices:
            raise SpecError(
                f"{index}.{self.name}: {value!r} not in {self.choices}")
        if self.lo is not None and value < self.lo:
            raise SpecError(f"{index}.{self.name}: {value!r} < min {self.lo}")
        if self.hi is not None and value > self.hi:
            raise SpecError(f"{index}.{self.name}: {value!r} > max {self.hi}")
        return value


@dataclasses.dataclass(frozen=True)
class IndexSchema:
    """Typed hyperparameter schema + CDFShop ladder for one index.

    ``ladder`` rungs are partial hyper dicts ordered SMALLEST to LARGEST
    expected size — the invariant that lets `stride_sample` guarantee a
    capped sweep still sees both size extremes.  Indexes excluded from
    the default sweep carry an explicit ``sweep_exclude_reason``.
    """

    index: str
    fields: Tuple[HyperField, ...]
    ladder: Tuple[Mapping[str, Any], ...]
    sweep: bool = True
    sweep_exclude_reason: str = ""

    def field_map(self) -> Dict[str, HyperField]:
        return {f.name: f for f in self.fields}

    def defaults(self) -> Dict[str, Any]:
        return {f.name: f.default for f in self.fields}


SCHEMAS: Dict[str, IndexSchema] = {}


def register_schema(index: str, fields: Sequence[HyperField],
                    ladder: Sequence[Mapping[str, Any]],
                    sweep: bool = True,
                    sweep_exclude_reason: str = "") -> IndexSchema:
    """Register the typed schema + size ladder for one index name.

    Called next to each builder's `base.register`; the schema is the
    single source the sweep ladders, the tuner search space, and spec
    validation are all derived from.
    """
    if sweep == bool(sweep_exclude_reason):
        raise ValueError(f"{index}: sweep-excluded schemas (and only "
                         "those) must state a reason")
    schema = IndexSchema(index=index, fields=tuple(fields),
                         ladder=tuple(dict(r) for r in ladder),
                         sweep=sweep,
                         sweep_exclude_reason=sweep_exclude_reason)
    SCHEMAS[index] = schema
    return schema


def get_schema(index: str) -> IndexSchema:
    try:
        return SCHEMAS[index]
    except KeyError:
        raise SpecError(f"no schema registered for index {index!r}; "
                        f"known: {sorted(SCHEMAS)}") from None


def sweep_names() -> Tuple[str, ...]:
    """Index names in the default sweep (schema-declared, in
    registration order) — the generated successor of the hand-kept
    name tuple benchmarks used to pass around."""
    return tuple(n for n, s in SCHEMAS.items() if s.sweep)


# ---------------------------------------------------------------------------
# IndexSpec
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=True)
class IndexSpec:
    """A declarative, serializable description of one index build.

    ``hyper`` may be partial — `validated()` fills schema defaults and
    type/range-checks every field, so an invalid spec fails BEFORE any
    build work.  ``backend`` is the `LookupPlan` backend the built index
    is intended to serve with; ``last_mile`` None defers to the
    builder's own default (binary).
    """

    index: str
    hyper: Dict[str, Any] = dataclasses.field(default_factory=dict)
    backend: str = "jnp"
    last_mile: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "hyper", dict(self.hyper))

    # -- validation ------------------------------------------------------
    def validated(self) -> "IndexSpec":
        """Schema-checked copy with defaults filled; raises `SpecError`."""
        if self.index not in base.REGISTRY:
            raise SpecError(f"unknown index {self.index!r}; "
                            f"known: {sorted(base.REGISTRY)}")
        schema = get_schema(self.index)
        fields = schema.field_map()
        unknown = set(self.hyper) - set(fields)
        if unknown:
            raise SpecError(f"{self.index}: unknown hyperparameters "
                            f"{sorted(unknown)}; schema has {sorted(fields)}")
        hyper = {name: f.coerce(self.index, self.hyper.get(name, f.default))
                 for name, f in fields.items()}
        if self.backend not in BACKENDS:
            raise SpecError(f"unknown backend {self.backend!r}; "
                            f"one of {BACKENDS}")
        if self.last_mile is not None and \
                self.last_mile not in search.SEARCH_FNS:
            raise SpecError(f"unknown last_mile {self.last_mile!r}; "
                            f"one of {tuple(search.SEARCH_FNS)}")
        return IndexSpec(self.index, hyper, self.backend, self.last_mile)

    def replace(self, **kw) -> "IndexSpec":
        return dataclasses.replace(self, **kw)

    def canonical(self) -> Tuple:
        """Hashable identity (frozen dataclasses with dict fields are
        equality-comparable but not hashable)."""
        return (self.index, tuple(sorted(self.hyper.items())),
                self.backend, self.last_mile)

    # -- serialization ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"index": self.index, "hyper": dict(self.hyper),
                             "backend": self.backend}
        if self.last_mile is not None:
            d["last_mile"] = self.last_mile
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "IndexSpec":
        unknown = set(d) - {"index", "hyper", "backend", "last_mile"}
        if unknown:
            raise SpecError(f"unknown IndexSpec keys {sorted(unknown)}")
        if "index" not in d:
            raise SpecError("IndexSpec dict needs an 'index' key")
        return cls(index=d["index"], hyper=dict(d.get("hyper", {})),
                   backend=d.get("backend", "jnp"),
                   last_mile=d.get("last_mile"))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "IndexSpec":
        return cls.from_dict(json.loads(s))


def coerce(spec_or_name, hyper: Optional[Mapping[str, Any]] = None,
           backend: Optional[str] = None,
           last_mile: Optional[str] = None) -> IndexSpec:
    """Fold an `IndexSpec` OR a legacy (name, hyper) pair — plus
    optional backend/last-mile overrides — into ONE validated spec.
    The single coercion every spec-or-legacy entry point shares
    (registry publish, mutable index, benchmark builders).  Passing
    ``hyper`` alongside an `IndexSpec` is a `TypeError`: the spec is
    the whole description."""
    if isinstance(spec_or_name, IndexSpec):
        if hyper is not None:
            raise TypeError(
                "pass hyperparameters inside the IndexSpec, not via hyper=")
        sp = spec_or_name
    else:
        sp = IndexSpec(spec_or_name, dict(hyper or {}))
    if backend is not None:
        sp = sp.replace(backend=backend)
    if last_mile is not None:
        sp = sp.replace(last_mile=last_mile)
    return sp.validated()


# ---------------------------------------------------------------------------
# The build entry point
# ---------------------------------------------------------------------------
def build(spec: IndexSpec, keys: np.ndarray) -> base.IndexBuild:
    """THE index construction entry point: validate, then build.

    Bit-identical to calling the registered builder directly with the
    same (defaults-filled) hyperparameters — validation adds checks, not
    behavior (pinned by tests/test_spec.py).  The validated spec rides
    in ``meta["spec"]`` so downstream consumers (serving registry,
    mutable compaction) stay spec-addressable without re-deriving it.
    """
    spec = spec.validated()
    kwargs = dict(spec.hyper)
    if spec.last_mile is not None:
        kwargs["last_mile"] = spec.last_mile
    b = base.REGISTRY[spec.index](np.asarray(keys), **kwargs)
    b.meta["spec"] = spec
    return b


# ---------------------------------------------------------------------------
# Generated ladders
# ---------------------------------------------------------------------------
def stride_sample(seq: Sequence, k: Optional[int]) -> List:
    """At most ``k`` elements spread evenly across ``seq``, ALWAYS
    including both ends when ``k >= 2`` — the fix for the historical
    ``ladder[:k]`` truncation that only ever saw the small-size end."""
    if k is None or k <= 0 or k >= len(seq):
        return list(seq)
    idx = np.unique(np.round(np.linspace(0, len(seq) - 1, k)).astype(int))
    return [seq[i] for i in idx]


def spec_ladder(index: str, max_configs: Optional[int] = None,
                backend: str = "jnp",
                last_mile: Optional[str] = None) -> List[IndexSpec]:
    """The index's CDFShop ladder as validated `IndexSpec`s, smallest to
    largest size, stride-sampled to ``max_configs`` rungs (both size
    extremes kept)."""
    schema = get_schema(index)
    return [IndexSpec(index, dict(r), backend=backend,
                      last_mile=last_mile).validated()
            for r in stride_sample(schema.ladder, max_configs)]


# ---------------------------------------------------------------------------
# The budget tuner
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Candidate:
    """One evaluated rung: the spec, its build cost metrics, the
    `analysis.cost_ns` latency proxy, and the objective ``score`` the
    search actually ranks on (== ``cost_ns`` unless a Tuner ``objective``
    rescored it)."""

    spec: IndexSpec
    size_bytes: int
    cost_ns: float
    metrics: Dict[str, Any]
    score: Optional[float] = None

    def __post_init__(self):
        if self.score is None:
            object.__setattr__(self, "score", float(self.cost_ns))


@dataclasses.dataclass
class TuneResult:
    spec: IndexSpec                   # chosen spec, backend resolved
    build: base.IndexBuild            # the chosen build (reusable as-is)
    frontier: List[Candidate]         # Pareto front over (size, score)
    evaluated: List[Candidate]        # every rung the search touched
    backend_ns: Dict[str, float]      # measured ns/lookup per backend
    max_bytes: Optional[int]
    target_ns: Optional[float]
    chosen: Optional[Candidate] = None   # the winning Candidate record


@dataclasses.dataclass(frozen=True)
class Tuner:
    """Budget-driven spec search over the schema-generated ladders.

    Budget semantics (DESIGN.md §12.3):

    - ``max_bytes`` — HARD cap on `IndexBuild.size_bytes`.  Candidates
      over it are discarded; if nothing fits, `BudgetError`.
    - ``target_ns`` — soft per-lookup goal on the `analysis.cost_ns`
      proxy: among candidates meeting it the SMALLEST wins (the paper's
      "smallest index that is fast enough"); if none meet it, the
      fastest feasible candidate wins.
    - neither — pure proxy-latency minimization under no size cap.

    Backend selection: with one entry in ``backends`` it is simply
    written into the chosen spec; with several, the winner's compiled
    lookup is *measured* per backend on the probe queries and the
    fastest backend wins (kernels run in interpret mode off-TPU, so the
    measurement is honest about what this host would serve with).
    """

    names: Optional[Sequence[str]] = None     # default: sweep_names()
    max_bytes: Optional[int] = None
    target_ns: Optional[float] = None
    backends: Sequence[str] = ("jnp",)
    max_configs: Optional[int] = None         # stride-cap rungs per index
    n_queries: int = 2048                     # probe queries when not given
    seed: int = 0
    repeats: int = 2                          # timing repeats per backend
    #: measured/proxy cost rescale before ranking: None (trust proxy),
    #: a scalar applied to every family, or {index_name: ratio} from
    #: `obs.profiler`'s ``cost_model_ratio`` (satellite of DESIGN.md §17)
    calibration: Any = None
    #: optional workload-aware objective (duck-typed, see
    #: `repro.autotune.objective.WorkloadObjective`): ``queries(keys)``
    #: may supply the probe stream, ``score(spec, metrics, widths)``
    #: replaces the ranking scalar.  None = classic mean-cost proxy.
    objective: Any = None

    def tune(self, keys: np.ndarray,
             queries: Optional[np.ndarray] = None) -> TuneResult:
        import jax
        import jax.numpy as jnp

        keys = np.asarray(keys, dtype=np.uint64)
        names = tuple(self.names) if self.names is not None else sweep_names()
        for be in self.backends:
            if be not in BACKENDS:
                raise SpecError(f"unknown backend {be!r}; one of {BACKENDS}")
        if queries is not None:
            q = np.asarray(queries, dtype=np.uint64)
        else:
            q = None
            if self.objective is not None and \
                    hasattr(self.objective, "queries"):
                got = self.objective.queries(keys)
                if got is not None:
                    q = np.asarray(got, dtype=np.uint64)
            if q is None:
                q = self._probe_queries(keys)
        q_jnp = jnp.asarray(q)

        evaluated: List[Candidate] = []
        for name in names:
            for sp in spec_ladder(name, max_configs=self.max_configs,
                                  backend=self.backends[0]):
                b = build(sp, keys)
                if b.meta.get("point_only"):
                    raise SpecError(
                        f"{name!r} is point-only: no lower-bound cost "
                        "model — exclude it from Tuner.names")
                lo, hi = b.lookup(b.state, q_jnp)
                widths = np.maximum(
                    np.asarray(hi) - np.asarray(lo) + 1, 1)
                metrics = analysis.describe(b, widths)
                cost = analysis.cost_ns(
                    metrics, calibration=self._calibration_for(name))
                score = cost if self.objective is None else float(
                    self.objective.score(sp, metrics, widths))
                evaluated.append(
                    Candidate(spec=sp, size_bytes=b.size_bytes,
                              cost_ns=cost, metrics=metrics, score=score))
                del b   # keep ONE build alive at a time, not every ladder

        chosen = self._select(evaluated)
        front = set(base.pareto_front(
            [(c.size_bytes, c.score, c.spec.canonical())
             for c in evaluated]))
        frontier = [c for c in evaluated
                    if (c.size_bytes, c.score, c.spec.canonical()) in front]

        # one extra (deterministic, bit-identical) rebuild of the winner
        # is far cheaper than holding the whole search space's state
        chosen_build = build(chosen.spec, keys)
        backend_ns: Dict[str, float] = {}
        best_backend = self.backends[0]
        if len(self.backends) > 1:
            from repro.core import plan as plan_mod
            import time

            p = plan_mod.lower(chosen_build, jnp.asarray(keys))
            for be in self.backends:
                fn = p.compile(backend=be)
                jax.block_until_ready(fn(q_jnp))      # compile + warm
                best = float("inf")
                for _ in range(max(1, self.repeats)):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(q_jnp))
                    best = min(best, time.perf_counter() - t0)
                backend_ns[be] = best / len(q) * 1e9
            best_backend = min(backend_ns, key=backend_ns.get)

        spec = chosen.spec.replace(backend=best_backend)
        chosen_build.meta["spec"] = spec
        return TuneResult(spec=spec, build=chosen_build, frontier=frontier,
                          evaluated=evaluated, backend_ns=backend_ns,
                          max_bytes=self.max_bytes, target_ns=self.target_ns,
                          chosen=chosen)

    def tune_shards(self, keys: np.ndarray, offsets: Sequence[int],
                    queries: Optional[np.ndarray] = None
                    ) -> List[TuneResult]:
        """Tune each contiguous key-range slice independently.

        ``offsets`` is the ShardTopology offset vector (len S+1).  Each
        shard's ladder search sees only its slice — per-shard models get
        tighter error bounds for the same byte budget because each slice
        is a narrower, easier distribution (the RMI root-model idea one
        level up).  A per-shard ``max_bytes`` of ``self.max_bytes / S``
        keeps the summed footprint inside the original budget.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        offs = [int(o) for o in offsets]
        s_eff = len(offs) - 1
        per = None if self.max_bytes is None else max(
            1, self.max_bytes // s_eff)
        sub = dataclasses.replace(self, max_bytes=per)
        q = None if queries is None else np.asarray(queries, dtype=np.uint64)
        results: List[TuneResult] = []
        for s in range(s_eff):
            sl = keys[offs[s]:offs[s + 1]]
            qs = None
            if q is not None:
                in_range = q[(q >= sl[0]) & (q <= sl[-1])]
                qs = in_range if in_range.size >= 64 else None
            results.append(sub.tune(sl, queries=qs))
        return results

    # -- internals -------------------------------------------------------
    def _calibration_for(self, index: str) -> float:
        """Resolve the measured/proxy rescale for one index family."""
        if self.calibration is None:
            return 1.0
        if isinstance(self.calibration, (int, float)):
            return float(self.calibration)
        return float(self.calibration.get(index, 1.0))

    def _probe_queries(self, keys: np.ndarray) -> np.ndarray:
        """Mixed present/absent probe stream (seeded; no repro.data
        dependency — the spec layer sits below the dataset layer)."""
        rng = np.random.default_rng(self.seed)
        m = min(self.n_queries, max(64, len(keys)))
        present = keys[rng.integers(0, len(keys), m // 2)]
        absent = rng.integers(int(keys[0]), max(int(keys[-1]),
                                                int(keys[0]) + 1),
                              m - m // 2, dtype=np.uint64)
        return np.concatenate([present, absent])

    def _select(self, cands: List[Candidate]) -> Candidate:
        feasible = [c for c in cands
                    if self.max_bytes is None
                    or c.size_bytes <= self.max_bytes]
        if not feasible:
            raise BudgetError(
                f"no spec fits max_bytes={self.max_bytes} "
                f"(smallest candidate: "
                f"{min(c.size_bytes for c in cands)} bytes)")
        if self.target_ns is not None:
            fast = [c for c in feasible if c.score <= self.target_ns]
            if fast:
                return min(fast, key=lambda c: (c.size_bytes, c.score))
        return min(feasible, key=lambda c: (c.score, c.size_bytes))
