"""Learned index structures (the paper's contribution) as JAX modules.

Implements the paper's §2 abstraction: an index over a sorted array ``D`` is a
map ``I: key -> (lo, hi)`` whose bound always contains
``LB(x) = lower_bound(x)``, followed by a last-mile search inside the bound.

64-bit integer keys require float64 model math (the paper's own
implementations "transform query keys to 64-bit floats"), so importing this
package enables jax x64 mode.  The LM model/serving/launch packages never
import ``repro.core`` — their dtype discipline (bf16/f32) is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.base import (  # noqa: E402
    IndexBuild,
    SearchBound,
    lower_bound_oracle,
    REGISTRY,
    register,
    get_index,
)
from repro.core import spec  # noqa: E402  (schemas register below)
from repro.core import rmi, radix_spline, pgm, btree, rbs, hashmap  # noqa: E402,F401
from repro.core import plan, search, validate, tuning, analysis  # noqa: E402,F401
from repro.core.plan import LookupPlan, lower  # noqa: E402
from repro.core.spec import IndexSpec, Tuner  # noqa: E402

__all__ = [
    "IndexBuild",
    "IndexSpec",
    "LookupPlan",
    "SearchBound",
    "Tuner",
    "lower",
    "lower_bound_oracle",
    "REGISTRY",
    "register",
    "get_index",
    "spec",
]
