"""PGM index (paper §3.3, Ferragina & Vinciguerra [13]).

Bottom-up recursion of error-bounded piecewise linear regressions: level 0
covers the data with error <= eps; each higher level is a PLA over the
anchor keys of the level below with error <= eps_internal, until a level
fits under ``top_cutoff`` segments (searched with one vector rank count).

Lookup descends: at each level the PLA predicts the position of the query's
segment in the level below within a static window, and a vectorized
upper-bound search inside the window pins the exact segment.

Validity note: the cone guarantees |pred - rank| <= eps only at FIT points;
a query just below a segment boundary can see extra overshoot (the violator
point that closed the segment is not covered by the segment's model).  We
therefore compute each level's TRUE worst-case error at build time — every
fit point evaluated under its own segment AND (for segment-opening points)
under the previous segment — and use that (+1 for inter-key gaps, see
DESIGN.md §2) as the static window.  eps keeps its paper role: it controls
segmentation granularity; the verified window is what makes lookups valid
for every integer query.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import base, _pla, search, spec

spec.register_schema(
    "pgm",
    fields=[
        spec.HyperField("eps", int, 64, lo=1, hi=1 << 20),
        spec.HyperField("eps_internal", int, 8, lo=1, hi=1 << 20),
        spec.HyperField("top_cutoff", int, 64, lo=1, hi=1 << 16),
    ],
    # smallest -> largest size: eps controls segment count inversely
    ladder=[dict(eps=e) for e in (2048, 1024, 512, 256, 128, 64, 32, 16, 8)],
)


def _level_error(ax, ay, sl, xs, ys) -> int:
    """Worst |pred - rank| of a PLA level over its own fit points, including
    each segment-opening point evaluated under the PREVIOUS segment (the
    overshoot a query approaching the boundary from below can see)."""
    seg = np.clip(np.searchsorted(ax, xs, side="right") - 1, 0, len(ax) - 1)
    pred = ay[seg] + sl[seg] * (xs - ax[seg])
    err = np.abs(pred - ys).max()
    opener = (xs == ax[seg]) & (seg > 0)
    if opener.any():
        sprev = seg[opener] - 1
        pred_b = ay[sprev] + sl[sprev] * (xs[opener] - ax[sprev])
        err = max(err, np.abs(pred_b - ys[opener]).max())
    return int(np.ceil(err))


@base.register("pgm")
def build(
    keys: np.ndarray,
    eps: int = 64,
    eps_internal: int = 8,
    top_cutoff: int = 64,
    last_mile: str = "binary",
) -> base.IndexBuild:
    keys = np.asarray(keys)
    n = len(keys)
    x = base.np_keys_to_f64(keys)
    y = np.arange(n, dtype=np.float64)
    xu, y_first, span = _pla.group_rounded(x, y)

    levels = []  # bottom -> top: (anchor_x, anchor_y, slope, verified_err)
    ax, ay, sl = _pla.shrinking_cone(xu, y_first, float(eps))
    levels.append((ax, ay, sl, _level_error(ax, ay, sl, xu, y_first)))
    while len(levels[-1][0]) > top_cutoff:
        lx = levels[-1][0]
        ly = np.arange(len(lx), dtype=np.float64)
        a2, y2, s2 = _pla.shrinking_cone(lx, ly, float(eps_internal))
        levels.append((a2, y2, s2, _level_error(a2, y2, s2, lx, ly)))

    jl = [(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)) for (a, b, c, _) in levels]
    errs = [e + 1 for (_, _, _, e) in levels]  # +1: inter-key gap safety
    state = {"levels": jl}
    size = sum(base.nbytes(a, b, c) for (a, b, c, _) in levels)
    n_top = len(levels[-1][0])
    depth = len(levels)
    e0 = errs[0] + span
    max_err = 2 * e0 + 2

    def lookup(state, q) -> base.SearchBound:
        qf = q.astype(jnp.float64)
        lv = state["levels"]
        # top level: one vector rank count over <= top_cutoff anchors
        top_x = lv[-1][0]
        seg = jnp.sum(top_x[None, :] <= qf[:, None], axis=-1).astype(jnp.int64) - 1
        seg = jnp.clip(seg, 0, n_top - 1)
        # descend
        for lvl in range(depth - 1, 0, -1):
            axl, ayl, sll = lv[lvl]
            e = errs[lvl]
            pred = jnp.take(ayl, seg) + jnp.take(sll, seg) * (qf - jnp.take(axl, seg))
            below_x = lv[lvl - 1][0]
            m = below_x.shape[0]
            pred = jnp.clip(pred, -1.0, float(m) + 1.0)  # guard int overflow
            lo = jnp.clip(jnp.floor(pred).astype(jnp.int64) - e, 0, m - 1)
            hi = jnp.clip(jnp.ceil(pred).astype(jnp.int64) + e, 0, m - 1)
            # segment = last anchor <= q  (upper_bound - 1)
            ub = search.bounded_binary(below_x, qf, lo, hi, 2 * e + 3, side="right")
            seg = jnp.clip(ub - 1, 0, m - 1)
        # level 0 predicts the data position
        ax0, ay0, sl0 = lv[0]
        pred = jnp.take(ay0, seg) + jnp.take(sl0, seg) * (qf - jnp.take(ax0, seg))
        pred = jnp.clip(pred, -1.0, float(n) + 1.0)  # guard int overflow
        lo = jnp.floor(pred).astype(jnp.int64) - e0
        hi = jnp.ceil(pred).astype(jnp.int64) + e0
        return base.clip_bound(lo, hi, n)

    return base.IndexBuild(
        name="pgm",
        state=state,
        lookup=lookup,
        size_bytes=size,
        hyper=dict(eps=eps, eps_internal=eps_internal, top_cutoff=top_cutoff,
                   last_mile=last_mile),
        meta={"max_err": max_err, "levels": depth, "n": n,
              "segments": len(levels[0][0])},
    )
