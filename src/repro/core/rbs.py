"""Radix binary search (paper §4.1.1 baseline, from SOSD [17]).

Stores only the radix table of the RS approach: table[p] = LB of the first
key with prefix p.  Lookup = one shift + two table gathers.  Exhibits the
paper's face-dataset failure mode: top-end outliers inflate the key range,
making the fixed prefix bits nearly useless.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import base, spec

spec.register_schema(
    "rbs",
    fields=[spec.HyperField("radix_bits", int, 16, lo=1, hi=28)],
    # smallest -> largest size: the table is 2^radix_bits entries
    ladder=[dict(radix_bits=r) for r in (6, 8, 10, 12, 14, 16, 18, 20, 22)],
)

spec.register_schema(
    "binary_search",
    fields=[],
    ladder=[dict()],
)


@base.register("rbs")
def build(
    keys: np.ndarray,
    radix_bits: int = 16,
    last_mile: str = "binary",
) -> base.IndexBuild:
    keys = np.asarray(keys)
    n = len(keys)
    kmin = np.uint64(keys[0])
    key_range = int(keys[-1]) - int(keys[0])
    sig_bits = max(1, key_range.bit_length())
    r = int(min(radix_bits, sig_bits))
    shift = sig_bits - r

    prefixes = ((keys - kmin) >> np.uint64(shift)).astype(np.int64)
    table = np.searchsorted(prefixes, np.arange((1 << r) + 1), side="left")
    table = table.astype(np.int64)
    max_gap = int(np.max(table[1:] - table[:-1]))

    state = {"table": jnp.asarray(table), "kmin": jnp.uint64(kmin)}
    size = base.nbytes(table)

    def lookup(state, q) -> base.SearchBound:
        qi = q.astype(jnp.uint64)
        delta = jnp.where(qi > state["kmin"], qi - state["kmin"], jnp.uint64(0))
        p = jnp.clip((delta >> shift).astype(jnp.int64), 0, (1 << r) - 1)
        lo = jnp.take(state["table"], p)
        hi = jnp.take(state["table"], p + 1)
        return base.clip_bound(lo, hi, n)

    return base.IndexBuild(
        name="rbs",
        state=state,
        lookup=lookup,
        size_bytes=size,
        hyper=dict(radix_bits=r, last_mile=last_mile),
        meta={"max_err": max_gap + 1, "levels": 1, "n": n},
    )


@base.register("binary_search")
def build_bs(keys: np.ndarray, last_mile: str = "binary") -> base.IndexBuild:
    """The paper's BS baseline: size zero, bound = whole array."""
    keys = np.asarray(keys)
    n = len(keys)

    def lookup(state, q) -> base.SearchBound:
        z = jnp.zeros(q.shape, jnp.int64)
        return z, jnp.full(q.shape, n, jnp.int64)

    return base.IndexBuild(
        name="binary_search",
        state={},
        lookup=lookup,
        size_bytes=0,
        hyper=dict(last_mile=last_mile),
        meta={"max_err": n + 1, "levels": 0, "n": n},
    )
