"""Validity checking (paper §2): every bound must contain LB(x)."""
from __future__ import annotations

from typing import Dict

import numpy as np
import jax.numpy as jnp

from repro.core import base, search


def check_bounds(build: base.IndexBuild, keys: np.ndarray, queries: np.ndarray) -> Dict:
    """Verify lo <= LB(q) <= hi for every query; report bound-width stats."""
    lb = base.lower_bound_oracle(keys, queries)
    lo, hi = build.lookup(build.state, jnp.asarray(queries))
    lo = np.asarray(lo)
    hi = np.asarray(hi)
    ok = (lo <= lb) & (lb <= hi)
    width = np.maximum(hi - lo + 1, 1)
    return {
        "valid": bool(ok.all()),
        "frac_valid": float(ok.mean()),
        "max_width": int(width.max()),
        "avg_width": float(width.mean()),
        "log2_err": float(np.mean(np.log2(width))),
        "n_bad": int((~ok).sum()),
        "bad_idx": np.flatnonzero(~ok)[:8],
    }


def check_end_to_end(
    build: base.IndexBuild,
    keys: np.ndarray,
    queries: np.ndarray,
    last_mile: str = "binary",
) -> Dict:
    """Full lookup (index + last-mile) must produce LB(q) exactly."""
    lb = base.lower_bound_oracle(keys, queries)
    data = jnp.asarray(keys)
    q = jnp.asarray(queries)
    lo, hi = build.lookup(build.state, q)
    fn = search.SEARCH_FNS[last_mile]
    got = np.asarray(fn(data, q, lo, hi, build.meta["max_err"]))
    ok = got == lb
    return {
        "exact": bool(ok.all()),
        "frac_exact": float(ok.mean()),
        "n_bad": int((~ok).sum()),
    }
