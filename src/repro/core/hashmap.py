"""Robin Hood hash table (paper §4.1.1 hashing baseline, point lookups only).

TPU adaptation: Robin Hood with linear probing stores keys sorted by home
slot, which lets the whole layout be computed VECTORIZED at build time
(pos_i = max(home_i, pos_{i-1}+1) is a running max — one np.maximum.accumulate)
and lets lookups gather a static-width probe window (max displacement + 1)
and resolve membership with one vector compare — no probe loop.

Like the paper's hash baselines: no lower-bound/range support, full key
storage, evaluated for point lookups in Table 2 / Fig. 16 analogues.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import base, spec

spec.register_schema(
    "robin_hash",
    fields=[spec.HyperField("load_factor", float, 0.5, lo=0.05, hi=0.95)],
    # smallest -> largest size: higher load factor = denser table
    ladder=[dict(load_factor=f) for f in (0.8, 0.5, 0.25)],
    sweep=False,
    sweep_exclude_reason=(
        "point-only: no lower-bound semantics, so it has no place on the "
        "size x LB-latency Pareto sweep (paper §4.1.1); benchmarks time it "
        "separately as the Table 2 hash companion"),
)

_MULT = np.uint64(0x9E3779B97F4A7C15)


def _hash(k, bits: int):
    # multiply-shift; keys are uint64
    return (k * _MULT) >> np.uint64(64 - bits)


def _hash_jnp(k, bits: int):
    return (k * jnp.uint64(0x9E3779B97F4A7C15)) >> jnp.uint64(64 - bits)


@base.register("robin_hash")
def build(keys: np.ndarray, load_factor: float = 0.5, **_) -> base.IndexBuild:
    keys = np.asarray(keys).astype(np.uint64)
    n = len(keys)
    bits = max(1, int(np.ceil(np.log2(max(2, n / load_factor)))))
    m = 1 << bits

    with np.errstate(over="ignore"):
        home = _hash(keys, bits).astype(np.int64)
    order = np.argsort(home, kind="stable")
    home_s = home[order]
    # Robin Hood layout: pos_i = max(home_i, pos_{i-1} + 1), vectorized.
    g = home_s - np.arange(n)
    pos = np.maximum.accumulate(g) + np.arange(n)
    max_disp = int((pos - home_s).max())
    table_len = int(pos[-1]) + 1

    slot_key = np.full(table_len, np.uint64(0xFFFFFFFFFFFFFFFF), np.uint64)
    slot_val = np.full(table_len, -1, np.int64)  # payload = position in D
    slot_key[pos] = keys[order]
    slot_val[pos] = order

    state = {"slot_key": jnp.asarray(slot_key), "slot_val": jnp.asarray(slot_val)}
    size = base.nbytes(slot_key, slot_val)
    W = max_disp + 1

    def lookup(state, q):
        """Point lookup: returns (found[B] bool, position[B] int64)."""
        qk = q.astype(jnp.uint64)
        with np.errstate(over="ignore"):
            home = _hash_jnp(qk, bits).astype(jnp.int64)
        idx = home[:, None] + jnp.arange(W, dtype=jnp.int64)[None, :]
        kwin = jnp.take(state["slot_key"], jnp.clip(idx, 0, table_len - 1), mode="clip")
        vwin = jnp.take(state["slot_val"], jnp.clip(idx, 0, table_len - 1), mode="clip")
        hit = kwin == qk[:, None]
        found = jnp.any(hit, axis=-1)
        first = jnp.argmax(hit, axis=-1)
        val = jnp.take_along_axis(vwin, first[:, None], axis=-1)[:, 0]
        return found, jnp.where(found, val, -1)

    return base.IndexBuild(
        name="robin_hash",
        state=state,
        lookup=lookup,
        size_bytes=size,
        hyper=dict(load_factor=load_factor, probe_window=W),
        meta={"max_err": 0, "levels": 1, "n": n, "point_only": True},
    )
