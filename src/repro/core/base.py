"""Common abstractions for index structures (paper §2).

An index structure maps a lookup key to a search bound ``(lo, hi)`` that must
contain ``LB(x)``, the smallest index i with ``D[i] >= x`` (C++
``lower_bound`` semantics, matching the paper's formal definition).  ``hi`` is
inclusive here: valid means ``lo <= LB(x) <= hi``.

Every concrete index provides:

  build(keys, **hyper) -> state        (numpy, host-side, one-time)
  lookup(state, queries) -> (lo, hi)   (pure jnp, vectorized over queries)
  size_bytes(state) -> int             (paper's "size" axis)

``state`` is a pytree of jnp arrays so ``lookup`` jits/shards cleanly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax.numpy as jnp
import numpy as np

Array = Any
SearchBound = Tuple[Array, Array]  # (lo, hi) int64 arrays, hi inclusive


@dataclasses.dataclass(frozen=True)
class IndexBuild:
    """A built index: state pytree + the functions that interpret it."""

    name: str
    state: Any
    lookup: Callable[[Any, Array], SearchBound]
    size_bytes: int
    hyper: Dict[str, Any]
    # Descriptive stats filled by analysis.describe(); None until then.
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Registry: name -> build function, used by tuning sweeps and benchmarks.
# ---------------------------------------------------------------------------
REGISTRY: Dict[str, Callable[..., IndexBuild]] = {}


def register(name: str):
    def deco(fn):
        REGISTRY[name] = fn
        return fn

    return deco


def get_index(name: str) -> Callable[..., IndexBuild]:
    return REGISTRY[name]


# ---------------------------------------------------------------------------
# Oracle + shared helpers
# ---------------------------------------------------------------------------
def lower_bound_oracle(keys: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Ground-truth LB(x) (numpy, host side)."""
    return np.searchsorted(keys, queries, side="left")


def keys_to_f64(keys) -> Array:
    """uint64 keys -> float64 model inputs (paper: 'transform query keys to
    64-bit floats').  Precision loss above 2^53 is absorbed by error bounds:
    builders compute their error terms against the SAME f64-rounded keys the
    lookup path sees."""
    return jnp.asarray(keys).astype(jnp.float64)


def np_keys_to_f64(keys: np.ndarray) -> np.ndarray:
    return keys.astype(np.float64)


def clip_bound(lo, hi, n: int) -> SearchBound:
    lo = jnp.clip(lo, 0, n).astype(jnp.int64)
    hi = jnp.clip(hi, 0, n).astype(jnp.int64)
    return lo, hi


def nbytes(*arrays) -> int:
    total = 0
    for a in arrays:
        a = np.asarray(a)
        total += a.nbytes
    return total


def pareto_front(points):
    """points: list of (size_bytes, latency_ns, tag). Returns the subset not
    dominated by any other point (smaller size AND lower latency)."""
    out = []
    for p in points:
        dominated = any(
            (q[0] <= p[0] and q[1] < p[1]) or (q[0] < p[0] and q[1] <= p[1])
            for q in points
        )
        if not dominated:
            out.append(p)
    return sorted(out)
