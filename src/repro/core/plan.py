"""`LookupPlan` IR: one lowering target for every index (DESIGN.md §11).

The paper's central observation (§5) is that every competitive index —
learned or not — reduces to the same two-phase shape: *predict a
position, then bounded last-mile search*.  This module makes that shape
an explicit, inspectable value instead of a per-index closure:

    IndexBuild --lower()--> LookupPlan(bounds, data, last_mile)
                                |.compile(backend)         -> q -> LB ranks
                                |.compile_scan(m)          -> q -> (LB, window)
                                |.compile_merged()         -> (q, delta) -> merged LB
                                |.compile_merged_scan(m)   -> (q, delta) -> merged (LB, window)
                                |.compile_instrumented()   -> (q, n_valid) -> (LB, health stats)
                                |.compile_instrumented_merged()
                                                           -> (q, n_valid, delta) -> (LB, stats)

A plan is a `bounds` stage — the index's state pytree, a pure predict
function ``(state, q) -> (lo, hi)`` with ``hi`` inclusive, and the
static window bound ``max_err`` (``hi - lo + 1 <= max_err`` with
``LB in [lo, hi]``) — composed with a last-mile stage executed by a
pluggable backend:

  ``"jnp"``     the vectorized `repro.core.search.SEARCH_FNS` searches,
                bit-identical to the historical fused pipeline;
  ``"pallas"``  the tile-binned `kernels/bounded_search` kernel consuming
                the plan's bounds (any index), or — where an index
                registers one — a fused whole-plan kernel executor
                (`kernels/rmi_lookup` for RMI).  On CPU the kernels run
                in interpret mode, so both backends execute everywhere.

Both backends return the exact lower-bound rank, so they are
bit-identical for every plan (pinned by tests/test_plan.py across the
full index x dataset x last-mile matrix).

Every consumer goes through plans: `core.search.fused_lookup_fn` is a
thin ``lower(...).compile(...)`` shim, the serving registry publishes
`Generation`s carrying their plan, the mutable layer's delta rank
correction and the range-scan materialization are plan transforms
(`compile_merged*`), and the benchmark matrix selects backends through
the same seam (`benchmarks/_common.full_lookup_fn`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import base, search
from repro.obs.health import HEALTH_DISP_BUCKETS, HEALTH_TRAFFIC_BUCKETS

__all__ = ["BACKENDS", "BoundsStage", "LookupPlan", "health_stats_expr",
           "lower", "pack_health_stats", "register_fused",
           "FUSED_LOWERERS"]

#: The backend axis every lookup consumer can select on.
BACKENDS = ("jnp", "pallas")

#: index name -> (plan, interpret) -> fn(q) -> positions.  A fused
#: executor replaces the whole predict+search pipeline with one kernel
#: path; registered per index family, used by backend="pallas".
FUSED_LOWERERS: Dict[str, Callable] = {}


def register_fused(name: str):
    def deco(fn):
        FUSED_LOWERERS[name] = fn
        return fn

    return deco


@dataclasses.dataclass(frozen=True)
class BoundsStage:
    """The predict half of a plan.

    ``predict(state, q) -> (lo, hi)`` must be pure jnp (jit/shard-safe),
    with ``hi`` inclusive, ``lo <= LB(q) <= hi`` for every uint64 query
    (the §2 validity contract), and ``hi - lo + 1 <= max_err`` with
    ``max_err`` static — the error guarantee that fixes last-mile trip
    counts and kernel window widths.  Point-only indexes (robin_hash)
    instead return ``(found, pos)`` and set ``max_err = 0``.
    """

    state: Any
    predict: Callable[[Any, base.Array], base.SearchBound]
    max_err: int


def _window_gather(data, pos, m: int):
    """[B] start positions -> [B, m] record window, one static gather.

    Past-the-end lanes hold the dtype's max value (for uint64 keys:
    UINT64_MAX, the same sentinel the delta buffer pads with) so windows
    of different plans merge by plain sort.
    """
    n = data.shape[0]
    sentinel = jnp.asarray(jnp.iinfo(data.dtype).max, data.dtype)
    idx = pos[:, None] + jnp.arange(m, dtype=pos.dtype)[None, :]
    oob = idx >= n
    window = jnp.take(data, jnp.clip(idx, 0, n - 1), mode="clip")
    return jnp.where(oob, sentinel, window)


def _cum_bucket_hist(vals, edges, valid):
    """Bucket counts WITHOUT a scatter: count ``vals >= edge`` per edge
    (a [B, E] comparison reduced over lanes), then difference the
    cumulative counts.  Identical integer counts to ``.at[idx].add`` —
    XLA lowers the comparisons to vector code where a CPU/TPU scatter
    serializes — and invalid lanes are masked out of every column."""
    c = jnp.sum((vals[:, None] >= edges[None, :]) & valid[:, None],
                axis=0, dtype=jnp.int32)
    total = jnp.sum(valid, dtype=jnp.int32)
    cext = jnp.concatenate([total[None], c, jnp.zeros(1, jnp.int32)])
    return cext[:-1] - cext[1:]


def health_stats_expr(pos, lo, hi, n: int, max_err: int, n_valid,
                      point_only: bool = False):
    """Fixed-size device reductions for the health monitor (DESIGN.md §15).

    ``pos`` is the [B] int64 result lanes, ``(lo, hi)`` the bounds-stage
    window (ignored when ``point_only``), ``n_valid`` a dynamic int32
    scalar masking out pad lanes so dispatcher padding never pollutes the
    statistics.  Everything returned is O(buckets): a log2
    prediction-displacement histogram (bucket 0 = exact hit, bucket j =
    ``[2^(j-1), 2^j)``, last bucket overflows — `obs.health` owns the
    geometry), a rank-quantized traffic histogram (bucket ``r*K//n``,
    realized as cumulative counts against the ceil rank edges — the
    same integer partition), and scalar sums for mean displacement /
    bound width / last-mile steps.  Displacement, width, and rank are
    narrowed to int32 when ``n`` permits — they are bounded by ``n`` —
    which halves the comparison bandwidth on the hot path.
    """
    B = pos.shape[0]
    K = HEALTH_TRAFFIC_BUCKETS
    lane = jnp.arange(B, dtype=jnp.int32) < n_valid
    dt = jnp.int32 if int(n) < 2 ** 31 else jnp.int64
    if point_only:
        valid = lane & (pos >= 0)
        disp = jnp.zeros(B, dt)
        width = jnp.where(valid, 1, 0).astype(dt)
        steps = jnp.zeros(B, dt)
    else:
        valid = lane
        lo_n, hi_n = lo.astype(dt), hi.astype(dt)
        mid = lo_n + (hi_n - lo_n) // 2
        disp = jnp.where(valid, jnp.abs(pos.astype(dt) - mid), 0)
        width = jnp.where(valid, hi_n - lo_n + 1, 0)
        # binary-search trip count over the bound: ceil(log2(width))
        s_edges = jnp.asarray(
            [1 << j for j in range(max(1, int(max_err).bit_length()))], dt)
        steps = jnp.where(
            valid,
            jnp.sum(width[:, None] > s_edges[None, :], axis=1,
                    dtype=jnp.int32), 0).astype(dt)
    d_edges = jnp.asarray(
        [1 << j for j in range(HEALTH_DISP_BUCKETS - 1)], dt)
    disp_hist = _cum_bucket_hist(disp, d_edges, valid)
    rank = jnp.clip(pos, 0, n - 1).astype(dt)
    # rank r is in traffic bucket r*K//n  <=>  r >= ceil(j*n/K) for
    # exactly (bucket index + 1) edges j — cumulative form of the same
    # partition
    t_edges = jnp.asarray(
        [(j * int(n) + K - 1) // K for j in range(1, K)], dt)
    traffic_hist = _cum_bucket_hist(rank, t_edges, valid)
    return {
        "n": jnp.sum(valid.astype(jnp.int32)),
        "disp_hist": disp_hist,
        "traffic_hist": traffic_hist,
        "disp_sum": jnp.sum(disp.astype(jnp.int64)),
        "disp_max": jnp.max(disp).astype(jnp.int64),
        "width_sum": jnp.sum(width.astype(jnp.int64)),
        "steps_sum": jnp.sum(steps.astype(jnp.int64)),
    }


def pack_health_stats(stats) -> Any:
    """Flatten one stats dict to a single int64 vector (the layout
    `repro.obs.health.unpack_stats` reverses): 5 scalars, then the two
    histograms.  One device array per batch means ONE host transfer in
    the completion path instead of seven."""
    scalars = jnp.stack([
        stats["n"].astype(jnp.int64), stats["disp_sum"],
        stats["disp_max"], stats["width_sum"], stats["steps_sum"]])
    return jnp.concatenate([scalars,
                            stats["disp_hist"].astype(jnp.int64),
                            stats["traffic_hist"].astype(jnp.int64)])


@dataclasses.dataclass(frozen=True, eq=False)
class LookupPlan:
    """One index lowered to predict -> bounded-search, backend-agnostic."""

    name: str
    bounds: BoundsStage
    data: Any                  # jnp device copy of the sorted keys
    n: int
    last_mile: str = "binary"
    point_only: bool = False
    fused: Optional[Callable] = None   # whole-plan kernel executor factory
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # compiled-fn cache: (kind, backend, interpret, ...) -> jitted callable.
    # Keyed per plan instance so repeated dispatch (registry generations,
    # sharded batches) reuses one compiled program per shape bucket.
    _cache: Dict[Any, Any] = dataclasses.field(
        default_factory=dict, repr=False)

    # -- expression builders (pure, un-jitted — composable in transforms) --
    def lb_expr(self, backend: str = "jnp", interpret: bool = False,
                fused: Optional[bool] = None) -> Callable:
        """``q -> int64 LB ranks`` as a pure expression.

        ``fused=None`` uses the registered whole-plan kernel when the
        backend is pallas and the index has one; ``fused=False`` forces
        the generic bounds->`lower_bound_windows` path (parity tests
        exercise both).
        """
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        if self.point_only:
            predict, state = self.bounds.predict, self.bounds.state

            def run_point(q):
                found, pos = predict(state, q)
                return jnp.where(found, pos, -1).astype(jnp.int64)

            return run_point

        predict, state = self.bounds.predict, self.bounds.state
        max_err, data = self.bounds.max_err, self.data

        if backend == "pallas":
            if fused is None:
                fused = self.fused is not None
            if fused:
                if self.fused is None:
                    raise ValueError(
                        f"plan {self.name!r} has no fused kernel executor")
                inner = self.fused(self, interpret)
                return lambda q: inner(q).astype(jnp.int64)

            from repro.kernels.bounded_search.ops import lower_bound_windows

            def run_pallas(q):
                lo, _hi = predict(state, q)
                # window precondition lo <= LB < lo + max_err holds by the
                # bounds contract (LB <= hi <= lo + max_err - 1)
                return lower_bound_windows(
                    data, q, lo, max_width=max_err,
                    interpret=interpret).astype(jnp.int64)

            return run_pallas

        fn = search.SEARCH_FNS[self.last_mile]

        def run_jnp(q):
            lo, hi = predict(state, q)
            return fn(data, q, lo, hi, max_err).astype(jnp.int64)

        return run_jnp

    def merged_expr(self, backend: str = "jnp",
                    interpret: bool = False) -> Callable:
        """Delta rank correction as a plan transform (DESIGN.md §10.2):
        ``(q, delta_padded) -> LB_base(q) + LB_delta(q)``.  Exact because
        base and delta are disjoint sorted sets; the padded delta's
        UINT64_MAX sentinels can never be counted by a lower bound."""
        run = self.lb_expr(backend, interpret)

        def merged(q, delta_padded):
            lb_base = run(q)
            lb_delta = jnp.searchsorted(delta_padded, q, side="left")
            return lb_base + lb_delta.astype(jnp.int64)

        return merged

    def scan_expr(self, m: int, backend: str = "jnp",
                  interpret: bool = False) -> Callable:
        """Range-scan materialization: ``q -> (LB, window[B, m])`` — the
        ``m`` records from ``LB(q)`` as one static-width windowed gather."""
        if self.point_only:
            raise ValueError(f"{self.name!r} is point-only: no scans")
        run = self.lb_expr(backend, interpret)
        data = self.data

        def scan(q):
            pos = run(q)
            return pos, _window_gather(data, pos, m)

        return scan

    def merged_scan_expr(self, m: int, backend: str = "jnp",
                         interpret: bool = False) -> Callable:
        """Scan over the merged (base + delta) view: gather ``m`` from each
        side and keep the first ``m`` of their sorted union — exact because
        the merged array's next ``m`` records are contained in the union of
        the two windows, and both pad with the UINT64_MAX sentinel."""
        if self.point_only:
            raise ValueError(f"{self.name!r} is point-only: no scans")
        run = self.lb_expr(backend, interpret)
        data = self.data

        def scan(q, delta_padded):
            pos_b = run(q)
            pos_d = jnp.searchsorted(
                delta_padded, q, side="left").astype(jnp.int64)
            wb = _window_gather(data, pos_b, m).astype(delta_padded.dtype)
            wd = _window_gather(delta_padded, pos_d, m)
            window = jnp.sort(
                jnp.concatenate([wb, wd], axis=-1), axis=-1)[:, :m]
            return pos_b + pos_d, window

        return scan

    def _instr_base_expr(self, backend: str, interpret: bool) -> Callable:
        """``(q, n_valid) -> (LB, lo, hi)`` sharing ONE predict between
        the search and the stats on the generic jnp path (the fused /
        pallas paths keep their own lookup and pay a second jnp predict
        for the stats — still backend-invariant by construction)."""
        predict, state = self.bounds.predict, self.bounds.state
        if backend == "jnp":
            fn = search.SEARCH_FNS[self.last_mile]
            data, max_err = self.data, self.bounds.max_err

            def base_jnp(q):
                lo, hi = predict(state, q)
                pos = fn(data, q, lo, hi, max_err).astype(jnp.int64)
                return pos, lo, hi

            return base_jnp

        run = self.lb_expr(backend, interpret)

        def base_other(q):
            pos = run(q)
            lo, hi = predict(state, q)
            return pos, lo, hi

        return base_other

    def instrumented_expr(self, backend: str = "jnp",
                          interpret: bool = False) -> Callable:
        """``(q, n_valid) -> (LB, packed stats)``: the lookup plus the
        `health_stats_expr` reduction flattened by `pack_health_stats`.

        The positions come from the SAME ops as the uninstrumented
        path — bit-identity holds by construction on every backend; the
        stats derive from the plan's own jnp bounds (not a fused
        kernel's refit state), so they are backend-invariant too.
        ``n_valid`` is a dynamic int32 scalar so one compiled program
        serves every occupancy of a padded batch bucket.
        """
        n, max_err = self.n, self.bounds.max_err
        if self.point_only:
            run = self.lb_expr(backend, interpret)

            def run_point_instr(q, n_valid):
                pos = run(q)
                stats = health_stats_expr(
                    pos, None, None, n, max_err, n_valid, point_only=True)
                return pos, pack_health_stats(stats)

            return run_point_instr

        base = self._instr_base_expr(backend, interpret)

        def run_instr(q, n_valid):
            pos, lo, hi = base(q)
            stats = health_stats_expr(pos, lo, hi, n, max_err, n_valid)
            return pos, pack_health_stats(stats)

        return run_instr

    def instrumented_merged_expr(self, backend: str = "jnp",
                                 interpret: bool = False) -> Callable:
        """``(q, n_valid, delta_padded) -> (merged LB, packed stats)``.
        Stats describe the BASE plan (its model is what health tracks);
        the payload is exactly `merged_expr`'s rank."""
        if self.point_only:
            raise ValueError(
                f"{self.name!r} is point-only: no merged lookups")
        base = self._instr_base_expr(backend, interpret)
        n, max_err = self.n, self.bounds.max_err

        def merged_instr(q, n_valid, delta_padded):
            lb_base, lo, hi = base(q)
            lb_delta = jnp.searchsorted(delta_padded, q, side="left")
            stats = health_stats_expr(lb_base, lo, hi, n, max_err, n_valid)
            return (lb_base + lb_delta.astype(jnp.int64),
                    pack_health_stats(stats))

        return merged_instr

    # -- compiled entry points (cached per plan) ---------------------------
    def _compiled(self, key, make_expr, donate_argnums=()) -> Callable:
        fn = self._cache.get(key)
        if fn is None:
            fn = jax.jit(make_expr(), donate_argnums=donate_argnums)
            self._cache[key] = fn
        return fn

    def compile(self, backend: str = "jnp", interpret: bool = False,
                fused: Optional[bool] = None,
                donate: bool = False) -> Callable:
        """jit'd ``q -> int64 LB ranks`` (the canonical fused lookup).

        ``donate=True`` donates the query buffer to XLA — safe when the
        caller stages each batch into a fresh/reusable device placement
        (the dispatcher does); a no-op with a warning on CPU."""
        # normalize fused before keying the cache: the default (None) and
        # its resolved value must alias to ONE compiled program
        if backend != "pallas" or self.point_only:
            fused = None
        elif fused is None:
            fused = self.fused is not None
        return self._compiled(
            ("lb", backend, interpret, fused, donate),
            lambda: self.lb_expr(backend, interpret, fused),
            donate_argnums=(0,) if donate else ())

    def compile_merged(self, backend: str = "jnp",
                       interpret: bool = False) -> Callable:
        return self._compiled(
            ("merged", backend, interpret),
            lambda: self.merged_expr(backend, interpret))

    def compile_scan(self, m: int, backend: str = "jnp",
                     interpret: bool = False) -> Callable:
        return self._compiled(
            ("scan", int(m), backend, interpret),
            lambda: self.scan_expr(int(m), backend, interpret))

    def compile_merged_scan(self, m: int, backend: str = "jnp",
                            interpret: bool = False) -> Callable:
        return self._compiled(
            ("merged_scan", int(m), backend, interpret),
            lambda: self.merged_scan_expr(int(m), backend, interpret))

    def compile_instrumented(self, backend: str = "jnp",
                             interpret: bool = False,
                             donate: bool = False) -> Callable:
        return self._compiled(
            ("instr", backend, interpret, donate),
            lambda: self.instrumented_expr(backend, interpret),
            donate_argnums=(0,) if donate else ())

    def compile_instrumented_merged(self, backend: str = "jnp",
                                    interpret: bool = False) -> Callable:
        return self._compiled(
            ("instr_merged", backend, interpret),
            lambda: self.instrumented_merged_expr(backend, interpret))

    def build_displacement_quantile(self, q: float = 0.99,
                                    sample: int = 65536) -> float:
        """Displacement quantile of the plan's OWN keys: the build-time
        prediction error level that live traffic is compared against
        (the `disp_p99_ratio` health key).  For key ``keys[i]`` the true
        rank is ``i``, so displacement is ``|i - mid(predict(keys[i]))|``
        — evaluated over an evenly strided sample of up to ``sample``
        keys and cached per plan (one device eval per generation).
        Point-only plans have no prediction window: 0."""
        key = ("build_disp", float(q), int(sample))
        cached = self._cache.get(key)
        if cached is not None:
            return cached
        if self.point_only or self.n == 0:
            self._cache[key] = 0.0
            return 0.0
        idx = np.linspace(0, self.n - 1,
                          min(self.n, int(sample))).astype(np.int64)
        lo, hi = self.bounds.predict(self.bounds.state,
                                     self.data[jnp.asarray(idx)])
        lo = np.asarray(lo).astype(np.int64)
        hi = np.asarray(hi).astype(np.int64)
        mid = lo + (hi - lo) // 2
        val = float(np.quantile(np.abs(idx - mid), q))
        self._cache[key] = val
        return val

    def scan(self, q, m: int, backend: str = "jnp",
             interpret: bool = False):
        """Convenience: materialize ``m`` records from ``LB(q)``."""
        return self.compile_scan(m, backend, interpret)(q)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------
def lower(build: base.IndexBuild, data_jnp,
          last_mile: Optional[str] = None) -> LookupPlan:
    """Lower a built index to its `LookupPlan`.

    The lowering contract is exactly the `IndexBuild` surface: ``lookup``
    is the pure bounds predictor, ``meta["max_err"]`` the static window
    bound.  ``last_mile`` defaults to the hyperparameter the index was
    built with (falling back to binary) — the policy every consumer
    shared before plans existed.
    """
    if last_mile is None:
        last_mile = build.hyper.get("last_mile", "binary")
    n = int(build.meta.get("n", data_jnp.shape[0]))
    bounds = BoundsStage(
        state=build.state,
        predict=build.lookup,
        max_err=int(build.meta.get("max_err", n + 1)),
    )
    return LookupPlan(
        name=build.name,
        bounds=bounds,
        data=data_jnp,
        n=n,
        last_mile=last_mile,
        point_only=bool(build.meta.get("point_only", False)),
        fused=FUSED_LOWERERS.get(build.name),
        meta=dict(build.hyper),
    )


@register_fused("rmi")
def _rmi_fused(plan: LookupPlan, interpret: bool) -> Callable:
    """Whole-plan executor for RMI: the fused f32 inference kernel +
    tiled last-mile search (`kernels/rmi_lookup`).  The f32 state is
    refit from the plan's keys with error tables re-verified through the
    kernel's own arithmetic, so the result is still the exact LB rank —
    bit-identical to every other backend."""
    from repro.kernels.rmi_lookup import ops as rops

    st = plan._cache.get("_rmi_f32_state")
    if st is None:
        st = rops.prepare_f32_state(
            np.asarray(plan.data),
            branching=int(plan.meta.get("branching", 1024)))
        plan._cache["_rmi_f32_state"] = st
    data = plan.data

    def run(q):
        return rops.rmi_lookup(st, data, q, interpret=interpret)

    return run
