"""`LookupPlan` IR: one lowering target for every index (DESIGN.md §11).

The paper's central observation (§5) is that every competitive index —
learned or not — reduces to the same two-phase shape: *predict a
position, then bounded last-mile search*.  This module makes that shape
an explicit, inspectable value instead of a per-index closure:

    IndexBuild --lower()--> LookupPlan(bounds, data, last_mile)
                                |.compile(backend)         -> q -> LB ranks
                                |.compile_scan(m)          -> q -> (LB, window)
                                |.compile_merged()         -> (q, delta) -> merged LB
                                |.compile_merged_scan(m)   -> (q, delta) -> merged (LB, window)

A plan is a `bounds` stage — the index's state pytree, a pure predict
function ``(state, q) -> (lo, hi)`` with ``hi`` inclusive, and the
static window bound ``max_err`` (``hi - lo + 1 <= max_err`` with
``LB in [lo, hi]``) — composed with a last-mile stage executed by a
pluggable backend:

  ``"jnp"``     the vectorized `repro.core.search.SEARCH_FNS` searches,
                bit-identical to the historical fused pipeline;
  ``"pallas"``  the tile-binned `kernels/bounded_search` kernel consuming
                the plan's bounds (any index), or — where an index
                registers one — a fused whole-plan kernel executor
                (`kernels/rmi_lookup` for RMI).  On CPU the kernels run
                in interpret mode, so both backends execute everywhere.

Both backends return the exact lower-bound rank, so they are
bit-identical for every plan (pinned by tests/test_plan.py across the
full index x dataset x last-mile matrix).

Every consumer goes through plans: `core.search.fused_lookup_fn` is a
thin ``lower(...).compile(...)`` shim, the serving registry publishes
`Generation`s carrying their plan, the mutable layer's delta rank
correction and the range-scan materialization are plan transforms
(`compile_merged*`), and the benchmark matrix selects backends through
the same seam (`benchmarks/_common.full_lookup_fn`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import base, search

__all__ = ["BACKENDS", "BoundsStage", "LookupPlan", "lower",
           "register_fused", "FUSED_LOWERERS"]

#: The backend axis every lookup consumer can select on.
BACKENDS = ("jnp", "pallas")

#: index name -> (plan, interpret) -> fn(q) -> positions.  A fused
#: executor replaces the whole predict+search pipeline with one kernel
#: path; registered per index family, used by backend="pallas".
FUSED_LOWERERS: Dict[str, Callable] = {}


def register_fused(name: str):
    def deco(fn):
        FUSED_LOWERERS[name] = fn
        return fn

    return deco


@dataclasses.dataclass(frozen=True)
class BoundsStage:
    """The predict half of a plan.

    ``predict(state, q) -> (lo, hi)`` must be pure jnp (jit/shard-safe),
    with ``hi`` inclusive, ``lo <= LB(q) <= hi`` for every uint64 query
    (the §2 validity contract), and ``hi - lo + 1 <= max_err`` with
    ``max_err`` static — the error guarantee that fixes last-mile trip
    counts and kernel window widths.  Point-only indexes (robin_hash)
    instead return ``(found, pos)`` and set ``max_err = 0``.
    """

    state: Any
    predict: Callable[[Any, base.Array], base.SearchBound]
    max_err: int


def _window_gather(data, pos, m: int):
    """[B] start positions -> [B, m] record window, one static gather.

    Past-the-end lanes hold the dtype's max value (for uint64 keys:
    UINT64_MAX, the same sentinel the delta buffer pads with) so windows
    of different plans merge by plain sort.
    """
    n = data.shape[0]
    sentinel = jnp.asarray(jnp.iinfo(data.dtype).max, data.dtype)
    idx = pos[:, None] + jnp.arange(m, dtype=pos.dtype)[None, :]
    oob = idx >= n
    window = jnp.take(data, jnp.clip(idx, 0, n - 1), mode="clip")
    return jnp.where(oob, sentinel, window)


@dataclasses.dataclass(frozen=True, eq=False)
class LookupPlan:
    """One index lowered to predict -> bounded-search, backend-agnostic."""

    name: str
    bounds: BoundsStage
    data: Any                  # jnp device copy of the sorted keys
    n: int
    last_mile: str = "binary"
    point_only: bool = False
    fused: Optional[Callable] = None   # whole-plan kernel executor factory
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # compiled-fn cache: (kind, backend, interpret, ...) -> jitted callable.
    # Keyed per plan instance so repeated dispatch (registry generations,
    # sharded batches) reuses one compiled program per shape bucket.
    _cache: Dict[Any, Any] = dataclasses.field(
        default_factory=dict, repr=False)

    # -- expression builders (pure, un-jitted — composable in transforms) --
    def lb_expr(self, backend: str = "jnp", interpret: bool = False,
                fused: Optional[bool] = None) -> Callable:
        """``q -> int64 LB ranks`` as a pure expression.

        ``fused=None`` uses the registered whole-plan kernel when the
        backend is pallas and the index has one; ``fused=False`` forces
        the generic bounds->`lower_bound_windows` path (parity tests
        exercise both).
        """
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        if self.point_only:
            predict, state = self.bounds.predict, self.bounds.state

            def run_point(q):
                found, pos = predict(state, q)
                return jnp.where(found, pos, -1).astype(jnp.int64)

            return run_point

        predict, state = self.bounds.predict, self.bounds.state
        max_err, data = self.bounds.max_err, self.data

        if backend == "pallas":
            if fused is None:
                fused = self.fused is not None
            if fused:
                if self.fused is None:
                    raise ValueError(
                        f"plan {self.name!r} has no fused kernel executor")
                inner = self.fused(self, interpret)
                return lambda q: inner(q).astype(jnp.int64)

            from repro.kernels.bounded_search.ops import lower_bound_windows

            def run_pallas(q):
                lo, _hi = predict(state, q)
                # window precondition lo <= LB < lo + max_err holds by the
                # bounds contract (LB <= hi <= lo + max_err - 1)
                return lower_bound_windows(
                    data, q, lo, max_width=max_err,
                    interpret=interpret).astype(jnp.int64)

            return run_pallas

        fn = search.SEARCH_FNS[self.last_mile]

        def run_jnp(q):
            lo, hi = predict(state, q)
            return fn(data, q, lo, hi, max_err).astype(jnp.int64)

        return run_jnp

    def merged_expr(self, backend: str = "jnp",
                    interpret: bool = False) -> Callable:
        """Delta rank correction as a plan transform (DESIGN.md §10.2):
        ``(q, delta_padded) -> LB_base(q) + LB_delta(q)``.  Exact because
        base and delta are disjoint sorted sets; the padded delta's
        UINT64_MAX sentinels can never be counted by a lower bound."""
        run = self.lb_expr(backend, interpret)

        def merged(q, delta_padded):
            lb_base = run(q)
            lb_delta = jnp.searchsorted(delta_padded, q, side="left")
            return lb_base + lb_delta.astype(jnp.int64)

        return merged

    def scan_expr(self, m: int, backend: str = "jnp",
                  interpret: bool = False) -> Callable:
        """Range-scan materialization: ``q -> (LB, window[B, m])`` — the
        ``m`` records from ``LB(q)`` as one static-width windowed gather."""
        if self.point_only:
            raise ValueError(f"{self.name!r} is point-only: no scans")
        run = self.lb_expr(backend, interpret)
        data = self.data

        def scan(q):
            pos = run(q)
            return pos, _window_gather(data, pos, m)

        return scan

    def merged_scan_expr(self, m: int, backend: str = "jnp",
                         interpret: bool = False) -> Callable:
        """Scan over the merged (base + delta) view: gather ``m`` from each
        side and keep the first ``m`` of their sorted union — exact because
        the merged array's next ``m`` records are contained in the union of
        the two windows, and both pad with the UINT64_MAX sentinel."""
        if self.point_only:
            raise ValueError(f"{self.name!r} is point-only: no scans")
        run = self.lb_expr(backend, interpret)
        data = self.data

        def scan(q, delta_padded):
            pos_b = run(q)
            pos_d = jnp.searchsorted(
                delta_padded, q, side="left").astype(jnp.int64)
            wb = _window_gather(data, pos_b, m).astype(delta_padded.dtype)
            wd = _window_gather(delta_padded, pos_d, m)
            window = jnp.sort(
                jnp.concatenate([wb, wd], axis=-1), axis=-1)[:, :m]
            return pos_b + pos_d, window

        return scan

    # -- compiled entry points (cached per plan) ---------------------------
    def _compiled(self, key, make_expr) -> Callable:
        fn = self._cache.get(key)
        if fn is None:
            fn = jax.jit(make_expr())
            self._cache[key] = fn
        return fn

    def compile(self, backend: str = "jnp", interpret: bool = False,
                fused: Optional[bool] = None) -> Callable:
        """jit'd ``q -> int64 LB ranks`` (the canonical fused lookup)."""
        # normalize fused before keying the cache: the default (None) and
        # its resolved value must alias to ONE compiled program
        if backend != "pallas" or self.point_only:
            fused = None
        elif fused is None:
            fused = self.fused is not None
        return self._compiled(
            ("lb", backend, interpret, fused),
            lambda: self.lb_expr(backend, interpret, fused))

    def compile_merged(self, backend: str = "jnp",
                       interpret: bool = False) -> Callable:
        return self._compiled(
            ("merged", backend, interpret),
            lambda: self.merged_expr(backend, interpret))

    def compile_scan(self, m: int, backend: str = "jnp",
                     interpret: bool = False) -> Callable:
        return self._compiled(
            ("scan", int(m), backend, interpret),
            lambda: self.scan_expr(int(m), backend, interpret))

    def compile_merged_scan(self, m: int, backend: str = "jnp",
                            interpret: bool = False) -> Callable:
        return self._compiled(
            ("merged_scan", int(m), backend, interpret),
            lambda: self.merged_scan_expr(int(m), backend, interpret))

    def scan(self, q, m: int, backend: str = "jnp",
             interpret: bool = False):
        """Convenience: materialize ``m`` records from ``LB(q)``."""
        return self.compile_scan(m, backend, interpret)(q)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------
def lower(build: base.IndexBuild, data_jnp,
          last_mile: Optional[str] = None) -> LookupPlan:
    """Lower a built index to its `LookupPlan`.

    The lowering contract is exactly the `IndexBuild` surface: ``lookup``
    is the pure bounds predictor, ``meta["max_err"]`` the static window
    bound.  ``last_mile`` defaults to the hyperparameter the index was
    built with (falling back to binary) — the policy every consumer
    shared before plans existed.
    """
    if last_mile is None:
        last_mile = build.hyper.get("last_mile", "binary")
    n = int(build.meta.get("n", data_jnp.shape[0]))
    bounds = BoundsStage(
        state=build.state,
        predict=build.lookup,
        max_err=int(build.meta.get("max_err", n + 1)),
    )
    return LookupPlan(
        name=build.name,
        bounds=bounds,
        data=data_jnp,
        n=n,
        last_mile=last_mile,
        point_only=bool(build.meta.get("point_only", False)),
        fused=FUSED_LOWERERS.get(build.name),
        meta=dict(build.hyper),
    )


@register_fused("rmi")
def _rmi_fused(plan: LookupPlan, interpret: bool) -> Callable:
    """Whole-plan executor for RMI: the fused f32 inference kernel +
    tiled last-mile search (`kernels/rmi_lookup`).  The f32 state is
    refit from the plan's keys with error tables re-verified through the
    kernel's own arithmetic, so the result is still the exact LB rank —
    bit-identical to every other backend."""
    from repro.kernels.rmi_lookup import ops as rops

    st = plan._cache.get("_rmi_f32_state")
    if st is None:
        st = rops.prepare_f32_state(
            np.asarray(plan.data),
            branching=int(plan.meta.get("branching", 1024)))
        plan._cache["_rmi_f32_state"] = st
    data = plan.data

    def run(q):
        return rops.rmi_lookup(st, data, q, interpret=interpret)

    return run
