"""Error-bounded piecewise-linear fitting (shared by PGM and RadixSpline).

``shrinking_cone`` is the O(n) streaming algorithm of Xie et al. [32] used by
PGM (and, with knots restricted to data points, the spline corridor of
Neumann & Michel [25] used by RadixSpline).  The python loop is chunked:
within a chunk, cone slopes are narrowed with vectorized running min/max and
the first violation located with argmax — O(n / chunk) python iterations.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

_CHUNK = 8192


def group_rounded(x_f64: np.ndarray, y: np.ndarray) -> Tuple[np.ndarray, np.ndarray, int]:
    """Collapse duplicate (f64-rounded) keys.

    Returns unique keys, the FIRST position of each (lower_bound semantics),
    and the maximum group span, which must be added to any error bound so
    that positions of all collapsed duplicates stay inside it.
    """
    keep = np.empty(len(x_f64), bool)
    keep[0] = True
    np.not_equal(x_f64[1:], x_f64[:-1], out=keep[1:])
    xu = x_f64[keep]
    y_first = y[keep]
    if keep.all():
        return xu, y_first, 0
    # span of each duplicate group = (next group's first index - 1) - first
    starts = np.flatnonzero(keep)
    ends = np.append(starts[1:], len(x_f64)) - 1
    span = int((ends - starts).max())
    return xu, y_first, span


def shrinking_cone(x: np.ndarray, y: np.ndarray, eps: float):
    """Fit y(x) with segments s.t. |pred - y| <= eps for every input point.

    Returns (anchor_x, anchor_y, slope) arrays, one row per segment.  Segment
    i covers x in [anchor_x[i], anchor_x[i+1]).  Prediction inside a segment:
    ``anchor_y + slope * (x - anchor_x)``.
    """
    n = len(x)
    assert n > 0
    ax, ay, slopes = [], [], []
    i = 0
    while i < n:
        xa, ya = x[i], y[i]
        slo, shi = -np.inf, np.inf
        j = i + 1
        # Narrow the cone until it collapses (or data runs out).
        while j < n:
            hi_idx = min(n, j + _CHUNK)
            dx = x[j:hi_idx] - xa  # > 0: duplicates were grouped out
            s_hi = (y[j:hi_idx] + eps - ya) / dx
            s_lo = (y[j:hi_idx] - eps - ya) / dx
            run_hi = np.minimum(np.minimum.accumulate(s_hi), shi)
            run_lo = np.maximum(np.maximum.accumulate(s_lo), slo)
            bad = run_lo > run_hi
            if bad.any():
                k = int(np.argmax(bad))  # first violation in this chunk
                if k == 0:
                    final_lo, final_hi = slo, shi
                else:
                    final_lo, final_hi = run_lo[k - 1], run_hi[k - 1]
                j = j + k
                break
            slo, shi = run_lo[-1], run_hi[-1]
            j = hi_idx
        else:
            final_lo, final_hi = slo, shi

        if not np.isfinite(final_lo):
            final_lo = final_hi if np.isfinite(final_hi) else 0.0
        if not np.isfinite(final_hi):
            final_hi = final_lo
        slope = 0.5 * (final_lo + final_hi)
        ax.append(xa)
        ay.append(float(ya))
        slopes.append(max(float(slope), 0.0))
        i = j if j > i else i + 1

    return (
        np.asarray(ax, np.float64),
        np.asarray(ay, np.float64),
        np.asarray(slopes, np.float64),
    )


def greedy_spline(x: np.ndarray, y: np.ndarray, eps: float):
    """GreedySplineCorridor [25]: like the cone, but knots are DATA points and
    the prediction interpolates between consecutive knots.

    A candidate point c violates if the exact chord slope base->c falls
    outside the corridor narrowed by all points strictly between base and c;
    the point before c then becomes a knot.  Chord-in-corridor implies the
    interpolation error at every interior data point is <= eps.

    Returns (knot_x, knot_y).
    """
    n = len(x)
    knots_x = [x[0]]
    knots_y = [float(y[0])]
    b = 0  # base knot index
    slo, shi = -np.inf, np.inf  # corridor from points (b, j)
    j = 1
    while j < n:
        hi_idx = min(n, j + _CHUNK)
        dx = x[j:hi_idx] - x[b]
        dy = y[j:hi_idx] - y[b]
        s_exact = dy / dx
        s_hi = (dy + eps) / dx
        s_lo = (dy - eps) / dx
        cum_hi = np.minimum.accumulate(s_hi)
        cum_lo = np.maximum.accumulate(s_lo)
        # corridor BEFORE each candidate: carried (slo, shi) + points < it
        prev_hi = np.minimum(np.concatenate([[np.inf], cum_hi[:-1]]), shi)
        prev_lo = np.maximum(np.concatenate([[-np.inf], cum_lo[:-1]]), slo)
        viol = (s_exact > prev_hi) | (s_exact < prev_lo)
        if viol.any():
            m = j + int(np.argmax(viol))  # first violating point; m-1 > b
            knots_x.append(x[m - 1])
            knots_y.append(float(y[m - 1]))
            b = m - 1
            slo, shi = -np.inf, np.inf
            j = b + 1
        else:
            shi = min(shi, float(cum_hi[-1]))
            slo = max(slo, float(cum_lo[-1]))
            j = hi_idx

    if knots_x[-1] != x[n - 1]:
        knots_x.append(x[n - 1])
        knots_y.append(float(y[n - 1]))
    return np.asarray(knots_x, np.float64), np.asarray(knots_y, np.float64)
