"""CDFShop-style configuration sweeps (paper §3.1 / §4.2).

The paper tunes every structure across ~10 configurations from minimum to
maximum size and reports the Pareto frontier.  ``LADDERS`` mirrors that: a
size ladder per structure; ``sweep`` builds each rung and hands the builds to
the caller (benchmarks attach timings, analysis attaches metrics).
"""
from __future__ import annotations

from typing import Dict, Iterable, List

import numpy as np

from repro.core import base

LADDERS: Dict[str, List[dict]] = {
    "rmi": [dict(branching=b, stage1=s1)
            for b in (2**6, 2**8, 2**10, 2**12, 2**14, 2**16, 2**18)
            for s1 in ("linear",)]
    + [dict(branching=2**10, stage1="cubic"), dict(branching=2**14, stage1="cubic")],
    "pgm": [dict(eps=e) for e in (8, 16, 32, 64, 128, 256, 512, 1024, 2048)],
    "radix_spline": [dict(eps=e, radix_bits=r)
                     for (e, r) in ((8, 20), (16, 18), (32, 16), (64, 16),
                                    (128, 14), (256, 12), (512, 10), (1024, 8))],
    "btree": [dict(sample=s) for s in (1, 2, 4, 8, 16, 32, 64, 256, 1024)],
    "ibtree": [dict(sample=s) for s in (1, 4, 16, 64, 256)],
    "rbs": [dict(radix_bits=r) for r in (6, 8, 10, 12, 14, 16, 18, 20, 22)],
    "binary_search": [dict()],
    "robin_hash": [dict(load_factor=f) for f in (0.25, 0.5, 0.8)],
}


def sweep(
    keys: np.ndarray,
    names: Iterable[str] = ("rmi", "pgm", "radix_spline", "btree", "rbs",
                            "binary_search"),
    max_configs: int | None = None,
) -> List[base.IndexBuild]:
    builds = []
    for name in names:
        rungs = LADDERS[name]
        if max_configs:
            rungs = rungs[:max_configs]
        for hyper in rungs:
            builds.append(base.REGISTRY[name](keys, **hyper))
    return builds
