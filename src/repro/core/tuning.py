"""CDFShop-style configuration sweeps (paper §3.1 / §4.2).

The paper tunes every structure across ~10 configurations from minimum
to maximum size and reports the Pareto frontier.  Since the declarative
build API landed (DESIGN.md §12), the size ladders are GENERATED from
the per-index hyperparameter schemas (`repro.core.spec`) rather than
hand-maintained here — `LADDERS` is a derived view kept for callers
that think in hyper dicts, and `sweep` builds every rung through the
one validated `spec.build` entry point.

``max_configs`` caps a sweep by stride-sampling ACROSS each ladder
(both size extremes always included) — the historical ``ladder[:k]``
truncation only ever saw the small end, so capped sweeps never met the
paper's "minimum to maximum size" protocol.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core import base
from repro.core import spec as spec_mod

#: Index names in the default sweep — generated from the schemas.
#: `robin_hash` is schema-excluded with a reason (point-only, no LB);
#: everything else in `base.REGISTRY`, including `ibtree`, sweeps.
DEFAULT_SWEEP = spec_mod.sweep_names()

#: Derived hyper-dict view of the schema ladders (back-compat surface;
#: the source of truth is `spec.SCHEMAS[name].ladder`).
LADDERS: Dict[str, List[dict]] = {
    name: [dict(rung) for rung in schema.ladder]
    for name, schema in spec_mod.SCHEMAS.items()
}


def spec_sweep(names: Optional[Iterable[str]] = None,
               max_configs: Optional[int] = None,
               backend: str = "jnp") -> List[spec_mod.IndexSpec]:
    """The sweep as validated `IndexSpec`s (no builds), smallest to
    largest per index, stride-sampled to ``max_configs`` rungs."""
    out: List[spec_mod.IndexSpec] = []
    for name in (DEFAULT_SWEEP if names is None else names):
        out.extend(spec_mod.spec_ladder(name, max_configs=max_configs,
                                        backend=backend))
    return out


def sweep(
    keys: np.ndarray,
    names: Optional[Iterable[str]] = None,
    max_configs: Optional[int] = None,
) -> List[base.IndexBuild]:
    """Build every (stride-sampled) rung of every ladder via specs."""
    return [spec_mod.build(s, keys)
            for s in spec_sweep(names, max_configs=max_configs)]
