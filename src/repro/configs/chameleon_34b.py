"""chameleon-34b [arXiv:2405.09818; unverified] — early-fusion VLM.

Early fusion means VQ image tokens share the 65536-entry vocabulary: the
backbone is a dense decoder and the VQ tokenizer is the stub frontend —
input_specs() is token ids.  QK-norm per the paper's training-stability fix.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    head_dim=128,
    rope=True,
    qk_norm=True,
    norm="rmsnorm",
    act="swiglu",
    source="arXiv:2405.09818",
    notes=("early fusion: modality frontend = VQ token ids",),
)
