"""Registry of the 10 assigned architectures + the 4 input-shape sets.

``get(name)`` returns the exact published config; ``get_smoke(name)``
returns a reduced same-family config for CPU smoke tests (small widths, few
layers/experts, tiny vocab) — the FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.config import ModelConfig

_MODULES = [
    "granite_3_2b",
    "starcoder2_3b",
    "qwen1_5_32b",
    "command_r_plus_104b",
    "mamba2_2_7b",
    "jamba_1_5_large_398b",
    "deepseek_moe_16b",
    "mixtral_8x22b",
    "whisper_tiny",
    "chameleon_34b",
]

ARCHS: Dict[str, ModelConfig] = {}
for m in _MODULES:
    mod = importlib.import_module(f"repro.configs.{m}")
    ARCHS[mod.CONFIG.name] = mod.CONFIG

# input shapes: name -> (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence mixing; only SSM/hybrid run it
# (decode itself is O(S), but the assignment says skip pure full-attention
# archs — recorded in DESIGN.md §6).
LONG_OK = {"mamba2-2.7b", "jamba-1.5-large-398b"}
SKIPS = {
    (arch, "long_500k"): "pure full-attention arch; long_500k skipped"
    for arch in ARCHS if arch not in LONG_OK
}


def get(name: str) -> ModelConfig:
    return ARCHS[name]


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config: tiny dims, same structural features."""
    cfg = ARCHS[name]
    changes = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=503,
        attn_chunk=64,
        remat="none",
    )
    if cfg.n_experts:
        changes.update(n_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=64)
    if cfg.n_shared_experts:
        changes.update(n_shared_experts=1)
    if cfg.dense_first_layer:
        changes.update(dense_first_d_ff=256)
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.hybrid_period:
        changes.update(hybrid_period=4, n_layers=8, moe_every=2, moe_offset=1)
    if cfg.family == "encdec":
        changes.update(encoder_layers=2, encoder_seq=64)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)
