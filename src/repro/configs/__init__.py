"""Assigned architecture configs.  ``get(name)`` / ``get_smoke(name)``."""
from repro.configs.registry import ARCHS, SHAPES, get, get_smoke, SKIPS  # noqa: F401
