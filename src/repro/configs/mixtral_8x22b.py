"""mixtral-8x22b [arXiv:2401.04088; hf] — 8-expert top-2 MoE, SWA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    rope=True,
    rope_theta=1000000.0,
    attn_window=4096,      # sliding-window attention per the assignment
    norm="rmsnorm",
    act="swiglu",
    n_experts=8,
    top_k=2,
    moe_every=1,
    capacity_factor=1.25,
    # 141B total but top-2-of-8: optimizer state fits at 256-way pure FSDP
    # and measured 1.8x lower collective volume than TP (§Perf iteration 4).
    parallelism="fsdp",
    source="arXiv:2401.04088",
    notes=("8 experts < 16-way model axis: expert dim replicates, the "
           "rules fall through to TP inside each expert (expert_mlp)",),
)
