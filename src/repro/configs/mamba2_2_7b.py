"""mamba2-2.7b [arXiv:2405.21060; unverified] — attention-free SSD."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,             # Mamba blocks have no separate FFN
    vocab=50280,
    rope=False,
    norm="rmsnorm",
    tie_embeddings=True,
    ssm_state=128,
    ssm_head_dim=64,    # d_inner=5120 -> 80 SSD heads
    ssm_chunk=128,
    ssm_conv=4,
    ssm_expand=2,
    source="arXiv:2405.21060 (SSD); gpt-neox vocab",
    notes=("runs long_500k: decode state is O(1) in context",),
)
