"""jamba-1.5-large-398b [arXiv:2403.19887; hf] — hybrid Mamba+attn, MoE.

1:7 attention:mamba interleave (layer 0 of every 8 is attention), MoE every
other layer, 16 experts top-2.  TPU adaptation: the Mamba mixer uses the
SSD (mamba-2 style) chunked formulation rather than the paper's selective-
scan kernel — same state-space map, matmul-friendly (DESIGN.md §7).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    rope=False,          # jamba uses no positional encoding in attn layers
    norm="rmsnorm",
    act="swiglu",
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    hybrid_period=8,
    ssm_state=128,
    ssm_head_dim=128,    # d_inner=16384 -> 128 SSD heads
    ssm_chunk=128,
    ssm_conv=4,
    ssm_expand=2,
    capacity_factor=1.25,
    source="arXiv:2403.19887 / 2408.12570",
    notes=("runs long_500k (hybrid: SSM state + O(S) attn decode)",),
)
