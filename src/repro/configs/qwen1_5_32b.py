"""qwen1.5-32b [hf:Qwen/Qwen1.5-32B; hf] — dense, MHA (kv=40), QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    head_dim=128,
    rope=True,
    qkv_bias=True,
    norm="rmsnorm",
    act="swiglu",
    source="hf:Qwen/Qwen1.5-0.5B (family config card)",
    notes=("QKV bias", "40 heads fall through to head_dim sharding on a "
           "16-way model axis"),
)
