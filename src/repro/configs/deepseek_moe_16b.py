"""deepseek-moe-16b [arXiv:2401.06066; hf] — fine-grained MoE.

2 shared + 64 routed experts, top-6, expert hidden 1408; layer 0 is a dense
FFN (hidden 10944) per the released config.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,            # routed expert hidden size (fine-grained)
    vocab=102400,
    head_dim=128,
    rope=True,
    norm="rmsnorm",
    act="swiglu",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_every=1,
    moe_d_ff=1408,
    dense_first_layer=True,
    dense_first_d_ff=10944,
    capacity_factor=1.25,
    source="arXiv:2401.06066 / hf:deepseek-ai/deepseek-moe-16b-base",
    notes=("64 routed experts shard 4-per-device on a 16-way model axis "
           "(expert parallelism)",),
)
