"""whisper-tiny [arXiv:2212.04356; unverified] — enc-dec audio backbone.

Conv/log-mel frontend is a stub: input_specs() provides precomputed frame
embeddings [B, 1500, 384].  The assigned seq shapes size the DECODER.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    rope=False,           # learned absolute positions
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    encoder_layers=4,
    encoder_seq=1500,
    source="arXiv:2212.04356",
    notes=("decode shapes size the decoder KV cache; cross-attn over 1500 "
           "stub frame embeddings",),
)
