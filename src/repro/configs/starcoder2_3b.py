"""starcoder2-3b [arXiv:2402.19173; hf] — dense, GQA kv=2, RoPE, biases."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    head_dim=128,
    rope=True,
    rope_theta=999999.4,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    source="arXiv:2402.19173",
    notes=("GQA kv=2", "24 heads do not divide a 16-way model axis: the "
           "sharding rules fall through to head_dim (128) sharding"),
)
