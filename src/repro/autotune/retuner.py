"""ShadowRetuner: alert-triggered tune → verify → hot-swap (DESIGN.md §17).

The state machine, per attempt:

    idle ──trigger──▶ tune ──▶ verify ──▶ margin ──▶ swap
            │           │         │          │
       (hysteresis   (cache    (reject:   (reject:
        + cooldown)    hit       verify)    cost /
                      skips               no_better_spec)
                      sweep)

- **trigger**: the retuner consumes the `AlertEngine`'s state — a rule
  in ``cfg.triggers`` must have been CONTINUOUSLY firing for
  ``hysteresis_s`` (via `AlertEngine.firing_since`), and at least
  ``cooldown_s`` must have passed since the last attempt.  Together
  these make the daemon flap-proof: a one-sample drift spike never
  tunes, and a persistently-firing alert tunes at a bounded rate.
- **tune**: off the hot path (the daemon thread), under the
  workload-aware `WorkloadObjective` — traffic-histogram probe
  sampling, profiler-calibrated proxy, SLO-burn-scaled tail term.  The
  spec-artifact store short-circuits the ladder sweep when this
  (dataset, budget, workload signature) was tuned before.
- **verify**: the candidate generation — the exact compiled object
  that would serve — must return bit-identical lower bounds to
  ``np.searchsorted`` on a replayed workload-drawn query sample (plus
  absent keys).  One divergent bit rejects the candidate.
- **margin**: the candidate's objective score must beat the incumbent's
  by ``min_win`` (both scored with the SAME objective on the SAME
  queries).  A candidate that merely ties — or IS the incumbent spec —
  is rejected truthfully (``no_better_spec``), which is also what ends
  the loop when an alert keeps firing about a workload the best spec
  already serves.  The margin is WAIVED when the incumbent busts the
  tuner's byte budget (the paper's tuning contract is budget-
  constrained; an over-sized model must not win on a proxy that cannot
  price its cache behaviour) — the swap's ``basis`` records which rule
  applied.
- **swap**: through the registry's existing publish path —
  `publish_prebuilt` (broadcast), per-shard `make_generation` +
  `publish_routed` (routed), or `MutableIndex.republish` (mutable,
  delta preserved).  Readers never block; the executor's subscriber
  invalidates + re-warms executables exactly as for any publish.

Every decision lands in a bounded history, counters, a trace span
(cat="autotune"), and the `/autotune.json` surface.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.autotune.objective import (WorkloadObjective,
                                      tail_weight_from_burn)
from repro.autotune.store import (SpecArtifactStore, dataset_fingerprint,
                                  workload_signature)
from repro.core import analysis
from repro.core import spec as spec_mod
from repro.obs.trace import maybe_span

__all__ = ["AutotuneConfig", "ShadowRetuner"]


@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    """Knobs of the self-driving loop (service-level config object)."""

    #: alert rules that may trigger a retune
    triggers: Sequence[str] = ("workload_drift", "error_inflation",
                               "slo_burn")
    #: a trigger must be continuously firing this long before acting
    hysteresis_s: float = 1.0
    #: minimum spacing between retune ATTEMPTS (success or not)
    cooldown_s: float = 30.0
    #: daemon poll period
    poll_s: float = 2.0
    #: trailing window for traffic/burn signals
    window_s: float = 10.0
    #: candidate must beat incumbent score by this fraction
    min_win: float = 0.05
    #: replayed query sample size for oracle verification + scoring
    verify_queries: int = 2048
    #: the spec search to run; None = same-family ladder around the
    #: incumbent (cheap, safe default for a daemon)
    tuner: Optional[spec_mod.Tuner] = None
    #: spec-artifact store directory; None = no persistence
    store_dir: Optional[str] = None
    #: measure the incumbent's cost_model_ratio and calibrate the proxy
    calibrate: bool = True
    #: start the background thread from `LookupService.start()`
    daemon: bool = False
    seed: int = 0
    #: decision-history ring size
    history: int = 64


class ShadowRetuner:
    """Workload-drift-triggered shadow retune daemon for one service.

    ``service`` is duck-typed (`LookupService` or `MutableLookupService`
    — detected by a ``mindex`` attribute): the retuner needs its
    ``registry`` / ``health`` / ``alerts`` / ``metrics`` / ``recorder``
    and ``check_alerts``.  All tuning work happens on the caller's
    thread (``poll_once``) or the daemon thread — never the serving
    path.
    """

    def __init__(self, service, cfg: Optional[AutotuneConfig] = None):
        self.svc = service
        self.cfg = cfg or AutotuneConfig()
        self.store = (SpecArtifactStore(self.cfg.store_dir)
                      if self.cfg.store_dir else None)
        self._mu = threading.Lock()
        self.decisions: "collections.deque" = collections.deque(
            maxlen=self.cfg.history)
        self.n_polls = 0
        self.n_triggered = 0
        self.n_sweeps = 0          # actual ladder sweeps run (cache misses)
        self.n_cache_hits = 0
        self.n_swapped = 0
        self.n_rejected = 0
        self.n_verify_failures = 0
        self.n_errors = 0
        self.last_trigger: Optional[Dict[str, Any]] = None
        self.last_verdict: Optional[str] = None
        self.last_error: Optional[str] = None
        self._t_last_attempt: Optional[float] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # alert sink: cheap bookkeeping only (sinks run on the
        # evaluating thread — never tune inside one)
        self._sink_events: "collections.deque" = collections.deque(maxlen=64)
        if getattr(service, "alerts", None) is not None:
            service.alerts.add_sink(self._on_alert_event)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="shadow-retuner", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=60.0)

    @property
    def alive(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def _run(self) -> None:
        while not self._stop.wait(self.cfg.poll_s):
            try:
                self.poll_once()
            except Exception as e:   # noqa: BLE001 — daemon must survive
                with self._mu:
                    self.n_errors += 1
                    self.last_error = f"{type(e).__name__}: {e}"

    # -- trigger side ----------------------------------------------------
    def _on_alert_event(self, event: Dict) -> None:
        if event.get("rule") in self.cfg.triggers:
            self._sink_events.append(
                {"rule": event.get("rule"), "state": event.get("state"),
                 "t": event.get("t")})

    def poll_once(self, force_trigger: Optional[str] = None
                  ) -> Optional[Dict[str, Any]]:
        """One trigger evaluation; runs a full retune attempt when due.
        Returns the decision record, or None when nothing was due.
        ``force_trigger`` bypasses hysteresis/cooldown (tests, ops)."""
        with self._mu:
            self.n_polls += 1
        now = time.perf_counter()
        trigger = force_trigger
        if trigger is None:
            try:
                self.svc.check_alerts(self.cfg.window_s)
            except Exception:   # noqa: BLE001 — a snapshot hiccup is not fatal
                return None
            since = self.svc.alerts.firing_since()
            due = sorted(
                (t0, rule) for rule, t0 in since.items()
                if rule in self.cfg.triggers
                and now - t0 >= self.cfg.hysteresis_s)
            if not due:
                return None
            trigger = due[0][1]
            if self._t_last_attempt is not None and \
                    now - self._t_last_attempt < self.cfg.cooldown_s:
                return None
        self._t_last_attempt = now
        with self._mu:
            self.n_triggered += 1
            self.last_trigger = {"rule": trigger, "t_unix": time.time()}
        recorder = getattr(self.svc, "recorder", None)
        if recorder is not None:
            recorder.instant("autotune_trigger", cat="autotune",
                             rule=trigger)
        return self._retune(trigger)

    # -- the attempt -----------------------------------------------------
    def _retune(self, trigger: str) -> Dict[str, Any]:
        recorder = getattr(self.svc, "recorder", None)
        t0 = time.perf_counter()
        with maybe_span(recorder, "autotune_retune", cat="autotune",
                        trigger=trigger):
            try:
                decision = self._retune_inner(trigger)
            except Exception as e:   # noqa: BLE001 — truthful error record
                decision = {"action": "error",
                            "reason": f"{type(e).__name__}: {e}"}
                with self._mu:
                    self.n_errors += 1
                    self.last_error = decision["reason"]
        decision.setdefault("action", "error")
        decision["trigger"] = trigger
        decision["t_unix"] = time.time()
        decision["duration_s"] = round(time.perf_counter() - t0, 4)
        with self._mu:
            self.decisions.append(decision)
            self.last_verdict = decision["action"] + (
                f":{decision['reason']}" if decision.get("reason") else "")
        if recorder is not None:
            recorder.instant("autotune_decision", cat="autotune",
                            action=decision["action"],
                            reason=decision.get("reason", ""),
                            trigger=trigger)
        return decision

    def _retune_inner(self, trigger: str) -> Dict[str, Any]:
        svc = self.svc
        mindex = getattr(svc, "mindex", None)
        if mindex is not None:
            snap_view = mindex.view()
            gen = snap_view.generation
            keys = snap_view.base_np
            topo = None
        else:
            gen = svc.registry.current()
            topo = getattr(gen, "topology", None)
            if topo is not None:
                keys = np.concatenate(
                    [np.asarray(s.data, dtype=np.uint64)
                     for s in gen.shards])
            else:
                keys = np.asarray(gen.data, dtype=np.uint64)

        # -- live signals → objective --------------------------------
        hist = None
        if getattr(svc, "health", None) is not None:
            hist = svc.health.global_traffic_hist(self.cfg.window_s)
        burn = 0.0
        try:
            burn = float(svc.metrics.windowed(self.cfg.window_s).get(
                "slo_budget_burn", 0.0) or 0.0)
        except Exception:   # noqa: BLE001
            pass
        calibration = self._measure_calibration(gen, topo, keys)
        objective = WorkloadObjective(
            traffic_hist=hist, calibration=calibration,
            tail_weight=tail_weight_from_burn(burn),
            n_queries=self.cfg.verify_queries, seed=self.cfg.seed)
        tuner = self._resolve_tuner(gen, topo)
        tuner = dataclasses.replace(tuner, objective=objective,
                                    calibration=calibration)

        # -- candidate specs: artifact cache, else ladder sweep ------
        fp = dataset_fingerprint(keys)
        sig = workload_signature(hist)
        q = objective.queries(keys)
        incumbent_specs = self._incumbent_specs(gen, topo)
        cache_hit = False
        tune_results: Optional[List[spec_mod.TuneResult]] = None
        art = self.store.get(fp, tuner.max_bytes, sig) if self.store \
            else None
        if art is not None and self._specs_compatible(art.specs, topo):
            cand_specs = art.specs
            cache_hit = True
            with self._mu:
                self.n_cache_hits += 1
        else:
            with self._mu:
                self.n_sweeps += 1
            if topo is not None:
                # per-shard search; cold shards fall back to uniform
                # probes (a global-histogram draw over shard-local
                # ranks would be miscoordinated)
                sub = dataclasses.replace(
                    tuner, objective=dataclasses.replace(
                        objective, traffic_hist=None))
                tune_results = sub.tune_shards(keys, topo.offsets,
                                               queries=q)
                cand_specs = [r.spec for r in tune_results]
            else:
                tune_results = [tuner.tune(keys)]
                cand_specs = [tune_results[0].spec]

        if [s.canonical() for s in cand_specs] == \
                [s.canonical() for s in incumbent_specs if s is not None]:
            decision = self._reject("no_better_spec", cache_hit=cache_hit,
                                    specs=cand_specs)
            if self.store and not cache_hit:
                # persist anyway: the NEXT cold start on this workload
                # skips the sweep and lands on the same verdict cheaply
                self.store.put(fp, tuner.max_bytes, sig, cand_specs,
                               score=0.0,
                               meta={"trigger": trigger,
                                     "verdict": "no_better_spec"})
            return decision

        # -- build candidates (reuse swept builds where possible) ----
        if topo is not None:
            offs = [int(o) for o in topo.offsets]
            slices = [keys[offs[s]:offs[s + 1]]
                      for s in range(len(offs) - 1)]
            if tune_results is not None:
                cand_builds = [r.build for r in tune_results]
            else:
                cand_builds = [spec_mod.build(sp, sl)
                               for sp, sl in zip(cand_specs, slices)]
        else:
            slices = [keys]
            if tune_results is not None:
                cand_builds = [tune_results[0].build]
            else:
                cand_builds = [spec_mod.build(cand_specs[0], keys)]

        # -- score both arms on the SAME queries ---------------------
        inc_builds = self._incumbent_builds(gen, topo)
        cand_score = self._score_arm(objective, cand_builds, cand_specs,
                                     slices, q)
        inc_score = self._score_arm(objective, inc_builds,
                                    incumbent_specs, slices, q)
        # margin gate — waived when the incumbent BUSTS the tuner's byte
        # budget: serving over budget is itself the violation (the
        # paper's tuning contract is budget-constrained), and the probe
        # proxy cannot price an over-sized model's cache behaviour, so
        # a budget-busting incumbent must not win on modeled cost
        over_budget = self._incumbent_over_budget(inc_builds, tuner, topo)
        if not over_budget and \
                cand_score > inc_score * (1.0 - self.cfg.min_win):
            return self._reject(
                "cost", cache_hit=cache_hit, specs=cand_specs,
                cand_score=cand_score, inc_score=inc_score)

        # -- assemble + verify the EXACT serving artifact ------------
        if mindex is not None:
            verified, n_div = self._verify_build(
                cand_builds[0], cand_specs[0], keys, q)
            if not verified:
                return self._reject_verify(cand_specs, n_div, cache_hit,
                                           cand_score, inc_score)
            new_gen = mindex.republish(cand_specs[0], build=cand_builds[0])
            if new_gen is None:
                return self._reject("stale", cache_hit=cache_hit,
                                    specs=cand_specs)
        elif topo is not None:
            shard_gens, n_div = [], 0
            for s, (b, sp, sl) in enumerate(
                    zip(cand_builds, cand_specs, slices)):
                sg = svc.registry.make_generation(
                    b, gen.shards[s].data, last_mile=sp.last_mile,
                    backend=sp.backend, spec=sp, shard=s)
                ok, div = self._verify_fn(sg.fn, sl, self._shard_queries(
                    q, sl))
                n_div += div
                if not ok:
                    return self._reject_verify(cand_specs, n_div,
                                               cache_hit, cand_score,
                                               inc_score)
                shard_gens.append(sg)
            new_gen = svc.registry.publish_routed(
                shard_gens, topo, spec=cand_specs[0],
                backend=cand_specs[0].backend)
        else:
            sp = cand_specs[0]
            cand_gen = svc.registry.make_generation(
                cand_builds[0], gen.data, last_mile=sp.last_mile,
                backend=sp.backend, spec=sp)
            ok, n_div = self._verify_fn(cand_gen.fn, keys, q)
            if not ok:
                return self._reject_verify(cand_specs, n_div, cache_hit,
                                           cand_score, inc_score)
            new_gen = svc.registry.publish_prebuilt(cand_gen)

        if self.store and not cache_hit:
            self.store.put(fp, tuner.max_bytes, sig, cand_specs,
                           score=cand_score,
                           meta={"trigger": trigger,
                                 "inc_score": round(inc_score, 2)})
        with self._mu:
            self.n_swapped += 1
        return {
            "action": "swapped", "reason": "",
            "basis": "budget" if over_budget else "cost",
            "cache_hit": cache_hit, "swept": tune_results is not None,
            "incumbent": {"specs": [s.canonical() if s else None
                                    for s in incumbent_specs],
                          "score": round(inc_score, 2),
                          "version": int(gen.version)},
            "candidate": {"specs": [s.canonical() for s in cand_specs],
                          "score": round(cand_score, 2),
                          "version": int(new_gen.version)},
            "objective": objective.describe(),
            "verify": {"n": int(len(q)), "divergent": 0},
        }

    # -- helpers ---------------------------------------------------------
    def _resolve_tuner(self, gen, topo) -> spec_mod.Tuner:
        if self.cfg.tuner is not None:
            return self.cfg.tuner
        spec = self._incumbent_specs(gen, topo)[0]
        index = spec.index if spec is not None else gen.plan.name
        backend = spec.backend if spec is not None else \
            getattr(gen, "backend", "jnp")
        return spec_mod.Tuner(names=(index,), max_configs=4,
                              backends=(backend,), seed=self.cfg.seed)

    def _incumbent_specs(self, gen, topo) -> List[
            Optional[spec_mod.IndexSpec]]:
        if topo is not None:
            return [s.spec for s in gen.shards]
        return [gen.spec]

    def _incumbent_builds(self, gen, topo) -> list:
        if topo is not None:
            return [s.build for s in gen.shards]
        return [gen.build]

    @staticmethod
    def _specs_compatible(specs: list, topo) -> bool:
        want = 1 if topo is None else topo.n_shards
        return len(specs) == want

    @staticmethod
    def _incumbent_over_budget(inc_builds: list, tuner: spec_mod.Tuner,
                               topo) -> bool:
        """Whether any serving build exceeds the tuner's hard byte cap
        (per-shard cap on the routed path, mirroring `tune_shards`)."""
        if tuner.max_bytes is None:
            return False
        cap = tuner.max_bytes
        if topo is not None and topo.n_shards > 0:
            cap = max(1, tuner.max_bytes // topo.n_shards)
        return any(b is not None and b.size_bytes > cap
                   for b in inc_builds)

    def _measure_calibration(self, gen, topo, keys: np.ndarray
                             ) -> Optional[Dict[str, float]]:
        """Measured/proxy ratio of the INCUMBENT's family, from the
        profiler's stage decomposition — rescales that family's proxy
        before cross-family ranking.  Returns None (trust proxy) when
        profiling is off, unavailable, or the plan has no decomposable
        cost model."""
        if not self.cfg.calibrate:
            return None
        try:
            from repro.obs.profiler import profile_generation
            target = gen.shards[0] if topo is not None else gen
            rng = np.random.default_rng(self.cfg.seed)
            n = min(1024, len(keys))
            q = keys[rng.integers(0, len(keys), n)]
            row = profile_generation(target, q, repeats=1)
            ratio = row.get("cost_model_ratio")
            if ratio is None or not np.isfinite(ratio) or ratio <= 0:
                return None
            return {target.plan.name: float(ratio)}
        except Exception:   # noqa: BLE001 — calibration is best-effort
            return None

    def _score_arm(self, objective: WorkloadObjective, builds: list,
                   specs: list, slices: List[np.ndarray],
                   q: np.ndarray) -> float:
        """Query-count-weighted objective score of one arm (incumbent
        or candidate) over the replayed workload sample — identical
        queries for both arms, so the margin compares like with like."""
        import jax.numpy as jnp

        total, weight = 0.0, 0
        for b, sp, sl in zip(builds, specs, slices):
            qs = self._shard_queries(q, sl) if len(slices) > 1 else q
            if qs.size == 0:
                continue
            lo, hi = b.lookup(b.state, jnp.asarray(qs))
            widths = np.maximum(np.asarray(hi) - np.asarray(lo) + 1, 1)
            metrics = analysis.describe(b, widths)
            total += objective.score(sp, metrics, widths) * qs.size
            weight += qs.size
        return total / weight if weight else float("inf")

    @staticmethod
    def _shard_queries(q: np.ndarray, sl: np.ndarray) -> np.ndarray:
        if sl.size == 0:
            return q[:0]
        return q[(q >= sl[0]) & (q <= sl[-1])]

    def _verify_fn(self, fn, keys: np.ndarray, q: np.ndarray
                   ) -> Tuple[bool, int]:
        """Bit-exactness of a compiled candidate vs the sorted-array
        oracle on the replayed sample; returns (ok, n_divergent)."""
        import jax.numpy as jnp

        if q.size == 0:
            return True, 0
        got = np.asarray(fn(jnp.asarray(q)), dtype=np.int64)
        want = np.searchsorted(keys, q, side="left").astype(np.int64)
        n_div = int(np.count_nonzero(got != want))
        return n_div == 0, n_div

    def _verify_build(self, build, spec: spec_mod.IndexSpec,
                      keys: np.ndarray, q: np.ndarray) -> Tuple[bool, int]:
        """Verify an un-lowered build (mutable path: the serving object
        is the plan-transformed merged fn, so the base plan is lowered
        here the same way `MutableIndex` will)."""
        import jax.numpy as jnp

        from repro.core import plan as plan_mod
        p = plan_mod.lower(build, jnp.asarray(keys),
                           last_mile=spec.last_mile)
        return self._verify_fn(p.compile(backend=spec.backend), keys, q)

    def _reject(self, reason: str, cache_hit: bool = False,
                specs: Optional[list] = None,
                cand_score: Optional[float] = None,
                inc_score: Optional[float] = None) -> Dict[str, Any]:
        with self._mu:
            self.n_rejected += 1
        d: Dict[str, Any] = {"action": "rejected", "reason": reason,
                             "cache_hit": cache_hit}
        if specs is not None:
            d["candidate"] = {"specs": [s.canonical() for s in specs]}
        if cand_score is not None:
            d["candidate"]["score"] = round(cand_score, 2)
        if inc_score is not None:
            d["incumbent"] = {"score": round(inc_score, 2)}
        return d

    def _reject_verify(self, specs: list, n_div: int, cache_hit: bool,
                       cand_score: float, inc_score: float
                       ) -> Dict[str, Any]:
        with self._mu:
            self.n_verify_failures += 1
        d = self._reject("verify", cache_hit=cache_hit, specs=specs,
                         cand_score=cand_score, inc_score=inc_score)
        d["verify"] = {"divergent": int(n_div)}
        return d

    # -- surfaces --------------------------------------------------------
    def status(self) -> Dict[str, Any]:
        """Compact doctor line: thread + last trigger/verdict."""
        with self._mu:
            return {
                "alive": self.alive,
                "daemon": self.cfg.daemon,
                "last_trigger": self.last_trigger,
                "last_verdict": self.last_verdict,
                "last_error": self.last_error,
                "n_triggered": self.n_triggered,
                "n_swapped": self.n_swapped,
                "n_rejected": self.n_rejected,
            }

    def to_dict(self) -> Dict[str, Any]:
        """The `/autotune.json` document."""
        with self._mu:
            doc = {
                "alive": self.alive,
                "config": {
                    "triggers": list(self.cfg.triggers),
                    "hysteresis_s": self.cfg.hysteresis_s,
                    "cooldown_s": self.cfg.cooldown_s,
                    "poll_s": self.cfg.poll_s,
                    "window_s": self.cfg.window_s,
                    "min_win": self.cfg.min_win,
                    "daemon": self.cfg.daemon,
                    "store_dir": self.cfg.store_dir,
                },
                "counters": {
                    "polls": self.n_polls,
                    "triggered": self.n_triggered,
                    "sweeps": self.n_sweeps,
                    "cache_hits": self.n_cache_hits,
                    "swapped": self.n_swapped,
                    "rejected": self.n_rejected,
                    "verify_failures": self.n_verify_failures,
                    "errors": self.n_errors,
                },
                "last_trigger": self.last_trigger,
                "last_verdict": self.last_verdict,
                "last_error": self.last_error,
                "decisions": list(self.decisions),
            }
        if self.store is not None:
            doc["store"] = self.store.stats()
        return doc
