"""Self-driving tuning subsystem (DESIGN.md §17).

Closes the loop the serve stack left open: the service *measures*
workload drift, dispersion inflation, and SLO burn (`repro.obs`), and
this package *acts* on them — a `ShadowRetuner` daemon re-runs the
budget `Tuner` off the hot path under a workload-aware objective,
verifies the candidate bit-exactly against the sorted-array oracle, and
hot-swaps it through the existing `IndexRegistry` publish path only on
a modeled-cost win.  Tuned specs persist in a versioned JSON artifact
store keyed by (dataset fingerprint, byte budget, workload signature)
so warm starts skip the ladder sweep entirely.

Layering: this package sits between core and serve — it imports
`repro.core` and `repro.obs` only; the serve layer hands it a service
object duck-typed at runtime (no serve import, no cycle).
"""
from repro.autotune.objective import (WorkloadObjective,
                                      tail_weight_from_burn,
                                      workload_queries)
from repro.autotune.retuner import AutotuneConfig, ShadowRetuner
from repro.autotune.store import (SpecArtifact, SpecArtifactStore,
                                  dataset_fingerprint, workload_signature)

__all__ = [
    "AutotuneConfig",
    "ShadowRetuner",
    "SpecArtifact",
    "SpecArtifactStore",
    "WorkloadObjective",
    "dataset_fingerprint",
    "tail_weight_from_burn",
    "workload_queries",
    "workload_signature",
]
