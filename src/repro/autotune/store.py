"""Versioned spec-artifact store: persist tuned `IndexSpec`s as JSON.

The paper's frontiers are *tuned* frontiers — a tuned spec is an
expensive artifact (a full ladder sweep builds every rung), and it is
a pure function of three things: the dataset, the byte budget, and the
workload shape.  This store keys on exactly that triple so a service
restarting on the same data under the same traffic skips the sweep:

- **dataset fingerprint** — sha256 over (n, endpoints, a strided
  subsample) of the sorted key array.  Strided, not full, so the hash
  of a 10^8-key array costs a bounded read; endpoints + n make
  truncation/extension collisions implausible.
- **byte budget** — the Tuner's hard ``max_bytes`` cap (0 = uncapped).
- **workload signature** — the 64-bucket key-space traffic histogram
  (PR 8's health telemetry), normalized and quantized to a few levels.
  Quantization is the cache's tolerance knob: traffic that differs
  only in noise maps to the same signature; a hot spot that moved
  buckets does not.

Artifacts append as versions under their key (never overwritten), so
the store doubles as a tuning history.  Writes are atomic
(tmp + rename) and lock-guarded; the store is safe to share between a
daemon thread and the serving thread.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import spec as spec_mod

#: quantization levels for the workload signature — coarse on purpose:
#: the signature should survive sampling noise but split real hot spots
SIGNATURE_LEVELS = 8
#: subsample cap for the dataset fingerprint
FINGERPRINT_SAMPLE = 4096


def dataset_fingerprint(keys: np.ndarray) -> str:
    """Stable content hash of a sorted key array (bounded read)."""
    keys = np.ascontiguousarray(keys, dtype=np.uint64)
    h = hashlib.sha256()
    h.update(np.int64(keys.size).tobytes())
    if keys.size:
        h.update(keys[0].tobytes())
        h.update(keys[-1].tobytes())
        step = max(1, keys.size // FINGERPRINT_SAMPLE)
        h.update(keys[::step].tobytes())
    return h.hexdigest()[:16]


def workload_signature(traffic_hist: Optional[np.ndarray],
                       levels: int = SIGNATURE_LEVELS) -> str:
    """Quantized traffic histogram → short signature string.

    ``None`` or an empty/zero histogram signs as ``"uniform"`` — the
    cold-start signature, which also matches genuinely flat traffic
    (a uniform histogram quantizes to all-equal levels and is folded
    into the same token for readability).
    """
    if traffic_hist is None:
        return "uniform"
    hist = np.asarray(traffic_hist, dtype=np.float64)
    total = float(hist.sum())
    if hist.size == 0 or total <= 0:
        return "uniform"
    # scale so a perfectly uniform histogram sits at level 1 everywhere
    q = np.minimum(levels - 1,
                   np.floor(hist / total * hist.size).astype(np.int64))
    if np.all(q == q[0]):
        return "uniform"
    body = "".join(str(int(v)) for v in q)
    return f"h{hashlib.sha256(body.encode()).hexdigest()[:12]}"


@dataclasses.dataclass(frozen=True)
class SpecArtifact:
    """One persisted tuning outcome: the spec(s), their objective score,
    and enough provenance to audit where they came from."""

    specs: List[spec_mod.IndexSpec]   # 1 entry (broadcast) or S (routed)
    score: float                      # objective score at tune time
    version: int                      # per-key monotone version
    created_unix: float
    meta: Dict[str, Any]              # trigger, signature, budget, ...

    def to_dict(self) -> Dict[str, Any]:
        return {
            "specs": [json.loads(s.to_json()) for s in self.specs],
            "score": self.score,
            "version": self.version,
            "created_unix": self.created_unix,
            "meta": self.meta,
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "SpecArtifact":
        return SpecArtifact(
            specs=[spec_mod.IndexSpec.from_json(json.dumps(s))
                   for s in d["specs"]],
            score=float(d["score"]),
            version=int(d["version"]),
            created_unix=float(d["created_unix"]),
            meta=dict(d.get("meta", {})),
        )


class SpecArtifactStore:
    """One JSON file per (fingerprint, budget, signature) key, holding a
    version list of `SpecArtifact`s; ``get`` returns the newest."""

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    # -- keying ----------------------------------------------------------
    @staticmethod
    def key(fingerprint: str, max_bytes: Optional[int],
            signature: str) -> str:
        return f"{fingerprint}_b{int(max_bytes or 0)}_{signature}"

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.json")

    # -- IO --------------------------------------------------------------
    def _read(self, key: str) -> List[Dict[str, Any]]:
        try:
            with open(self._path(key)) as f:
                doc = json.load(f)
            return list(doc.get("versions", []))
        except (OSError, ValueError):
            return []

    def _write(self, key: str, versions: List[Dict[str, Any]]) -> None:
        doc = {"key": key, "versions": versions}
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, self._path(key))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- API -------------------------------------------------------------
    def get(self, fingerprint: str, max_bytes: Optional[int],
            signature: str) -> Optional[SpecArtifact]:
        """Newest artifact under the key, or None (counts hit/miss)."""
        key = self.key(fingerprint, max_bytes, signature)
        with self._lock:
            versions = self._read(key)
            if not versions:
                self.misses += 1
                return None
            self.hits += 1
            return SpecArtifact.from_dict(versions[-1])

    def put(self, fingerprint: str, max_bytes: Optional[int],
            signature: str, specs: Sequence[spec_mod.IndexSpec],
            score: float, meta: Optional[Dict[str, Any]] = None
            ) -> SpecArtifact:
        """Append a new version under the key and return it."""
        key = self.key(fingerprint, max_bytes, signature)
        with self._lock:
            versions = self._read(key)
            art = SpecArtifact(
                specs=list(specs), score=float(score),
                version=len(versions) + 1, created_unix=time.time(),
                meta=dict(meta or {}))
            versions.append(art.to_dict())
            self._write(key, versions)
            return art

    def lookup_or_tune(self, fingerprint: str, max_bytes: Optional[int],
                       signature: str,
                       tune_fn: Callable[[], "tuple[List[spec_mod.IndexSpec], float, Dict[str, Any]]"]
                       ) -> "tuple[SpecArtifact, bool]":
        """Cached specs if present, else run ``tune_fn`` and persist.

        Returns ``(artifact, cache_hit)``.  ``tune_fn`` runs OUTSIDE the
        store lock (a ladder sweep is seconds-to-minutes; readers must
        not block on it) — a concurrent tuner for the same key simply
        appends the next version.
        """
        art = self.get(fingerprint, max_bytes, signature)
        if art is not None:
            return art, True
        specs, score, meta = tune_fn()
        return self.put(fingerprint, max_bytes, signature,
                        specs, score, meta), False

    def entries(self) -> List[Dict[str, Any]]:
        """Newest version per key, for surfacing (small; re-reads disk)."""
        out = []
        with self._lock:
            try:
                names = sorted(os.listdir(self.root))
            except OSError:
                return out
            for fn in names:
                if not fn.endswith(".json"):
                    continue
                versions = self._read(fn[:-5])
                if versions:
                    latest = dict(versions[-1])
                    latest["key"] = fn[:-5]
                    latest["n_versions"] = len(versions)
                    out.append(latest)
        return out

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses}
