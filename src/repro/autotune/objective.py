"""Workload-aware tuning objective (DESIGN.md §17).

The stock `Tuner` ranks ladder rungs by `analysis.cost_ns` over a
uniform probe stream — the paper's setting, where queries hit the key
space evenly and the mean is the story.  Live traffic is neither: the
health layer's 64-bucket histogram says *where* queries actually land,
the profiler's ``cost_model_ratio`` says how far the proxy is from
measured reality, and the windowed SLO burn says the *tail*, not the
mean, is what pages.  This objective folds all three into the Tuner
through its plug-in point:

- **traffic weighting** enters through the probe stream itself:
  `workload_queries` samples query ranks from the traffic histogram, so
  every per-rung ``widths`` measurement — and therefore every metric
  the cost model sees — is already weighted by where traffic lands.
  An index family whose error balloons exactly under the hot spot pays
  for it; one that is tight there is rewarded.
- **calibration** rescales each family's proxy cost by the measured
  ``cost_model_ratio`` before cross-family ranking (satellite fix: a
  2x-miscalibrated proxy must not flip the choice).
- **tail pressure** adds a p99-width term: the extra last-mile probe
  rounds a p99-wide window needs beyond the mean-width window, at the
  proxy's per-probe price, scaled by ``tail_weight`` (derived from the
  live SLO burn — the hotter the burn, the more the tail dominates the
  score).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import numpy as np

from repro.core import analysis

#: proxy ns for ONE extra dependent last-mile probe round
#: (1 probe + 8 bytes + 2 flops at the §12.3 weights)
_PER_PROBE_NS = (analysis.COST_NS_WEIGHTS["probes"]
                 + 8 * analysis.COST_NS_WEIGHTS["bytes_touched"]
                 + 2 * analysis.COST_NS_WEIGHTS["flops"])


def tail_weight_from_burn(slo_burn: float) -> float:
    """Map the windowed SLO error-budget burn to a tail weight: 1.0 at
    zero burn (mean and tail count equally), saturating at 5.0 so one
    pathological window cannot make the tail term the whole objective."""
    return 1.0 + min(4.0, max(0.0, float(slo_burn)))


def workload_queries(keys: np.ndarray,
                     traffic_hist: Optional[np.ndarray],
                     n: int, seed: int = 0,
                     absent_frac: float = 0.25) -> np.ndarray:
    """Probe stream drawn from the live traffic histogram.

    Buckets are the health layer's equal-rank-count partition (the same
    ceil-edge formula as ``obs.health.build_rank_hist``, so bucket j
    here is exactly bucket j there); a bucket is drawn proportional to
    its traffic mass, then a rank uniformly inside it.  A fixed
    ``absent_frac`` of the stream is absent keys uniform over the key
    range — lower-bound semantics on misses must stay in the objective
    or the tuner would overfit to the present-key fast path.
    Zero/None histogram → uniform ranks (cold-start behaviour matches
    the stock tuner's probe mix).
    """
    rng = np.random.default_rng(seed)
    keys = np.asarray(keys, dtype=np.uint64)
    n_keys = len(keys)
    n = max(64, int(n))
    n_absent = int(n * absent_frac)
    n_present = n - n_absent

    hist = None if traffic_hist is None else np.asarray(
        traffic_hist, dtype=np.float64)
    if hist is None or hist.size == 0 or float(hist.sum()) <= 0:
        ranks = rng.integers(0, n_keys, n_present)
    else:
        k = hist.size
        p = hist / hist.sum()
        edges = (np.arange(k + 1, dtype=np.int64) * n_keys + k - 1) // k
        buckets = rng.choice(k, size=n_present, p=p)
        lo = edges[buckets]
        hi = np.maximum(edges[buckets + 1], lo + 1)   # empty-bucket guard
        ranks = (lo + rng.random(n_present) * (hi - lo)).astype(np.int64)
        ranks = np.clip(ranks, 0, n_keys - 1)
    present = keys[ranks]
    absent = rng.integers(int(keys[0]),
                          max(int(keys[-1]), int(keys[0]) + 1),
                          n_absent, dtype=np.uint64)
    return np.concatenate([present, absent])


@dataclasses.dataclass
class WorkloadObjective:
    """Duck-typed `Tuner.objective`: workload-drawn probes + calibrated,
    tail-weighted scoring.  Also reused by the retuner to score the
    *incumbent* build under identical terms (same queries, same
    calibration, same tail weight) so the win-margin comparison is
    apples to apples."""

    traffic_hist: Optional[np.ndarray] = None
    calibration: Any = None          # None | float | {index_name: ratio}
    tail_weight: float = 1.0
    n_queries: int = 2048
    seed: int = 0
    absent_frac: float = 0.25

    # -- Tuner plug-in protocol -----------------------------------------
    def queries(self, keys: np.ndarray) -> np.ndarray:
        return workload_queries(keys, self.traffic_hist, self.n_queries,
                                seed=self.seed,
                                absent_frac=self.absent_frac)

    def score(self, spec: Any, metrics: Dict[str, Any],
              widths: np.ndarray) -> float:
        """Calibrated mean proxy + tail term from the width quantiles."""
        cal = self._calibration_for(getattr(spec, "index", None))
        mean_cost = analysis.cost_ns(metrics, calibration=cal)
        w = np.asarray(widths, dtype=np.float64)
        if w.size:
            p99_w = float(np.quantile(w, 0.99))
        else:
            p99_w = float(metrics.get("avg_width", 1.0))
        extra = self._probe_rounds(p99_w) - self._probe_rounds(
            float(metrics.get("avg_width", 1.0)))
        tail = max(0.0, extra) * _PER_PROBE_NS * cal
        return float(mean_cost + self.tail_weight * tail)

    # -- helpers ---------------------------------------------------------
    @staticmethod
    def _probe_rounds(width: float) -> float:
        """Binary-search rounds a last-mile window of ``width`` takes."""
        return math.ceil(math.log2(max(2.0, width)))

    def _calibration_for(self, index: Optional[str]) -> float:
        if self.calibration is None:
            return 1.0
        if isinstance(self.calibration, (int, float)):
            return float(self.calibration)
        return float(self.calibration.get(index, 1.0))

    def describe(self) -> Dict[str, Any]:
        """Compact JSON-able summary for decision records."""
        hist = self.traffic_hist
        return {
            "tail_weight": self.tail_weight,
            "n_queries": self.n_queries,
            "traffic_buckets": None if hist is None else int(
                np.asarray(hist).size),
            "calibration": (self.calibration
                            if self.calibration is None
                            or isinstance(self.calibration, (int, float))
                            else {k: round(float(v), 4)
                                  for k, v in self.calibration.items()}),
        }
