"""Index-health records: prediction error + workload drift (DESIGN.md §15).

PR 7 gave the serve path latency observability; this module gives it
MODEL observability — the quantity the source paper (and Kraska et al.
before it) explains learned-index performance with.  The device side
lives in `repro.core.plan.instrumented_expr`: every instrumented batch
returns fixed-size reductions (a log2 prediction-displacement histogram,
a rank-quantized key-space traffic histogram, bound-width and last-mile
step sums), so what crosses to the host is O(buckets) per batch, never
O(batch).  This module is the host half:

  GenerationHealth   one generation's accumulator: lifetime displacement
                     statistics (quantiles against the static ``max_err``
                     bound) plus a ring of per-time-slot traffic
                     histograms — the same lazy-recycle ring as
                     `windows.WindowedMetrics` — compared at read time
                     against the build-time key distribution.  The
                     comparison is a total-variation score: by
                     construction the build-time distribution over rank
                     buckets is UNIFORM (bucket j holds ranks
                     [j*n/K, (j+1)*n/K)), so drift is measured without
                     retaining the keys.
  HealthMonitor      version -> GenerationHealth map (bounded), fed by
                     `IndexRegistry.publish` and the executors'
                     completion paths; `snapshot()` flattens the CURRENT
                     generation's health into the alert-rule namespace.

Everything here is numpy + stdlib; the serve stack imports *us*.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional

import numpy as np

__all__ = ["GenerationHealth", "HEALTH_DISP_BUCKETS", "HEALTH_STATS_SIZE",
           "HEALTH_TRAFFIC_BUCKETS", "HealthMonitor", "unpack_stats"]

#: Log2 displacement buckets: bucket 0 holds |pred-found| == 0, bucket j
#: holds [2^(j-1), 2^j), the last bucket overflows.  24 buckets cover
#: displacements past 4M slots — beyond any sane error bound.
HEALTH_DISP_BUCKETS = 24

#: Rank-quantized key-space traffic buckets: query rank r lands in
#: bucket r*K//n.  Build-time mass per bucket is uniform by construction.
HEALTH_TRAFFIC_BUCKETS = 64


#: Packed stats vector (what instrumented executables actually return,
#: `repro.core.plan.pack_health_stats`): 5 int64 scalars
#: [n, disp_sum, disp_max, width_sum, steps_sum] then the two histograms.
HEALTH_STATS_SIZE = 5 + HEALTH_DISP_BUCKETS + HEALTH_TRAFFIC_BUCKETS


def unpack_stats(vec) -> Dict:
    """Reverse `repro.core.plan.pack_health_stats`: one int64 vector
    back to the named stats dict `GenerationHealth.accumulate` folds."""
    vec = np.asarray(vec)
    if vec.shape != (HEALTH_STATS_SIZE,):
        raise ValueError(f"packed stats must be shape "
                         f"({HEALTH_STATS_SIZE},), got {vec.shape}")
    d1 = 5 + HEALTH_DISP_BUCKETS
    return {"n": int(vec[0]), "disp_sum": int(vec[1]),
            "disp_max": int(vec[2]), "width_sum": int(vec[3]),
            "steps_sum": int(vec[4]), "disp_hist": vec[5:d1],
            "traffic_hist": vec[d1:]}


def disp_bucket_edge(j: int) -> int:
    """Upper edge (inclusive) of displacement bucket ``j``: the value a
    quantile read reports for mass landing in that bucket."""
    return 0 if j == 0 else (1 << j) - 1


def build_rank_hist(n_keys: int,
                    k: int = HEALTH_TRAFFIC_BUCKETS) -> np.ndarray:
    """The build-time key-rank distribution over ``k`` buckets — exact
    integer counts of ranks per bucket (uniform up to rounding), derived
    from ``n_keys`` alone.  Ceil edges: rank ``r`` belongs to bucket
    ``r*k//n``, exactly the device-side partition in
    `plan.health_stats_expr`."""
    edges = (np.arange(k + 1, dtype=np.int64) * int(n_keys)
             + k - 1) // k
    return np.diff(edges)


class _TrafficSlot:
    """One time slot of the traffic ring: a bucket-count vector."""

    __slots__ = ("idx", "hist")

    def __init__(self, idx: int, k: int):
        self.idx = idx
        self.hist = np.zeros(k, np.int64)


class GenerationHealth:
    """Accumulated health of ONE serving generation.

    `accumulate` ingests the device-reduced stats dict of one completed
    instrumented batch (already on host, via `ShardedDispatcher.finalize`);
    `snapshot` answers displacement quantiles vs ``max_err``, mean bound
    width / last-mile steps, the windowed traffic-vs-build drift score,
    and the delta/compaction-debt gauge — the flat key namespace alert
    rules evaluate over.
    """

    def __init__(self, version: int, index: str, n_keys: int, max_err: int,
                 *, build_disp_p99: float = 0.0, slot_s: float = 0.5,
                 n_slots: int = 240, clock=time.perf_counter,
                 shard: Optional[int] = None):
        self.version = int(version)
        self.index = str(index)
        self.n_keys = int(n_keys)
        self.max_err = int(max_err)
        #: shard index inside a routed generation set (None = broadcast)
        #: — surfaces as the ``shard`` key of `/health.json` records
        self.shard = shard
        #: build-time p99 displacement of the generation's own keys
        #: (`LookupPlan.build_displacement_quantile`): the baseline the
        #: live `disp_p99_ratio` alert key is relative to
        self.build_disp_p99 = float(build_disp_p99)
        self.slot_s = float(slot_s)
        self.n_slots = int(n_slots)
        self._clock = clock
        self.t_published = clock()
        self._mu = threading.Lock()
        # lifetime displacement statistics (device-reduced, host-summed)
        self.n = 0
        self.disp_hist = np.zeros(HEALTH_DISP_BUCKETS, np.int64)
        self.disp_sum = 0
        self.disp_max = 0
        self.width_sum = 0
        self.steps_sum = 0
        # traffic: lifetime total + windowed ring (drift is windowed —
        # a shift must not be diluted by the stationary history)
        self.traffic_total = np.zeros(HEALTH_TRAFFIC_BUCKETS, np.int64)
        self._slots: List[Optional[_TrafficSlot]] = [None] * self.n_slots
        self.build_hist = build_rank_hist(self.n_keys)
        # write-side gauge (mutable service): compaction debt
        self.delta_keys = 0
        self.delta_threshold = 0

    # -- ingestion -------------------------------------------------------
    def accumulate(self, stats, t: Optional[float] = None) -> None:
        """Fold one batch's stats in — either the packed int64 vector an
        instrumented executable returns, or the named dict (tests and
        synthetic injection)."""
        if not isinstance(stats, dict):
            stats = unpack_stats(stats)
        t = self._clock() if t is None else t
        traffic = np.asarray(stats["traffic_hist"], np.int64)
        idx = int(t / self.slot_s)
        with self._mu:
            self.n += int(stats["n"])
            self.disp_hist += np.asarray(stats["disp_hist"], np.int64)
            self.disp_sum += int(stats["disp_sum"])
            self.disp_max = max(self.disp_max, int(stats["disp_max"]))
            self.width_sum += int(stats["width_sum"])
            self.steps_sum += int(stats["steps_sum"])
            self.traffic_total += traffic
            slot = self._slots[idx % self.n_slots]
            if slot is None or slot.idx != idx:
                # lazy recycle — any previous occupant is >= n_slots
                # slots old, outside every window we answer
                slot = _TrafficSlot(idx, HEALTH_TRAFFIC_BUCKETS)
                self._slots[idx % self.n_slots] = slot
            slot.hist += traffic

    def note_delta(self, delta_keys: int, threshold: int) -> None:
        with self._mu:
            self.delta_keys = int(delta_keys)
            self.delta_threshold = int(threshold)

    # -- reads -----------------------------------------------------------
    def disp_quantile(self, q: float) -> float:
        """Displacement at quantile ``q`` from the lifetime log2
        histogram, linearly interpolated within the landing bucket —
        the upper edge alone overstates coarse high buckets by up to
        2x (a p99 of 804 would read as 1023).  The overflow bucket
        reports the observed max."""
        with self._mu:
            hist, n, dmax = self.disp_hist.copy(), self.n, self.disp_max
        if n == 0:
            return 0.0
        target = q * n
        acc = 0
        for j, c in enumerate(hist):
            c = int(c)
            if c and acc + c >= target:
                if j == HEALTH_DISP_BUCKETS - 1:
                    return float(dmax)
                lo = 0 if j == 0 else (1 << (j - 1))
                frac = (target - acc) / c
                return lo + frac * (disp_bucket_edge(j) - lo)
            acc += c
        return float(dmax)

    def traffic_window(self, window_s: float,
                       t: Optional[float] = None) -> np.ndarray:
        """Merged traffic histogram over the trailing ``window_s``."""
        t = self._clock() if t is None else t
        k = max(1, min(self.n_slots, int(np.ceil(window_s / self.slot_s))))
        idx_now = int(t / self.slot_s)
        lo = idx_now - k + 1
        out = np.zeros(HEALTH_TRAFFIC_BUCKETS, np.int64)
        with self._mu:
            for slot in self._slots:
                if slot is not None and lo <= slot.idx <= idx_now:
                    out += slot.hist
        return out

    def drift(self, window_s: float = 10.0,
              t: Optional[float] = None):
        """Total-variation distance between the trailing window's traffic
        distribution and the build-time rank distribution; returns
        ``(tv, n_window)``.  TV in [0, 1]: 0 = traffic matches the build
        distribution, 1 = fully disjoint support."""
        traffic = self.traffic_window(window_s, t=t)
        n = int(traffic.sum())
        b = int(self.build_hist.sum())
        if n == 0 or b == 0:
            return 0.0, n
        tv = 0.5 * float(np.abs(traffic / n - self.build_hist / b).sum())
        return tv, n

    def snapshot(self, window_s: float = 10.0,
                 t: Optional[float] = None) -> Dict[str, float]:
        """The flat health keys of this generation — what alert rules
        and the export surfaces consume."""
        tv, n_window = self.drift(window_s, t=t)
        with self._mu:
            n = self.n
            disp_sum, disp_max = self.disp_sum, self.disp_max
            width_sum, steps_sum = self.width_sum, self.steps_sum
            delta_keys, delta_threshold = (self.delta_keys,
                                           self.delta_threshold)
        p50 = self.disp_quantile(0.50)
        p99 = self.disp_quantile(0.99)
        return {
            "generation_version": float(self.version),
            "health_n": float(n),
            "disp_mean": disp_sum / n if n else 0.0,
            "disp_p50": float(p50),
            "disp_p99": float(p99),
            "disp_max": float(disp_max),
            "build_disp_p99": self.build_disp_p99,
            # live p99 vs the SAME model's build-time p99: ~1.0 when
            # traffic exercises the keys the model was fit on, inflating
            # when it concentrates on badly-modelled regions or a grown
            # delta shifts ranks — the alertable signal
            # (bound_utilization_p99 saturates near 1.0 even when
            # healthy for eps-bounded indexes, so rules key on this)
            "disp_p99_ratio": (float(p99) / max(1.0, self.build_disp_p99)
                               if n else 0.0),
            # how much of the static error bound the live p99
            # displacement consumes: the bounded search window must span
            # [pred - d, pred + d], i.e. 2*d + 1 of the max_err budget
            "bound_utilization_p99": (min(1.0, (2.0 * p99 + 1.0)
                                          / self.max_err)
                                      if self.max_err > 0 and n else 0.0),
            "mean_bound_width": width_sum / n if n else 0.0,
            "mean_last_mile_steps": steps_sum / n if n else 0.0,
            "drift_tv": tv,
            "drift_n": float(n_window),
            "compaction_debt": (delta_keys / delta_threshold
                                if delta_threshold else 0.0),
        }

    def record(self, window_s: float = 10.0) -> Dict:
        """Registry-facing per-generation health record."""
        doc = self.snapshot(window_s)
        doc.update(index=self.index, n_keys=self.n_keys,
                   max_err=self.max_err,
                   traffic_lifetime=int(self.traffic_total.sum()))
        if self.shard is not None:
            doc["shard"] = int(self.shard)
        return doc


def _zero_snapshot() -> Dict[str, float]:
    return {
        "generation_version": -1.0, "health_n": 0.0, "disp_mean": 0.0,
        "disp_p50": 0.0, "disp_p99": 0.0, "disp_max": 0.0,
        "build_disp_p99": 0.0, "disp_p99_ratio": 0.0,
        "bound_utilization_p99": 0.0, "mean_bound_width": 0.0,
        "mean_last_mile_steps": 0.0, "drift_tv": 0.0, "drift_n": 0.0,
        "compaction_debt": 0.0,
    }


class HealthMonitor:
    """Bounded version -> `GenerationHealth` map for one registry name.

    `IndexRegistry.publish` calls `on_publish` (the monitor hangs off
    the registry like the span recorder does); the executors' completion
    paths call `accumulate(version, stats)` — a batch that completes
    against a just-retired generation still lands in ITS record, never
    the successor's.  ``keep`` bounds retained generations (compaction
    churn must not grow memory).
    """

    def __init__(self, slot_s: float = 0.5, n_slots: int = 240,
                 keep: int = 8, clock=time.perf_counter):
        self.slot_s = float(slot_s)
        self.n_slots = int(n_slots)
        self.keep = int(keep)
        self._clock = clock
        self._mu = threading.Lock()
        self._records: "collections.OrderedDict[int, GenerationHealth]" = \
            collections.OrderedDict()
        self._latest: Optional[GenerationHealth] = None
        #: versions of the live routed shard group (None = broadcast):
        #: set by `on_publish_group`, consumed by `snapshot` to merge
        self._group: Optional[tuple] = None

    # -- registry hooks ---------------------------------------------------
    def _make_record(self, gen,
                     shard: Optional[int] = None) -> GenerationHealth:
        bq = getattr(gen.plan, "build_displacement_quantile", None)
        return GenerationHealth(
            version=gen.version, index=gen.plan.name, n_keys=gen.n_keys,
            max_err=int(gen.plan.bounds.max_err),
            build_disp_p99=float(bq(0.99)) if bq is not None else 0.0,
            slot_s=self.slot_s, n_slots=self.n_slots, clock=self._clock,
            shard=shard)

    def on_publish(self, gen) -> None:
        """New generation published (duck-typed on the `Generation`
        surface: version / n_keys / plan.name / plan.bounds.max_err).
        The build-time displacement baseline is evaluated here — one
        device pass over a key sample per publish, amortized against
        the index build that just happened."""
        rec = self._make_record(gen)
        with self._mu:
            self._records[rec.version] = rec
            self._latest = rec
            self._group = None
            while len(self._records) > self.keep:
                self._records.popitem(last=False)

    def on_publish_group(self, gens) -> None:
        """Routed publish (DESIGN.md §16): one record PER SHARD
        generation, tagged with its shard index, plus a group marker so
        `snapshot` answers the merged view.  Per-shard records keep
        their own drift windows — a hot range shifting inside one shard
        is that shard's alert, not averaged away globally."""
        recs = [self._make_record(gen, shard=getattr(gen, "shard", s))
                for s, gen in enumerate(gens)]
        with self._mu:
            for rec in recs:
                self._records[rec.version] = rec
            self._latest = recs[-1] if recs else self._latest
            self._group = tuple(rec.version for rec in recs)
            # never trim away a member of the live shard group
            while len(self._records) > max(self.keep, len(recs)):
                ver, _ = next(iter(self._records.items()))
                if self._group is not None and ver in self._group:
                    break
                self._records.popitem(last=False)

    # -- ingestion --------------------------------------------------------
    def accumulate(self, version: int, stats,
                   t: Optional[float] = None) -> None:
        with self._mu:
            rec = self._records.get(int(version))
        if rec is not None:
            rec.accumulate(stats, t=t)

    def note_delta(self, delta_keys: int, threshold: int) -> None:
        rec = self.current()
        if rec is not None:
            rec.note_delta(delta_keys, threshold)

    # -- reads ------------------------------------------------------------
    def current(self) -> Optional[GenerationHealth]:
        with self._mu:
            return self._latest

    def get(self, version: int) -> Optional[GenerationHealth]:
        with self._mu:
            return self._records.get(int(version))

    def records(self, window_s: float = 10.0) -> List[Dict]:
        with self._mu:
            recs = list(self._records.values())
        return [r.record(window_s) for r in recs]

    def merged_snapshot(self, versions, window_s: float = 10.0
                        ) -> Dict[str, float]:
        """One flat health view over a routed shard group: displacement
        histograms and count sums merge exactly (they are plain sums of
        per-batch reductions); drift TV is the traffic-mass-weighted
        mean of per-shard TVs (each shard's window is compared against
        its OWN build distribution — a global uniform baseline would
        misread routing itself as drift)."""
        with self._mu:
            recs = [self._records.get(int(v)) for v in versions]
        recs = [r for r in recs if r is not None]
        if not recs:
            return _zero_snapshot()
        agg = GenerationHealth(
            version=max(r.version for r in recs), index=recs[0].index,
            n_keys=sum(r.n_keys for r in recs),
            max_err=max(r.max_err for r in recs),
            build_disp_p99=max(r.build_disp_p99 for r in recs),
            slot_s=self.slot_s, n_slots=1, clock=self._clock)
        tv_num, n_window = 0.0, 0
        for r in recs:
            with r._mu:
                agg.n += r.n
                agg.disp_hist += r.disp_hist
                agg.disp_sum += r.disp_sum
                agg.disp_max = max(agg.disp_max, r.disp_max)
                agg.width_sum += r.width_sum
                agg.steps_sum += r.steps_sum
            tv, nw = r.drift(window_s)
            tv_num += tv * nw
            n_window += nw
        snap = agg.snapshot(window_s)
        snap["drift_tv"] = tv_num / n_window if n_window else 0.0
        snap["drift_n"] = float(n_window)
        snap["health_shards"] = float(len(recs))
        return snap

    def global_traffic_hist(self, window_s: float = 10.0
                            ) -> Optional[np.ndarray]:
        """The trailing window's traffic histogram in GLOBAL rank space —
        the autotune retuner's workload signature / objective input.

        Broadcast: the current generation's window verbatim.  Routed
        group: each shard's local-rank histogram is re-binned into the
        global rank axis (shards ordered by shard index, offsets from
        their key counts) by landing each local bucket's mass at its
        midpoint rank — exact to within one global bucket, which is
        finer than the signature quantization consuming it.  None
        before any publish."""
        with self._mu:
            group = self._group
            latest = self._latest
        if group is None:
            return None if latest is None \
                else latest.traffic_window(window_s)
        with self._mu:
            recs = [self._records.get(int(v)) for v in group]
        recs = sorted([r for r in recs if r is not None],
                      key=lambda r: (r.shard if r.shard is not None else 0))
        if not recs:
            return None
        k = HEALTH_TRAFFIC_BUCKETS
        n_total = sum(r.n_keys for r in recs)
        merged = np.zeros(k, np.int64)
        off = 0
        for r in recs:
            local = r.traffic_window(window_s)
            edges = (np.arange(k + 1, dtype=np.int64) * r.n_keys
                     + k - 1) // k
            mids = np.minimum((edges[:-1] + edges[1:]) // 2,
                              max(0, r.n_keys - 1))
            g = np.minimum((off + mids) * k // max(1, n_total), k - 1)
            np.add.at(merged, g, local)
            off += r.n_keys
        return merged

    def snapshot(self, window_s: float = 10.0) -> Dict[str, float]:
        """The CURRENT generation's flat health keys (zeros before any
        publish, so alert rules always see their keys).  With a routed
        group live, the merged cross-shard view."""
        with self._mu:
            group = self._group
        if group is not None:
            return self.merged_snapshot(group, window_s)
        rec = self.current()
        return rec.snapshot(window_s) if rec is not None \
            else _zero_snapshot()
