"""Per-plan-stage timing: predict vs bounded-search (DESIGN.md §14.3).

The source paper's §4.3 contribution is *explanatory*: lookup latency
decomposes into model inference (data movement through index state) and
last-mile probes, and no single metric explains both.  The plan IR makes
the two stages first-class (`BoundsStage.predict` -> backend last-mile),
so we can measure them apart on live plans instead of inferring:

  measured   time a jitted predict-only program and the full plan
             executable on the same query batch; the difference is the
             bounded-search stage (both best-of-k wall clock, blocked
             until ready).
  proxy      `repro.core.analysis.describe`/`cost_ns` split along the
             same seam: the last-mile term is ``probes/bytes/flops``
             attributable to the bounded search, the remainder is model
             inference.

`profile_generation` reports both per (index, backend) cell — the
benchmark's stage-decomposition columns — so the measured split can be
held against the cost model the Tuner budgets with (`cost_model_ratio`:
measured total / proxy total).
"""
from __future__ import annotations

import math
import time
from typing import Dict, Optional

import numpy as np

__all__ = ["profile_plan", "profile_generation", "proxy_decomposition",
           "time_fn_s"]


def time_fn_s(fn, *args, repeats: int = 3) -> float:
    """Best-of-k wall time of a jitted callable, seconds (compile+warm
    excluded — same regime as `benchmarks._common.time_lookup`)."""
    import jax

    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def profile_plan(plan, q, backend: str = "jnp", interpret: bool = False,
                 repeats: int = 3) -> Dict[str, float]:
    """Measured per-lookup stage decomposition of one `LookupPlan`.

    Returns ns/lookup for the predict stage, the bounded-search stage
    (total - predict, clamped at 0 — jit may fuse across the seam, in
    which case the stages are reported as inseparable), and the total.
    Point-only plans have no search stage by construction.
    """
    import jax
    import jax.numpy as jnp

    q = jnp.asarray(np.asarray(q, dtype=np.uint64))
    m = int(q.shape[0])
    full = plan.compile(backend=backend, interpret=interpret)
    total_s = time_fn_s(full, q, repeats=repeats)
    if plan.point_only:
        predict_s = total_s
    else:
        state, predict = plan.bounds.state, plan.bounds.predict
        predict_fn = jax.jit(lambda qq: predict(state, qq))
        predict_s = time_fn_s(predict_fn, q, repeats=repeats)
    total_ns = total_s / m * 1e9
    predict_ns = min(predict_s / m * 1e9, total_ns)
    return {
        "backend": backend,
        "n_queries": m,
        "stage_predict_ns": predict_ns,
        "stage_search_ns": max(0.0, total_ns - predict_ns),
        "stage_total_ns": total_ns,
        "stage_predict_frac": predict_ns / total_ns if total_ns else 0.0,
    }


def proxy_decomposition(build, widths: np.ndarray) -> Dict[str, float]:
    """The `analysis.cost_ns` proxy split along the same predict/search
    seam: the last-mile term is the probe/byte/flop cost `describe`
    attributes to the bounded search, the remainder model inference."""
    from repro.core import analysis

    metrics = analysis.describe(build, np.asarray(widths))
    total = analysis.cost_ns(metrics)
    lm = int(math.ceil(math.log2(max(2.0, metrics["avg_width"]))))
    w = analysis.COST_NS_WEIGHTS
    # describe() adds per last-mile probe: 1 probe round, 8 bytes, 2 flops
    search = lm * (w["probes"] + 8 * w["bytes_touched"] + 2 * w["flops"])
    search = min(search, total)
    return {
        "proxy_predict_ns": total - search,
        "proxy_search_ns": search,
        "proxy_total_ns": total,
        "avg_width": float(metrics["avg_width"]),
    }


def profile_generation(gen, q, repeats: int = 3,
                       backend: Optional[str] = None) -> Dict[str, float]:
    """Stage decomposition of one serving `Generation`: measured split
    for the backend it serves with, proxy split from its build, and the
    measured/proxy ratio that calibrates the Tuner's cost model."""
    import jax

    backend = gen.backend if backend is None else backend
    row = profile_plan(gen.plan, q, backend=backend, repeats=repeats)
    row["index"] = gen.plan.name
    if not gen.plan.point_only:
        import jax.numpy as jnp

        state, predict = gen.plan.bounds.state, gen.plan.bounds.predict
        qd = jnp.asarray(np.asarray(q, dtype=np.uint64))
        lo, hi = jax.jit(lambda qq: predict(state, qq))(qd)
        widths = np.asarray(hi, np.int64) - np.asarray(lo, np.int64) + 1
        row.update(proxy_decomposition(gen.build, widths))
        row["cost_model_ratio"] = (
            row["stage_total_ns"] / row["proxy_total_ns"]
            if row["proxy_total_ns"] else 0.0)
    return row
