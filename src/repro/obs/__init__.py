"""`repro.obs` — the unified observability layer (DESIGN.md §14).

Four pieces, each importable without the serve stack (the serve stack
imports *us*):

  windows    log-spaced `LatencyHistogram` (the one histogram class every
             metrics surface shares) and `WindowedMetrics` — a ring of
             per-time-slot sub-histograms merged at read, giving
             `snapshot(window_s=...)` plus SLO tracking (p99 target,
             error-budget burn rate).  The interface a p99-aware Tuner
             objective consumes.
  trace      `SpanRecorder` — a low-overhead bounded-ring structured span
             recorder with per-request ids propagated from admission
             through executor launch/completion, exported as
             Chrome-trace/Perfetto JSON (`to_chrome`).
  profiler   per-plan-stage timing: decompose measured lookup time into
             predict vs bounded-search per (index, backend) and report it
             against the `analysis.cost_ns` proxy — the paper's §4.3
             explanatory decomposition on live plans.
  export     Prometheus-text + JSON exporters, a stdlib HTTP metrics
             endpoint (`MetricsServer`), and periodic JSONL metrics
             logging (`JsonlMetricsLogger`).
"""
from repro.obs.trace import SpanRecorder, maybe_span
from repro.obs.windows import LatencyHistogram, WindowedMetrics

__all__ = [
    "LatencyHistogram",
    "SpanRecorder",
    "WindowedMetrics",
    "maybe_span",
]
