"""`repro.obs` — the unified observability layer (DESIGN.md §14).

Four pieces, each importable without the serve stack (the serve stack
imports *us*):

  windows    log-spaced `LatencyHistogram` (the one histogram class every
             metrics surface shares) and `WindowedMetrics` — a ring of
             per-time-slot sub-histograms merged at read, giving
             `snapshot(window_s=...)` plus SLO tracking (p99 target,
             error-budget burn rate).  The interface a p99-aware Tuner
             objective consumes.
  trace      `SpanRecorder` — a low-overhead bounded-ring structured span
             recorder with per-request ids propagated from admission
             through executor launch/completion, exported as
             Chrome-trace/Perfetto JSON (`to_chrome`).
  profiler   per-plan-stage timing: decompose measured lookup time into
             predict vs bounded-search per (index, backend) and report it
             against the `analysis.cost_ns` proxy — the paper's §4.3
             explanatory decomposition on live plans.
  export     Prometheus-text + JSON exporters, a stdlib HTTP metrics
             endpoint (`MetricsServer`), and periodic JSONL metrics
             logging (`JsonlMetricsLogger`).
  health     per-generation model health (DESIGN.md §15): lifetime
             prediction-displacement statistics vs the static `max_err`
             bound, and a windowed rank-traffic ring compared against
             the build-time key distribution (total-variation drift).
             Fed by the device-reduced stats of
             `core.plan.instrumented_expr`.
  alerts     declarative `AlertRule` thresholds over any flat snapshot
             key, evaluated by an `AlertEngine` with ok/firing/resolved
             state, emission cooldown, and pluggable sinks.
"""
from repro.obs.alerts import (AlertEngine, AlertRule, JsonlSink, LogSink,
                              default_rules)
from repro.obs.health import GenerationHealth, HealthMonitor
from repro.obs.trace import SpanRecorder, maybe_span
from repro.obs.windows import LatencyHistogram, WindowedMetrics

__all__ = [
    "AlertEngine",
    "AlertRule",
    "GenerationHealth",
    "HealthMonitor",
    "JsonlSink",
    "LatencyHistogram",
    "LogSink",
    "SpanRecorder",
    "WindowedMetrics",
    "default_rules",
    "maybe_span",
]
