"""Declarative alert engine over flat metric snapshots (DESIGN.md §15.3).

An `AlertRule` is one threshold over one key of a flat snapshot dict —
the namespace `LookupService.health_snapshot()` produces (lifetime
metrics + ``window_``-prefixed rolling window + generation health).
The `AlertEngine` evaluates every rule against a snapshot (pull-based:
callers decide when — the HTTP endpoints, the serve driver's doctor
report, the benchmarks' health cells), tracks ok/firing/resolved state
per rule, and emits fire/resolve events to pluggable sinks.

State vs emission are deliberately separate: a rule's STATE always
tracks the truth (so ``/healthz`` never lies about a firing critical
alert), while cooldown only suppresses repeated sink EMISSION of a
flapping rule.  A fire suppressed by cooldown is emitted late if the
rule is still firing once the cooldown expires, and cancelled silently
if it resolved first — operators see one notification per sustained
incident, not one per flap.

``min_samples`` gates guard cold starts: a drift score over 40 lookups
or a cache-hit rate over 2 accesses is noise, not an incident.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import logging
import operator
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["AlertEngine", "AlertRule", "JsonlSink", "LogSink",
           "default_rules"]

_OPS: Dict[str, Callable[[float, float], bool]] = {
    ">": operator.gt, ">=": operator.ge,
    "<": operator.lt, "<=": operator.le,
    "==": operator.eq, "!=": operator.ne,
}

SEVERITIES = ("warning", "critical")


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One declarative threshold: ``snapshot[key] <op> threshold``."""

    name: str
    key: str
    op: str = ">"
    threshold: float = 0.0
    severity: str = "warning"
    cooldown_s: float = 30.0
    #: Gate: the rule only evaluates once ``snapshot[min_samples_key]``
    #: reaches ``min_samples`` (None = always evaluate).
    min_samples_key: Optional[str] = None
    min_samples: float = 0.0
    description: str = ""
    action: str = ""           # the runbook line: what an operator does

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown op {self.op!r}; one of {list(_OPS)}")
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}")

    def check(self, snapshot: Dict) -> Optional[Tuple[bool, float]]:
        """``(breached, value)``, or None when the key is absent or the
        sample gate is not met (the rule abstains — state unchanged)."""
        v = snapshot.get(self.key)
        if v is None or not isinstance(v, (int, float, bool)):
            return None
        if self.min_samples_key is not None:
            ns = snapshot.get(self.min_samples_key, 0.0)
            if float(ns) < self.min_samples:
                return None
        return _OPS[self.op](float(v), float(self.threshold)), float(v)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class _RuleState:
    __slots__ = ("state", "last_value", "t_changed", "t_last_fire_emit",
                 "pending_emit", "n_fired", "n_resolved", "n_suppressed")

    def __init__(self):
        self.state = "ok"                # "ok" | "firing" | "resolved"
        self.last_value: Optional[float] = None
        self.t_changed: Optional[float] = None
        self.t_last_fire_emit: Optional[float] = None
        self.pending_emit = False        # fire suppressed, not yet emitted
        self.n_fired = 0
        self.n_resolved = 0
        self.n_suppressed = 0

    def to_dict(self) -> Dict:
        return {"state": self.state, "last_value": self.last_value,
                "t_changed": self.t_changed, "n_fired": self.n_fired,
                "n_resolved": self.n_resolved,
                "n_suppressed": self.n_suppressed}


class LogSink:
    """Emit events through stdlib logging (warning/critical by severity)."""

    def __init__(self, logger: Optional[logging.Logger] = None):
        self.logger = logger or logging.getLogger("repro.obs.alerts")

    def __call__(self, event: Dict) -> None:
        level = (logging.CRITICAL if event["severity"] == "critical"
                 else logging.WARNING)
        self.logger.log(
            level, "alert %s %s: %s=%s (threshold %s %s)",
            event["rule"], event["state"], event["key"], event["value"],
            event["op"], event["threshold"])


class JsonlSink:
    """Append one JSON object per event to a file (offline alert feed)."""

    def __init__(self, path: str):
        self.path = path

    def __call__(self, event: Dict) -> None:
        with open(self.path, "a") as f:
            f.write(json.dumps(event) + "\n")


class AlertEngine:
    """Evaluate rules over snapshots; track state; emit to sinks.

    Sink failures are isolated PER (event, sink) call: one sink raising
    on one rule's event never blocks another rule's delivery or the
    evaluation itself — failures are counted in ``n_sink_errors``.
    """

    def __init__(self, rules: Sequence[AlertRule] = (),
                 sinks: Sequence[Callable[[Dict], None]] = (),
                 clock=time.perf_counter, history: int = 256):
        self._mu = threading.Lock()
        self._clock = clock
        self.rules: List[AlertRule] = list(rules)
        self.sinks: List[Callable[[Dict], None]] = list(sinks)
        self._states: Dict[str, _RuleState] = {
            r.name: _RuleState() for r in self.rules}
        self.events: "collections.deque" = collections.deque(maxlen=history)
        self.n_evaluations = 0
        self.n_sink_errors = 0

    def add_rule(self, rule: AlertRule) -> None:
        with self._mu:
            self.rules.append(rule)
            self._states[rule.name] = _RuleState()

    def add_sink(self, sink: Callable[[Dict], None]) -> None:
        with self._mu:
            self.sinks.append(sink)

    # -- evaluation -------------------------------------------------------
    def evaluate(self, snapshot: Dict,
                 t: Optional[float] = None) -> List[Dict]:
        """One pass over every rule; returns the emitted events."""
        t = self._clock() if t is None else t
        emitted: List[Dict] = []
        with self._mu:
            self.n_evaluations += 1
            for rule in self.rules:
                st = self._states[rule.name]
                res = rule.check(snapshot)
                if res is None:
                    continue
                breached, value = res
                st.last_value = value
                cooled = (st.t_last_fire_emit is None
                          or t - st.t_last_fire_emit >= rule.cooldown_s)
                if breached and st.state != "firing":
                    st.state = "firing"
                    st.n_fired += 1
                    st.t_changed = t
                    if cooled:
                        st.t_last_fire_emit = t
                        emitted.append(self._event(rule, st, "firing",
                                                   value, t))
                    else:
                        st.n_suppressed += 1
                        st.pending_emit = True
                elif breached and st.pending_emit and cooled:
                    # still firing when the cooldown expired: late-emit
                    # the one notification the flap suppressed
                    st.pending_emit = False
                    st.t_last_fire_emit = t
                    emitted.append(self._event(rule, st, "firing",
                                               value, t))
                elif not breached and st.state == "firing":
                    st.state = "resolved"
                    st.n_resolved += 1
                    st.t_changed = t
                    if st.pending_emit:
                        # the fire was never delivered — cancel silently
                        st.pending_emit = False
                    else:
                        emitted.append(self._event(rule, st, "resolved",
                                                   value, t))
            self.events.extend(emitted)
            sinks = list(self.sinks)
        for event in emitted:
            for sink in sinks:
                try:
                    sink(event)
                except Exception:   # noqa: BLE001 — isolate per (event, sink)
                    with self._mu:
                        self.n_sink_errors += 1
        return emitted

    @staticmethod
    def _event(rule: AlertRule, st: _RuleState, state: str,
               value: float, t: float) -> Dict:
        return {"rule": rule.name, "key": rule.key, "op": rule.op,
                "threshold": rule.threshold, "severity": rule.severity,
                "state": state, "value": value, "t": t,
                "n_fired": st.n_fired,
                "description": rule.description, "action": rule.action}

    # -- reads ------------------------------------------------------------
    def firing(self, severity: Optional[str] = None) -> List[str]:
        """Names of rules currently in the firing state."""
        with self._mu:
            sev = {r.name: r.severity for r in self.rules}
            return [name for name, st in self._states.items()
                    if st.state == "firing"
                    and (severity is None or sev.get(name) == severity)]

    def has_critical_firing(self) -> bool:
        return bool(self.firing(severity="critical"))

    def firing_since(self) -> Dict[str, float]:
        """``{rule_name: t_changed}`` for rules currently firing — the
        hysteresis input consumers like the autotune retuner use to act
        only on alerts that have been CONTINUOUSLY firing for a dwell
        period, not on one-sample flaps."""
        with self._mu:
            return {name: float(st.t_changed)
                    for name, st in self._states.items()
                    if st.state == "firing" and st.t_changed is not None}

    def state(self) -> Dict[str, Dict]:
        with self._mu:
            return {name: st.to_dict()
                    for name, st in self._states.items()}

    def to_dict(self) -> Dict:
        with self._mu:
            return {
                "rules": [r.to_dict() for r in self.rules],
                "states": {n: s.to_dict() for n, s in self._states.items()},
                "firing": [n for n, s in self._states.items()
                           if s.state == "firing"],
                "events": list(self.events),
                "n_evaluations": self.n_evaluations,
                "n_sink_errors": self.n_sink_errors,
            }


def default_rules() -> Tuple[AlertRule, ...]:
    """The shipped ruleset over `LookupService.health_snapshot()` keys —
    thresholds documented (with operator actions) in the README runbook.
    Sample gates keep every rule quiet on cold starts and tiny tests."""
    return (
        AlertRule(
            "slo_burn", key="window_slo_budget_burn", op=">",
            threshold=2.0, severity="critical", cooldown_s=30.0,
            min_samples_key="window_n", min_samples=32,
            description="p99 SLO error budget burning > 2x the "
                        "sustainable rate over the trailing window",
            action="inspect window_p99_ms vs p99_batch_ms/p99_queue_ms "
                   "split; raise max_batch/slots or scale out"),
        AlertRule(
            "workload_drift", key="drift_tv", op=">", threshold=0.6,
            cooldown_s=30.0,
            min_samples_key="drift_n", min_samples=512,
            description="windowed key-space traffic diverged from the "
                        "build-time key distribution: more than 60% of "
                        "the traffic mass moved (total variation; "
                        "stationary mixed-hit/miss traffic measures "
                        "<= ~0.5, a hot-spot shift ~0.98)",
            action="retune/rebuild against live traffic (swap_keys or "
                   "compaction with a Tuner); verify upstream routing"),
        AlertRule(
            "error_inflation", key="disp_p99_ratio", op=">",
            threshold=2.0, cooldown_s=30.0,
            min_samples_key="health_n", min_samples=512,
            description="live p99 prediction displacement exceeds 2x "
                        "the build-time level of the same model — "
                        "prediction error is inflating toward the "
                        "static max_err bound (the raw "
                        "bound_utilization_p99 gauge saturates near "
                        "1.0 even when healthy for eps-bounded "
                        "indexes, so the rule keys on the "
                        "build-relative ratio; stationary traffic "
                        "measures ~1.0)",
            action="rebuild with a larger error budget (eps/branching) "
                   "or retune against live keys before bound "
                   "violations surface as wrong windows"),
        AlertRule(
            "cache_hit_collapse", key="cache_hit_rate", op="<",
            threshold=0.5, cooldown_s=30.0,
            min_samples_key="cache_accesses", min_samples=32,
            description="executable-cache hit rate collapsed under "
                        "serving traffic (per-batch recompiles)",
            action="check warm_buckets cover the traffic's batch sizes; "
                   "look for generation churn (compaction storm)"),
        AlertRule(
            "slot_saturation", key="inflight_saturation", op=">=",
            threshold=0.98, cooldown_s=30.0,
            min_samples_key="batches", min_samples=128,
            description="async in-flight slot ring persistently full — "
                        "dispatch is backpressured on completion",
            action="raise slots, raise max_batch, or shed load; check "
                   "for a straggler bucket occupying slots"),
        AlertRule(
            "trace_drops", key="trace_dropped", op=">", threshold=0.0,
            cooldown_s=30.0,
            description="span recorder dropped spans (ring capacity "
                        "exceeded) — the trace under-reports",
            action="raise trace_capacity or disable tracing under "
                   "sustained load"),
    )
