"""Structured span recorder with Chrome-trace export (DESIGN.md §14.1).

`SpanRecorder` is the request-causality half of the observability layer:
a bounded ring buffer of spans stamped on the serve path's shared clock
(`time.perf_counter`, the same clock `PendingRequest.t_submit` uses, so
admission timestamps and completion timestamps subtract exactly).  The
recording cost is one lock + one deque append; when tracing is disabled
the serve path holds ``None`` and skips even that (`maybe_span`).

Span taxonomy (the ``cat`` field):

  admission   instants at `MicroBatcher.submit` (one per request id) and
              backlog/rate rejections
  request     one complete span per finished request: admission ->
              futures resolved, args carry rid / kind / n_keys and the
              queue vs execute decomposition
  serve       dispatch-side phases: launch, device wait ("finalize"),
              pad+place
  compile     executable-cache builds (misses and warm-up compiles) —
              the p99 outliers the async executor exists to hide
  lifecycle   index_build/publish (hot-swap), warmup, compaction

Export is the Chrome trace-event JSON format ("traceEvents" with "X"
complete events, µs timestamps), openable in `chrome://tracing` or
Perfetto: a slow request shows as a long `request` span visually
overlapping whatever caused it — a deep queue, a `compile` span, or a
`compaction` span on the compactor thread.  The ring bound is explicit:
`to_chrome` reports how many spans were dropped, never silently
truncates.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Span", "SpanRecorder", "maybe_span"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded event; ``t0``/``dur`` in perf_counter seconds."""

    name: str
    cat: str
    t0: float
    dur: float              # 0.0 for instants
    tid: int
    ph: str = "X"           # "X" complete | "i" instant
    args: Optional[Dict] = None


def maybe_span(recorder: Optional["SpanRecorder"], name: str,
               cat: str = "serve", **args):
    """Context manager recording a span when tracing is on, a no-op
    otherwise — the one guard every instrumentation site uses."""
    if recorder is None:
        return contextlib.nullcontext()
    return recorder.span(name, cat=cat, **args)


class SpanRecorder:
    """Thread-safe bounded ring of spans; overflow drops the oldest."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.t_epoch = time.perf_counter()   # exported ts are relative
        self._mu = threading.Lock()
        self._spans: "collections.deque[Span]" = collections.deque(
            maxlen=self.capacity)
        self._thread_names: Dict[int, str] = {}
        self.n_recorded = 0                  # total, including dropped

    # -- recording -------------------------------------------------------
    def _tid(self) -> int:
        th = threading.current_thread()
        ident = th.ident or 0
        if ident not in self._thread_names:
            with self._mu:
                self._thread_names.setdefault(ident, th.name)
        return ident

    def add(self, name: str, t0: float, t1: float, cat: str = "serve",
            ph: str = "X", tid: Optional[int] = None, **args) -> None:
        span = Span(name=name, cat=cat, t0=t0, dur=max(0.0, t1 - t0),
                    tid=self._tid() if tid is None else tid, ph=ph,
                    args=args or None)
        with self._mu:
            self._spans.append(span)
            self.n_recorded += 1

    def instant(self, name: str, cat: str = "serve",
                t: Optional[float] = None, **args) -> None:
        t = time.perf_counter() if t is None else t
        self.add(name, t, t, cat=cat, ph="i", **args)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "serve", **args):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, t0, time.perf_counter(), cat=cat, **args)

    def request(self, rid: int, *, kind: str, n_keys: int,
                t_submit: float, t_launch: float, t_end: float) -> None:
        """The per-request span: admission -> future resolved, with the
        queue/execute decomposition inline (§13 observability contract —
        queue + execute == the span's whole duration)."""
        self.add("request", t_submit, t_end, cat="request",
                 rid=int(rid), kind=kind, n_keys=int(n_keys),
                 queue_us=round((t_launch - t_submit) * 1e6, 3),
                 exec_us=round((t_end - t_launch) * 1e6, 3))

    # -- reading ---------------------------------------------------------
    def spans(self) -> List[Span]:
        with self._mu:
            return list(self._spans)

    @property
    def n_dropped(self) -> int:
        with self._mu:
            return self.n_recorded - len(self._spans)

    def __len__(self) -> int:
        with self._mu:
            return len(self._spans)

    # -- chrome-trace export ---------------------------------------------
    def to_chrome(self) -> Dict:
        """The trace as a Chrome trace-event JSON object (µs timestamps
        relative to the recorder's epoch), with thread-name metadata and
        an explicit dropped-span count."""
        with self._mu:
            spans = list(self._spans)
            names = dict(self._thread_names)
            dropped = self.n_recorded - len(spans)
        events = [{"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                   "args": {"name": name}} for tid, name in sorted(names.items())]
        for s in spans:
            ev = {"name": s.name, "cat": s.cat, "ph": s.ph, "pid": 0,
                  "tid": s.tid,
                  "ts": round((s.t0 - self.t_epoch) * 1e6, 3)}
            if s.ph == "X":
                ev["dur"] = round(s.dur * 1e6, 3)
            if s.ph == "i":
                ev["s"] = "t"     # instant scope: thread
            if s.args:
                ev["args"] = s.args
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_spans": dropped,
                              "recorded_spans": self.n_recorded}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path

    # -- parse-side helpers (reconciliation + tests) ----------------------
    @staticmethod
    def request_events(trace: Dict) -> List[Dict]:
        """The per-request "X" spans of an exported (or re-parsed) trace."""
        return [ev for ev in trace.get("traceEvents", ())
                if ev.get("ph") == "X" and ev.get("cat") == "request"]

    @staticmethod
    def request_latencies_s(trace: Dict) -> Dict[int, float]:
        """rid -> end-to-end request latency (seconds), parsed back from
        the µs export — the trace side of the trace-vs-histogram p99
        reconciliation."""
        out: Dict[int, float] = {}
        for ev in SpanRecorder.request_events(trace):
            args = ev.get("args") or {}
            if "rid" in args:
                out[int(args["rid"])] = float(ev.get("dur", 0.0)) / 1e6
        return out
