"""Histograms and rolling-window metrics (DESIGN.md §14.2).

`LatencyHistogram` is the one latency-distribution primitive every
metrics surface shares: log-spaced buckets (fixed memory, ~5% bucket
resolution), O(log n_buckets) record via bisect — it runs under the
metrics lock on every batch completion, on the very hot path it is
supposed to measure — and mergeable counts so windowed sub-histograms
sum into exactly the histogram a flat recording would have produced.

`WindowedMetrics` answers the question lifetime aggregates cannot: *what
is the p99 right now?*  It keeps a ring of per-time-slot sub-histograms;
`record()` lands in the current slot (lazily recycling whatever stale
slot occupied its ring position), and `snapshot(window_s=...)` merges
the slots covering the trailing window at read time.  A mid-run p99
shift is visible within one slot width, while the lifetime histogram —
dominated by history — hides it.  With an SLO target configured, each
slot also counts target violations, so the snapshot reports the
error-budget burn rate of the *window*, not of all time: the objective
a p99-aware Tuner consumes (ROADMAP item 5).
"""
from __future__ import annotations

import bisect
import math
import threading
import time
from typing import Dict, List, Optional

__all__ = ["LatencyHistogram", "WindowedMetrics"]


class LatencyHistogram:
    """Log-spaced histogram over [1us, ~84s), growth factor 1.05."""

    def __init__(self, lo_s: float = 1e-6, factor: float = 1.05,
                 n_buckets: int = 360):
        self.lo_s = lo_s
        self.factor = factor
        self.bounds: List[float] = []
        b = lo_s
        for _ in range(n_buckets):
            self.bounds.append(b)
            b *= factor
        self.counts = [0] * (n_buckets + 1)
        self.n = 0
        self.total_s = 0.0

    def bucket_index(self, seconds: float) -> int:
        """Index of the bucket holding ``seconds``: the first i with
        ``seconds < bounds[i]`` (== number of bounds <= seconds), i.e.
        `bisect_right` over the sorted bounds; len(bounds) = overflow."""
        return bisect.bisect_right(self.bounds, seconds)

    def record(self, seconds: float) -> None:
        self.counts[self.bucket_index(seconds)] += 1
        self.n += 1
        self.total_s += seconds

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile (0 if empty)."""
        if self.n == 0:
            return 0.0
        target = q * self.n
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return self.bounds[i] if i < len(self.bounds) else float("inf")
        return self.bounds[-1]

    @property
    def mean(self) -> float:
        return self.total_s / self.n if self.n else 0.0

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Add ``other``'s counts in place (same bucketization required).
        Summing counts commutes with recording, so merged sub-histograms
        are exactly the flat histogram of the union of observations."""
        if (other.lo_s != self.lo_s or other.factor != self.factor
                or len(other.bounds) != len(self.bounds)):
            raise ValueError("cannot merge histograms with different buckets")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.n += other.n
        self.total_s += other.total_s
        return self


class _Slot:
    """One time slot of the window ring: a sub-histogram + counters."""

    __slots__ = ("idx", "hist", "units", "violations")

    def __init__(self, idx: int):
        self.idx = idx                    # absolute slot number (t // slot_s)
        self.hist = LatencyHistogram()
        self.units = 0                    # caller-defined weight (e.g. keys)
        self.violations = 0               # observations above the SLO target


class WindowedMetrics:
    """Ring of per-slot sub-histograms, merged at read.

    ``slot_s`` is the time resolution (a p99 shift becomes visible
    within one slot); ``n_slots`` bounds memory and the largest
    answerable window (``slot_s * n_slots``).  ``slo_p99_ms`` configures
    the latency target: each observation above it burns error budget,
    where the budget is the ``slo_budget`` fraction of observations
    allowed over target (default 1%, the complement of a p99 SLO).
    A burn rate of 1.0 means the window is consuming its budget exactly
    at the sustainable rate; above it, the SLO will be violated.
    """

    def __init__(self, slot_s: float = 0.5, n_slots: int = 240,
                 slo_p99_ms: Optional[float] = None,
                 slo_budget: float = 0.01,
                 clock=time.perf_counter):
        if slot_s <= 0 or n_slots < 1:
            raise ValueError("need slot_s > 0 and n_slots >= 1")
        if not 0 < slo_budget < 1:
            raise ValueError("slo_budget must be in (0, 1)")
        self.slot_s = float(slot_s)
        self.n_slots = int(n_slots)
        self.slo_p99_ms = slo_p99_ms
        self.slo_budget = float(slo_budget)
        self._clock = clock
        self._mu = threading.Lock()
        self._slots: List[Optional[_Slot]] = [None] * self.n_slots

    @property
    def max_window_s(self) -> float:
        return self.slot_s * self.n_slots

    def record(self, seconds: float, units: int = 1,
               t: Optional[float] = None) -> None:
        """One latency observation at time ``t`` (defaults to now, on
        the same clock the serve path stamps completions with)."""
        t = self._clock() if t is None else t
        idx = int(t / self.slot_s)
        with self._mu:
            slot = self._slots[idx % self.n_slots]
            if slot is None or slot.idx != idx:
                # recycle lazily: the ring position's previous occupant is
                # at least n_slots slots old, outside every window we serve
                slot = _Slot(idx)
                self._slots[idx % self.n_slots] = slot
            slot.hist.record(seconds)
            slot.units += int(units)
            if (self.slo_p99_ms is not None
                    and seconds * 1e3 > self.slo_p99_ms):
                slot.violations += 1

    def merged(self, window_s: float, t: Optional[float] = None):
        """Merge the slots covering the trailing ``window_s``; returns
        ``(hist, units, violations, covered_window_s)``."""
        t = self._clock() if t is None else t
        k = max(1, min(self.n_slots, math.ceil(window_s / self.slot_s)))
        idx_now = int(t / self.slot_s)
        lo = idx_now - k + 1
        hist = LatencyHistogram()
        units = violations = 0
        with self._mu:
            for slot in self._slots:
                if slot is not None and lo <= slot.idx <= idx_now:
                    hist.merge(slot.hist)
                    units += slot.units
                    violations += slot.violations
        return hist, units, violations, k * self.slot_s

    def snapshot(self, window_s: float = 10.0,
                 t: Optional[float] = None) -> Dict[str, float]:
        """Quantiles, rates, and SLO state of the trailing window."""
        hist, units, violations, covered = self.merged(window_s, t=t)
        viol_rate = violations / hist.n if hist.n else 0.0
        return {
            "window_s": covered,
            "n": hist.n,
            "units": units,
            "units_per_s": units / covered if covered else 0.0,
            "mean_ms": hist.mean * 1e3,
            "p50_ms": hist.quantile(0.50) * 1e3,
            "p99_ms": hist.quantile(0.99) * 1e3,
            "slo_p99_target_ms": (self.slo_p99_ms
                                  if self.slo_p99_ms is not None else 0.0),
            "slo_violations": violations,
            "slo_violation_rate": viol_rate,
            # budget burn: violation rate / allowed rate.  1.0 = burning
            # exactly at the sustainable pace; > 1.0 = SLO at risk.
            "slo_budget_burn": (viol_rate / self.slo_budget
                                if self.slo_p99_ms is not None else 0.0),
        }
