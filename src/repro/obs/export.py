"""Ops export surface: Prometheus text, JSON, HTTP, JSONL (DESIGN.md §14.4).

Everything here is stdlib-only and pull-based, wrapped around whatever
object exposes the serve-path metrics contract:

  provider.metrics.snapshot()            lifetime aggregate dict
  provider.metrics.windowed(window_s)    rolling-window dict (optional)
  provider.recorder                      `SpanRecorder` or None

which is exactly what `LookupService` / `MutableLookupService` look
like.  Surfaces:

  prometheus_text   one gauge line per numeric snapshot key (the
                    Prometheus text exposition format a scraper ingests)
  MetricsServer     stdlib ThreadingHTTPServer on a daemon thread:
                    /metrics (Prometheus text: lifetime + windowed +
                    index health), /metrics.json (structured),
                    /trace.json (Chrome trace when tracing is on),
                    /health.json (flat health snapshot + per-generation
                    records + alert states), /alerts.json (the full
                    alert-engine document, evaluated at request time),
                    /autotune.json (the shadow retuner's config,
                    counters, and decision history when one is attached),
                    /healthz (200/503 from the provider's
                    `health_status` when it has one — stopped service
                    or firing critical alert answers 503)
  JsonlMetricsLogger  periodic snapshot appends to a JSONL file — the
                    offline-analysis feed (one timestamped JSON object
                    per line; pandas/jq-friendly).  A failed write
                    (disk full, path removed) counts in ``n_errors``
                    and the loop keeps going.
"""
from __future__ import annotations

import http.server
import json
import math
import threading
import time
from typing import Dict, Optional
from urllib.parse import parse_qs, urlparse

__all__ = ["JsonlMetricsLogger", "MetricsServer", "metrics_payload",
           "prometheus_text"]


def _numeric(v) -> bool:
    return isinstance(v, (int, float, bool))


def _prom_value(v: float) -> str:
    """Prometheus exposition rendering of one sample value: the text
    format spells non-finite values ``+Inf``/``-Inf``/``NaN`` — bare
    ``inf``/``nan`` (Python's float repr) is a parse error upstream."""
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return f"{v:.10g}"


def prometheus_text(snapshot: Dict, prefix: str = "repro_lookup_",
                    labels: Optional[Dict[str, str]] = None) -> str:
    """Render one flat snapshot dict as Prometheus gauges.  Non-numeric
    values are skipped; ``labels`` are attached to every sample."""
    lbl = ""
    if labels:
        lbl = "{" + ",".join(
            f'{k}="{str(v)}"' for k, v in sorted(labels.items())) + "}"
    lines = []
    for key in sorted(snapshot):
        v = snapshot[key]
        if not _numeric(v):
            continue
        name = prefix + key
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name}{lbl} {_prom_value(float(v))}")
    return "\n".join(lines) + "\n"


def metrics_payload(provider, window_s: float = 10.0) -> Dict:
    """The structured metrics document every exporter serves: lifetime
    snapshot + rolling-window snapshot (when the metrics object has
    one), stamped with wall time."""
    payload: Dict = {"t_unix": time.time()}
    metrics = getattr(provider, "metrics", provider)
    payload["lifetime"] = metrics.snapshot()
    windowed = getattr(metrics, "windowed", None)
    if windowed is not None:
        payload["windowed"] = windowed(window_s)
    per_shard = getattr(metrics, "per_shard", None)
    if per_shard is not None:
        rows = per_shard()
        if rows:
            payload["per_shard"] = rows
    rec = getattr(provider, "recorder", None)
    if rec is not None:
        payload["trace_spans"] = len(rec)
        payload["trace_dropped"] = rec.n_dropped
    health = getattr(provider, "health", None)
    if health is not None:
        payload["health"] = health.snapshot(window_s)
    alerts = getattr(provider, "alerts", None)
    if alerts is not None:
        payload["alerts_firing"] = alerts.firing()
    return payload


class MetricsServer:
    """Stdlib HTTP metrics endpoint on a daemon thread.

    ``port=0`` binds an ephemeral port (tests); `port` reports the bound
    one.  The handler reads the provider's metrics at request time —
    scrapes always see current state, nothing is pushed or buffered.
    """

    def __init__(self, provider, port: int = 0, host: str = "127.0.0.1",
                 window_s: float = 10.0):
        self.provider = provider
        self.window_s = float(window_s)
        outer = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def log_message(self, *a):   # noqa: D102 — keep scrapes quiet
                pass

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):   # noqa: N802 — http.server API
                url = urlparse(self.path)
                try:
                    q = parse_qs(url.query)
                    try:
                        window_s = float(
                            q.get("window_s", [outer.window_s])[0])
                    except (TypeError, ValueError):
                        # a malformed query is the CLIENT's error: 400,
                        # not a 500 through the blanket handler below
                        self._send(400, b"bad window_s\n", "text/plain")
                        return
                    if url.path == "/metrics":
                        body = outer.render_prometheus(window_s)
                        self._send(200, body.encode(),
                                   "text/plain; version=0.0.4")
                    elif url.path == "/metrics.json":
                        body = json.dumps(
                            metrics_payload(outer.provider, window_s))
                        self._send(200, body.encode(), "application/json")
                    elif url.path == "/trace.json":
                        rec = getattr(outer.provider, "recorder", None)
                        if rec is None:
                            self._send(404, b"tracing disabled\n",
                                       "text/plain")
                        else:
                            self._send(200,
                                       json.dumps(rec.to_chrome()).encode(),
                                       "application/json")
                    elif url.path == "/health.json":
                        body = outer.render_health(window_s)
                        if body is None:
                            self._send(404, b"no health surface\n",
                                       "text/plain")
                        else:
                            self._send(200, body.encode(),
                                       "application/json")
                    elif url.path == "/alerts.json":
                        body = outer.render_alerts(window_s)
                        if body is None:
                            self._send(404, b"no alert engine\n",
                                       "text/plain")
                        else:
                            self._send(200, body.encode(),
                                       "application/json")
                    elif url.path == "/autotune.json":
                        body = outer.render_autotune()
                        if body is None:
                            self._send(404, b"no autotune\n",
                                       "text/plain")
                        else:
                            self._send(200, body.encode(),
                                       "application/json")
                    elif url.path == "/healthz":
                        status_fn = getattr(outer.provider,
                                            "health_status", None)
                        if status_fn is None:
                            self._send(200, b"ok\n", "text/plain")
                        else:
                            code, doc = status_fn(window_s)
                            self._send(code,
                                       (json.dumps(doc) + "\n").encode(),
                                       "application/json")
                    else:
                        self._send(404, b"not found\n", "text/plain")
                except Exception as e:   # noqa: BLE001 — a bad scrape must
                    # never take the serving process down with it
                    self._send(500, f"{e!r}\n".encode(), "text/plain")

        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    def render_prometheus(self, window_s: Optional[float] = None) -> str:
        window_s = self.window_s if window_s is None else window_s
        payload = metrics_payload(self.provider, window_s)
        text = prometheus_text(payload["lifetime"])
        if "windowed" in payload:
            text += prometheus_text(payload["windowed"],
                                    prefix="repro_lookup_window_")
        if "health" in payload:
            text += prometheus_text(payload["health"],
                                    prefix="repro_lookup_health_")
        for row in payload.get("per_shard", []):
            text += prometheus_text(
                {k: v for k, v in row.items() if k != "shard"},
                prefix="repro_lookup_shard_",
                labels={"shard": str(row["shard"])})
        return text

    def render_health(self, window_s: Optional[float] = None):
        """The `/health.json` document, or None when the provider has no
        health surface: the flat alert-namespace snapshot, the per-
        generation records, and the alert states."""
        snap_fn = getattr(self.provider, "health_snapshot", None)
        if snap_fn is None:
            return None
        window_s = self.window_s if window_s is None else window_s
        doc: Dict = {"t_unix": time.time(),
                     "snapshot": snap_fn(window_s)}
        registry = getattr(self.provider, "registry", None)
        if registry is not None and hasattr(registry, "health_records"):
            doc["generations"] = registry.health_records(window_s)
        alerts = getattr(self.provider, "alerts", None)
        if alerts is not None:
            doc["alerts"] = {"firing": alerts.firing(),
                             "states": alerts.state()}
        return json.dumps(doc)

    def render_autotune(self):
        """The `/autotune.json` document (retuner state machine: config,
        counters, decision history, artifact-store stats), or None when
        the provider has no retuner attached."""
        at = getattr(self.provider, "autotune", None)
        if at is None:
            return None
        doc = at.to_dict()
        doc["t_unix"] = time.time()
        return json.dumps(doc)

    def render_alerts(self, window_s: Optional[float] = None):
        """The `/alerts.json` document, or None without an engine —
        rules are re-evaluated against a fresh snapshot first, so the
        reported states reflect request time, not the last poll."""
        alerts = getattr(self.provider, "alerts", None)
        if alerts is None:
            return None
        window_s = self.window_s if window_s is None else window_s
        check = getattr(self.provider, "check_alerts", None)
        if check is not None:
            check(window_s)
        doc = alerts.to_dict()
        doc["t_unix"] = time.time()
        return json.dumps(doc)

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    def start(self) -> "MetricsServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="metrics-http",
                daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join()
            self._thread = None
        self._httpd.server_close()

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


class JsonlMetricsLogger:
    """Append one metrics payload per interval to a JSONL file."""

    def __init__(self, provider, path: str, interval_s: float = 1.0,
                 window_s: float = 10.0):
        self.provider = provider
        self.path = path
        self.interval_s = float(interval_s)
        self.window_s = float(window_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.n_written = 0
        #: writes that failed (disk full, path removed, provider error).
        #: The loop keeps going — a logging outage must never silently
        #: kill the feed for the rest of the run.
        self.n_errors = 0

    def write_once(self) -> bool:
        """One snapshot append; returns whether it succeeded.  Failures
        count in ``n_errors`` instead of raising — the periodic loop
        (and any direct caller) survives a transient sink outage."""
        try:
            line = json.dumps(
                metrics_payload(self.provider, self.window_s))
            with open(self.path, "a") as f:
                f.write(line + "\n")
        except Exception:   # noqa: BLE001 — the feed outlives its sink
            self.n_errors += 1
            return False
        self.n_written += 1
        return True

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.write_once()
        self.write_once()   # final snapshot on stop: the run's end state

    def start(self) -> "JsonlMetricsLogger":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="metrics-jsonl", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "JsonlMetricsLogger":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
