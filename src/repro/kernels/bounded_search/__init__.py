from repro.kernels.bounded_search.ops import lower_bound_windows  # noqa: F401
