"""jit'd wrapper: bin queries to data tiles, run the kernel, un-bin.

Binning uses fixed per-tile capacity (GShard-style): the rare overflow
queries fall back to the pure-jnp bounded binary search (the shared
dtype-parameterized implementation in `repro.kernels.common`, run in
int32 here), keeping the result exact for every input while the kernel
path stays fully static-shaped.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.common import (branchless_lower_bound, split_u64,
                                  pad_pow2, pad_to)
from repro.kernels.bounded_search.kernel import DATA_TILE, lower_bound_kernel


def _fallback_lb(data, q, lo, hi_exclusive, max_width: int):
    """Overflow-slot fallback: shared branchless search, int32 positions."""
    return branchless_lower_bound(
        data, q, lo, hi_exclusive - 1, max_width, index_dtype=jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("max_width", "capacity", "interpret"),
)
def lower_bound_windows(
    data,                 # [n] sorted uint64 (or uint32) keys
    queries,              # [m] lookup keys
    lo,                   # [m] int32/int64 window starts, LB in [lo, lo+max_width)
    max_width: int,
    capacity: int = 256,
    interpret: bool = False,
):
    """Exact LB(q) for every query; window precondition lo <= LB < lo+max_width."""
    # TPU is the target; the CPU backend only runs Pallas in interpret mode
    interpret = interpret or jax.default_backend() == "cpu"
    n = data.shape[0]
    m = queries.shape[0]
    window = pad_pow2(max_width, minimum=128)
    if window > DATA_TILE:
        # Bound too loose for the tiled kernel; stay exact via fallback.
        hi = jnp.minimum(lo + max_width, n).astype(jnp.int32)
        return _fallback_lb(data, queries, lo.astype(jnp.int32), hi, max_width)

    n_pad = pad_to(n, DATA_TILE)
    dhi, dlo_plane = split_u64(data)
    pad = ((0, n_pad - n),)
    # padding compares as +inf (all-ones), never counted as < q
    dhi = jnp.pad(dhi, pad, constant_values=np.uint32(0xFFFFFFFF))
    dlo_plane = jnp.pad(dlo_plane, pad, constant_values=np.uint32(0xFFFFFFFF))
    n_tiles = n_pad // DATA_TILE

    lo32 = jnp.clip(lo.astype(jnp.int32), 0, max(n - 1, 0))
    tile = lo32 // DATA_TILE                              # [m]
    order = jnp.argsort(tile)
    tile_s = jnp.take(tile, order)
    # slot within tile = rank among same-tile queries
    ranks = jnp.arange(m, dtype=jnp.int32) - jnp.searchsorted(
        tile_s, tile_s, side="left"
    ).astype(jnp.int32)
    overflow = ranks >= capacity

    qhi, qlo_plane = split_u64(queries)
    qhi_s = jnp.take(qhi, order)
    qlo_s = jnp.take(qlo_plane, order)
    lo_s = jnp.take(lo32, order)
    # overflow entries scatter into a trash row (n_tiles) so they can never
    # clobber a real slot; the kernel grid only covers rows [0, n_tiles)
    row = jnp.where(overflow, n_tiles, tile_s)
    slot = jnp.where(overflow, 0, ranks)

    def scatter(vals, fill):
        buf = jnp.full((n_tiles + 1, capacity), fill, vals.dtype)
        return buf.at[row, slot].set(vals)[:n_tiles]

    qhi_b = scatter(qhi_s, np.uint32(0))
    qlo_b = scatter(qlo_s, np.uint32(0))
    lo_b = scatter(lo_s, np.int32(0))
    valid_b = jnp.zeros((n_tiles + 1, capacity), bool).at[row, slot].set(
        ~overflow)[:n_tiles]

    pos_b = lower_bound_kernel(
        dhi, dlo_plane, qhi_b, qlo_b, lo_b, valid_b,
        window=window, n=n, interpret=interpret,
    )
    pos_s = pos_b[tile_s, slot]

    # exact fallback for overflow slots
    hi_s = jnp.minimum(lo_s + max_width, n).astype(jnp.int32)
    q_sorted = jnp.take(queries, order)
    fb = _fallback_lb(data, q_sorted, lo_s, hi_s, max_width)
    pos_s = jnp.where(overflow, fb, pos_s)

    out = jnp.zeros((m,), jnp.int32).at[order].set(pos_s)
    return out
