"""Tile-binned batched lower-bound search — the last-mile hot path.

The paper's last-mile search is a dependent-load chain per query (binary
search inside the bound).  The TPU-native form (DESIGN.md §2): bin queries
by the DATA TILE containing their window, stream each tile HBM->VMEM once,
and resolve all of the tile's queries with one vectorized rank count
(``pos = lo + sum(window < q)``).  Data-dependent gathers become dense,
tile-local vector compares; each data tile is touched exactly once per
batch regardless of how many queries land in it.

Grid: one step per data tile.  A query whose window starts in tile t may
spill into tile t+1 (window width <= tile size), so the kernel sees two
consecutive data tiles per step — expressed as two BlockSpecs over the same
operand with index maps t and t+1 (Pallas blocks cannot overlap; two views
can).

Keys are uint32 (hi, lo) planes — see kernels/common.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import less_u64

DATA_TILE = 2048  # uint32-pair elements per VMEM tile (2 tiles * 8B = 32 KiB)


def _kernel(
    dhi0_ref, dlo0_ref, dhi1_ref, dlo1_ref,
    qhi_ref, qlo_ref, qlo_pos_ref, valid_ref,
    out_ref,
    *, window: int, n: int,
):
    t = pl.program_id(0)
    base = t * DATA_TILE
    # two consecutive data tiles, concatenated in VMEM
    dhi = jnp.concatenate([dhi0_ref[...], dhi1_ref[...]])
    dlo = jnp.concatenate([dlo0_ref[...], dlo1_ref[...]])

    qhi = qhi_ref[0]            # [C]
    qlo = qlo_ref[0]            # [C]
    lo_pos = qlo_pos_ref[0]     # [C] window start (absolute)
    valid = valid_ref[0]        # [C] slot occupied?

    local = (lo_pos - base).astype(jnp.int32)          # [0, DATA_TILE)
    offs = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], window), 1)
    idx = local[:, None] + offs                        # [C, W] into 2 tiles
    whi = jnp.take(dhi, idx, mode="clip")
    wlo = jnp.take(dlo, idx, mode="clip")
    in_range = (lo_pos[:, None] + offs) < n            # beyond-end = +inf
    less = less_u64(whi, wlo, qhi[:, None], qlo[:, None]) & in_range
    count = jnp.sum(less.astype(jnp.int32), axis=-1)
    pos = (lo_pos + count).astype(jnp.int32)
    out_ref[0] = jnp.where(valid, pos, -1)


def lower_bound_kernel(
    dhi, dlo,            # [n_pad] uint32 data planes (padded to tile multiple)
    qhi, qlo,            # [n_tiles, C] binned query planes
    lo_pos,              # [n_tiles, C] int32 absolute window starts
    valid,               # [n_tiles, C] bool
    *, window: int, n: int, interpret: bool = False,
):
    n_tiles = qhi.shape[0]
    cap = qhi.shape[1]
    last = dhi.shape[0] // DATA_TILE - 1

    data_spec0 = pl.BlockSpec((DATA_TILE,), lambda t: (t,))
    data_spec1 = pl.BlockSpec((DATA_TILE,), lambda t: (jnp.minimum(t + 1, last),))
    q_spec = pl.BlockSpec((1, cap), lambda t: (t, 0))

    return pl.pallas_call(
        functools.partial(_kernel, window=window, n=n),
        grid=(n_tiles,),
        in_specs=[data_spec0, data_spec0, data_spec1, data_spec1,
                  q_spec, q_spec, q_spec, q_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, cap), jnp.int32),
        interpret=interpret,
    )(dhi, dlo, dhi, dlo, qhi, qlo, lo_pos, valid)
