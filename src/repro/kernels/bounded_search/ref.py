"""Pure-jnp oracle for the tiled bounded last-mile search."""
from __future__ import annotations

import jax.numpy as jnp


def lower_bound_windows_ref(data, queries, lo, max_width: int):
    """LB(q) for each query, given windows [lo, lo+max_width) known to
    contain it.  Oracle ignores the windows and searches the whole array —
    the kernel must agree wherever the window precondition holds."""
    del lo, max_width
    return jnp.searchsorted(data, queries, side="left").astype(jnp.int32)
