"""jit'd wrappers: f32 RMI state preparation + fused lookup pipeline.

``prepare_f32_state`` re-verifies the stage-2 error table through the exact
f32 arithmetic the kernel runs (same jnp expressions, same rounding), so
the kernel's bounds stay valid even though TPU model math is float32 while
the paper's reference implementations use float64.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to
from repro.kernels.rmi_lookup import ref as _ref
from repro.kernels.rmi_lookup.kernel import (
    QUERY_BLOCK,
    TABLE_TILE,
    rmi_infer_kernel,
)
from repro.kernels.bounded_search.ops import lower_bound_windows


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class F32RMIState:
    c0: Any
    c1: Any
    x0: Any
    inv_range: Any
    a2: Any
    b2: Any
    err: Any
    scale: float
    branching: int
    n: int
    max_err: int

    def tree_flatten(self):
        leaves = (self.c0, self.c1, self.x0, self.inv_range,
                  self.a2, self.b2, self.err)
        aux = (self.scale, self.branching, self.n, self.max_err)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


@jax.jit
def _infer_u_bkt(state: "F32RMIState", q):
    """Stage-1 inference: the ONE code path used by both the error-table
    verification (on keys) and rmi_bounds (on queries).  Build/lookup must
    share the compiled arithmetic bit-for-bit (XLA may contract a*u+b into
    an FMA; a numpy replica can differ by 1 ulp and misassign boundary
    keys' errors — see repro.core.rmi)."""
    u = _ref.f32_u(state, q)
    p1 = state.c0 * u + state.c1
    bkt = jnp.clip(
        jnp.floor(p1 * jnp.float32(state.scale)), 0, state.branching - 1
    ).astype(jnp.int32)
    return u, bkt


def prepare_f32_state(keys: np.ndarray, branching: int = 4096) -> F32RMIState:
    """Fit a linear/linear RMI (f64 numpy fit, as repro.core.rmi) and verify
    its error table under the kernel's f32 inference pipeline."""
    keys = np.asarray(keys).astype(np.uint64)
    n = len(keys)
    B = int(branching)
    x = keys.astype(np.float64)
    y = np.arange(n, dtype=np.float64)

    x0 = np.float32(x[0])
    rng = np.float32(x[-1]) - x0
    inv_range = np.float32(1.0 / rng) if rng > 0 else np.float32(1.0)
    scale = B / n

    # stage-1 fit (f64 for conditioning, stored f32)
    u64 = (x - float(x0)) * float(inv_range)
    su, sy = u64.sum(), y.sum()
    suu, suy = (u64 * u64).sum(), (u64 * y).sum()
    denom = n * suu - su * su
    a1 = max((n * suy - su * sy) / denom, 0.0) if denom > 0 else 0.0
    b1 = (sy - a1 * su) / n

    state = F32RMIState(
        c0=jnp.float32(a1), c1=jnp.float32(b1), x0=jnp.float32(x0),
        inv_range=jnp.float32(inv_range),
        a2=jnp.zeros(1, jnp.float32), b2=jnp.zeros(1, jnp.float32),
        err=jnp.zeros(1, jnp.int32),
        scale=scale, branching=B, n=n, max_err=1,
    )
    # bucket assignment + u through the EXACT kernel-side math
    u_j, bkt_j = _infer_u_bkt(state, jnp.asarray(keys))
    u32 = np.asarray(u_j, np.float64)
    bkt = np.asarray(bkt_j).astype(np.int64)
    bkt = np.maximum.accumulate(bkt)  # no-op safeguard (inference monotone)

    # stage-2 grouped least squares (f64 fit on the f32-rounded u)
    cnt = np.bincount(bkt, minlength=B).astype(np.float64)
    su2 = np.bincount(bkt, weights=u32, minlength=B)
    sy2 = np.bincount(bkt, weights=y, minlength=B)
    suu2 = np.bincount(bkt, weights=u32 * u32, minlength=B)
    suy2 = np.bincount(bkt, weights=u32 * y, minlength=B)
    den2 = cnt * suu2 - su2 * su2
    ok = den2 > 1e-30
    a2 = np.where(ok, (cnt * suy2 - su2 * sy2) / np.where(ok, den2, 1.0), 0.0)
    a2 = np.maximum(a2, 0.0)
    b2 = np.where(cnt > 0, (sy2 - a2 * su2) / np.where(cnt > 0, cnt, 1.0), 0.0)
    first_pos = np.searchsorted(bkt, np.arange(B), side="left").astype(np.float64)
    empty = cnt == 0
    b2 = np.where(empty, first_pos, b2)

    a2f = a2.astype(np.float32)
    b2f = b2.astype(np.float32)

    # error verification through f32 arithmetic (same expression as kernel)
    pred = np.asarray(
        jax.jit(lambda a, b, u, k: jnp.take(a, k) * u + jnp.take(b, k))(
            jnp.asarray(a2f), jnp.asarray(b2f), u_j, jnp.asarray(bkt, jnp.int32)
        ),
        np.float64,
    )
    err = np.zeros(B, np.float64)
    np.maximum.at(err, bkt, np.abs(pred - y))
    # both-side boundary augmentation (see repro.core.rmi)
    nonempty = np.flatnonzero(~empty)
    fp = first_pos[nonempty].astype(np.int64)

    def _eval(bids, kidx):
        return np.asarray(
            jax.jit(lambda a, b, u, k: jnp.take(a, k) * u + jnp.take(b, k))(
                jnp.asarray(a2f), jnp.asarray(b2f),
                u_j[jnp.asarray(kidx)], jnp.asarray(bids, jnp.int32)
            ),
            np.float64,
        )

    hp = fp > 0
    np.maximum.at(err, nonempty[hp],
                  np.abs(_eval(nonempty[hp], fp[hp] - 1) - fp[hp].astype(np.float64)))
    lp = np.searchsorted(bkt, nonempty, side="right") - 1
    hn = lp < n - 1
    np.maximum.at(err, nonempty[hn],
                  np.abs(_eval(nonempty[hn], lp[hn] + 1) - (lp[hn] + 1.0)))
    # empty buckets: exact LB is first_pos; only f32 rounding of b2 matters
    err[empty] = np.abs(b2f[empty].astype(np.float64) - first_pos[empty])

    err_i = (np.ceil(err) + 1).astype(np.int32)
    state = dataclasses.replace(
        state,
        a2=jnp.asarray(a2f), b2=jnp.asarray(b2f), err=jnp.asarray(err_i),
        max_err=int(2 * err_i.max() + 2),
    )
    return state


@functools.partial(jax.jit, static_argnames=("interpret",))
def rmi_bounds(state: F32RMIState, queries, interpret: bool = False):
    """Fused inference via the Pallas kernel: queries -> (lo, hi)."""
    interpret = interpret or jax.default_backend() == "cpu"
    m = queries.shape[0]
    u, bkt = _infer_u_bkt(state, queries)

    m_pad = pad_to(max(m, 1), QUERY_BLOCK)
    order = jnp.argsort(bkt)
    u_s = jnp.pad(jnp.take(u, order), (0, m_pad - m))
    bkt_s = jnp.pad(jnp.take(bkt, order), (0, m_pad - m))

    T_pad = pad_to(state.branching, TABLE_TILE)
    a2 = jnp.pad(state.a2, (0, T_pad - state.branching))
    b2 = jnp.pad(state.b2, (0, T_pad - state.branching))
    er = jnp.pad(state.err, (0, T_pad - state.branching))

    n_blocks = m_pad // QUERY_BLOCK
    tile_idx = (
        bkt_s[:: QUERY_BLOCK].astype(jnp.int32) // TABLE_TILE
    ).reshape(n_blocks)

    pred_s, err_s, ok_s = rmi_infer_kernel(
        tile_idx, u_s, bkt_s, a2, b2, er, interpret=interpret
    )
    # fallback for blocks whose buckets span > 2 table tiles (rare)
    fb_pred = jnp.take(state.a2, jnp.minimum(bkt_s, state.branching - 1)) * u_s \
        + jnp.take(state.b2, jnp.minimum(bkt_s, state.branching - 1))
    fb_err = jnp.take(state.err, jnp.minimum(bkt_s, state.branching - 1))
    pred_s = jnp.where(ok_s, pred_s, fb_pred)
    err_s = jnp.where(ok_s, err_s, fb_err)

    pred = jnp.zeros((m,), jnp.float32).at[order].set(pred_s[:m])
    pred = jnp.clip(pred, -1.0, float(state.n) + 1.0)  # guard int32 overflow
    err = jnp.zeros((m,), jnp.int32).at[order].set(err_s[:m])
    lo = jnp.clip(jnp.floor(pred).astype(jnp.int32) - err, 0, state.n)
    hi = jnp.clip(jnp.ceil(pred).astype(jnp.int32) + err, 0, state.n)
    return lo, hi


def rmi_lookup(state: F32RMIState, data, queries, interpret: bool = False):
    """End-to-end: fused RMI bounds -> tiled last-mile search -> exact LB."""
    lo, hi = rmi_bounds(state, queries, interpret=interpret)
    del hi
    return lower_bound_windows(
        data, queries, lo, max_width=state.max_err, interpret=interpret
    )
