"""Pure-jnp oracle for the fused RMI inference kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import split_u64


def f32_u(state, queries):
    """Query keys -> normalized f32 coordinate (the kernel's exact math)."""
    qhi, qlo = split_u64(queries)
    qf = qhi.astype(jnp.float32) * jnp.float32(4294967296.0) + qlo.astype(
        jnp.float32
    )
    return (qf - state.x0) * state.inv_range


def rmi_infer_ref(state, queries):
    """(pred, err, bucket) via plain jnp — no tiling, no prefetch."""
    u = f32_u(state, queries)
    p1 = state.c0 * u + state.c1
    bkt = jnp.clip(jnp.floor(p1 * state.scale), 0, state.branching - 1)
    bkt = bkt.astype(jnp.int32)
    pred = jnp.take(state.a2, bkt) * u + jnp.take(state.b2, bkt)
    err = jnp.take(state.err, bkt)
    return pred, err, bkt


def rmi_bounds_ref(state, queries, n: int):
    pred, err, _ = rmi_infer_ref(state, queries)
    pred = jnp.clip(pred, -1.0, float(n) + 1.0)  # guard int32 overflow
    lo = jnp.clip(jnp.floor(pred).astype(jnp.int32) - err, 0, n)
    hi = jnp.clip(jnp.ceil(pred).astype(jnp.int32) + err, 0, n)
    return lo, hi


def rmi_lookup_ref(data, queries):
    """End-to-end ground truth: exact lower bound."""
    return jnp.searchsorted(data, queries, side="left").astype(jnp.int32)
