"""Fused two-stage RMI inference kernel (sorted queries, prefetched tiles).

The paper's RMI hot path is: stage-1 predict -> select stage-2 model ->
stage-2 predict -> error bound.  The stage-2 table (up to millions of rows)
cannot live in VMEM, and per-query HBM gathers are the slowest thing a TPU
can do.  TPU-native form (DESIGN.md §2): the wrapper sorts queries by
bucket, so each query block touches a narrow band of the table; a scalar-
prefetched block index maps exactly two consecutive table tiles into VMEM
per block, and the model gather becomes a small in-VMEM ``take``.

All model math is float32 (TPU has no f64 path); validity is preserved by
re-verifying the per-bucket error table through this exact f32 pipeline at
build time (ops.prepare_f32_state) — the beyond-paper fix for the paper's
§4.2.2 observation that 32-bit math "caused floating point errors".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TABLE_TILE = 2048   # stage-2 rows per VMEM tile (3 arrays * 8 KiB)
QUERY_BLOCK = 1024


def _kernel(
    tile_idx_ref,                      # scalar-prefetch: [n_blocks] int32
    u_ref, bkt_ref,                    # [QUERY_BLOCK] f32 / int32
    a0_ref, b0_ref, e0_ref,            # table tile t
    a1_ref, b1_ref, e1_ref,            # table tile t+1
    pred_ref, err_ref, ok_ref,
):
    g = pl.program_id(0)
    start = tile_idx_ref[g] * TABLE_TILE
    local = bkt_ref[...] - start                   # >= 0 (queries sorted)
    ok = local < 2 * TABLE_TILE
    lidx = jnp.clip(local, 0, 2 * TABLE_TILE - 1)
    a = jnp.take(jnp.concatenate([a0_ref[...], a1_ref[...]]), lidx)
    b = jnp.take(jnp.concatenate([b0_ref[...], b1_ref[...]]), lidx)
    e = jnp.take(jnp.concatenate([e0_ref[...], e1_ref[...]]), lidx)
    pred_ref[...] = a * u_ref[...] + b
    err_ref[...] = e
    ok_ref[...] = ok


def rmi_infer_kernel(
    tile_idx,                # [n_blocks] int32: table tile per query block
    u_sorted, bkt_sorted,    # [m_pad] f32 / int32, sorted by bucket
    a2, b2, err,             # [T_pad] f32 / f32 / int32 stage-2 table
    *, interpret: bool = False,
):
    m_pad = u_sorted.shape[0]
    n_blocks = m_pad // QUERY_BLOCK
    last = a2.shape[0] // TABLE_TILE - 1

    q_spec = pl.BlockSpec((QUERY_BLOCK,), lambda g, s: (g,))
    t_spec0 = pl.BlockSpec((TABLE_TILE,), lambda g, s: (s[g],))
    t_spec1 = pl.BlockSpec(
        (TABLE_TILE,), lambda g, s: (jnp.minimum(s[g] + 1, last),)
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[q_spec, q_spec,
                  t_spec0, t_spec0, t_spec0,
                  t_spec1, t_spec1, t_spec1],
        out_specs=[q_spec, q_spec, q_spec],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m_pad,), jnp.float32),
            jax.ShapeDtypeStruct((m_pad,), jnp.int32),
            jax.ShapeDtypeStruct((m_pad,), jnp.bool_),
        ],
        interpret=interpret,
    )(tile_idx, u_sorted, bkt_sorted, a2, b2, err, a2, b2, err)
