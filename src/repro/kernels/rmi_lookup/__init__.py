from repro.kernels.rmi_lookup.ops import (  # noqa: F401
    F32RMIState,
    prepare_f32_state,
    rmi_bounds,
    rmi_lookup,
)
