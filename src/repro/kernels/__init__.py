"""Pallas TPU kernels for the paper's lookup hot path.

  bounded_search/  tile-binned batched last-mile lower-bound search
  rmi_lookup/      fused two-stage RMI inference (sorted + prefetched tiles)

Each kernel package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper incl. exact fallbacks), ref.py (pure-jnp oracle).  Kernels target
TPU v5e and are validated with interpret=True on CPU (see tests/).
"""
