"""Shared helpers for the TPU lookup kernels.

TPU VPU/MXU have no native 64-bit integer or float64 path, so 64-bit keys
are carried as two uint32 planes (hi, lo) and compared lexicographically —
the hardware adaptation of the paper's 64-bit-key experiments (DESIGN.md §2).
32-bit datasets (paper §4.2.2) use a zero hi plane, one uniform code path.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def split_u64(a):
    """Key array -> (hi, lo) uint32 planes (numpy or jnp).

    32-bit-or-narrower inputs (paper §4.2.2; int32 serving tables) get a
    zero hi plane without ever touching 64-bit ops — usable in contexts
    where jax x64 is disabled."""
    if isinstance(a, np.ndarray):
        if a.dtype.itemsize <= 4:
            lo = a.astype(np.uint32)
            return np.zeros_like(lo), lo
        a = a.astype(np.uint64)
        return (a >> np.uint64(32)).astype(np.uint32), a.astype(np.uint32)
    if jnp.dtype(a.dtype).itemsize <= 4:
        lo = a.astype(jnp.uint32)
        return jnp.zeros_like(lo), lo
    a = a.astype(jnp.uint64)
    return (a >> jnp.uint64(32)).astype(jnp.uint32), a.astype(jnp.uint32)


def merge_u64(hi, lo):
    return (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | np.asarray(
        lo
    ).astype(np.uint64)


def less_u64(a_hi, a_lo, b_hi, b_lo):
    """(a < b) for keys as uint32 planes; works on jnp values in-kernel."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def pad_pow2(x: int, minimum: int = 128) -> int:
    n = minimum
    while n < x:
        n *= 2
    return n


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple
