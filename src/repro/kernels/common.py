"""Shared helpers for the TPU lookup kernels.

TPU VPU/MXU have no native 64-bit integer or float64 path, so 64-bit keys
are carried as two uint32 planes (hi, lo) and compared lexicographically —
the hardware adaptation of the paper's 64-bit-key experiments (DESIGN.md §2).
32-bit datasets (paper §4.2.2) use a zero hi plane, one uniform code path.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def lb_steps(max_width: int) -> int:
    """Fixed trip count covering any bounded window of width <= max_width."""
    return int(np.ceil(np.log2(max(2, int(max_width) + 1)))) + 1


def branchless_lower_bound(data, q, lo, hi, max_width: int,
                           side: str = "left", index_dtype=None):
    """Branchless lower/upper bound in ``[lo, hi]`` (hi INCLUSIVE).

    The ONE bounded binary search in the repo, parameterized by position
    dtype: `repro.core.search.bounded_binary` runs it in int64 (x64 core
    path), the Pallas overflow fallback in `kernels.bounded_search.ops`
    in int32 (kernel wrappers never require x64 mode).  ``max_width`` is
    a static bound on ``hi - lo + 1``; it fixes the trip count so the
    loop lowers to a fixed-depth HLO with no data-dependent control
    flow.  Position ``n`` (one past the end) compares as +infinity.
    """
    n = data.shape[0]
    if index_dtype is None:
        index_dtype = lo.dtype
    lo = lo.astype(index_dtype)
    count = (hi + 1 - lo).astype(index_dtype)
    count = jnp.maximum(count, 0)

    def body(_, carry):
        lo, count = carry
        step = count // 2
        idx = lo + step
        probe = jnp.take(data, jnp.clip(idx, 0, n - 1), mode="clip")
        if side == "left":
            go_right = probe < q
        else:  # upper_bound: first element > q
            go_right = probe <= q
        go_right &= idx < n
        lo = jnp.where(go_right, lo + step + 1, lo)
        count = jnp.where(go_right, count - step - 1, step)
        return lo, count

    lo, _ = jax.lax.fori_loop(0, lb_steps(max_width), body, (lo, count))
    return lo


def split_u64(a):
    """Key array -> (hi, lo) uint32 planes (numpy or jnp).

    32-bit-or-narrower inputs (paper §4.2.2; int32 serving tables) get a
    zero hi plane without ever touching 64-bit ops — usable in contexts
    where jax x64 is disabled."""
    if isinstance(a, np.ndarray):
        if a.dtype.itemsize <= 4:
            lo = a.astype(np.uint32)
            return np.zeros_like(lo), lo
        a = a.astype(np.uint64)
        return (a >> np.uint64(32)).astype(np.uint32), a.astype(np.uint32)
    if jnp.dtype(a.dtype).itemsize <= 4:
        lo = a.astype(jnp.uint32)
        return jnp.zeros_like(lo), lo
    a = a.astype(jnp.uint64)
    return (a >> jnp.uint64(32)).astype(jnp.uint32), a.astype(jnp.uint32)


def merge_u64(hi, lo):
    return (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | np.asarray(
        lo
    ).astype(np.uint64)


def less_u64(a_hi, a_lo, b_hi, b_lo):
    """(a < b) for keys as uint32 planes; works on jnp values in-kernel."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def pad_pow2(x: int, minimum: int = 128) -> int:
    n = minimum
    while n < x:
        n *= 2
    return n


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple
