"""Key-access distributions as *rank samplers* (DESIGN.md §10.1).

A sampler maps ``(rng, size, n_keys)`` to int64 ranks in ``[0, n_keys)``
— which key of the sorted key array each operation touches.  Ranks, not
keys: the same access pattern then composes with any dataset, and a
"hot" rank set stays hot across a compaction that changes key values.

All samplers draw from the caller's `np.random.Generator` in a fixed
order, so a `Workload` is fully determined by its seed (the
reproducibility contract `make_workload` documents).
"""
from __future__ import annotations

import numpy as np

__all__ = ["DISTRIBUTIONS", "uniform_ranks", "zipfian_ranks",
           "hot_set_ranks", "sequential_ranks"]


def uniform_ranks(rng: np.random.Generator, size: int, n_keys: int) -> np.ndarray:
    """Every key equally likely — the paper's own sampling regime."""
    return rng.integers(0, n_keys, size=size, dtype=np.int64)


def zipfian_ranks(rng: np.random.Generator, size: int, n_keys: int,
                  theta: float = 0.99, scramble: bool = True) -> np.ndarray:
    """Bounded zipfian over ranks (YCSB's default skew, theta=0.99).

    Inverse-CDF sampling over the explicit rank weights ``(i+1)^-theta``;
    ``scramble`` applies a seeded permutation so the popular keys are
    spread over the key space instead of clustering at the low end
    (YCSB's "scrambled zipfian" — without it, skew and key locality
    are conflated and a learned index sees an unrealistically easy
    hot range).
    """
    w = np.power(np.arange(1, n_keys + 1, dtype=np.float64), -float(theta))
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    ranks = np.searchsorted(cdf, rng.random(size), side="left").astype(np.int64)
    ranks = np.minimum(ranks, n_keys - 1)
    if scramble:
        ranks = rng.permutation(n_keys)[ranks]
    return ranks


def hot_set_ranks(rng: np.random.Generator, size: int, n_keys: int,
                  hot_frac: float = 0.01, hot_weight: float = 0.9) -> np.ndarray:
    """A random ``hot_frac`` of the keys receives ``hot_weight`` of the
    accesses, uniform within each class — the two-temperature caricature
    of production key popularity."""
    n_hot = int(np.clip(round(n_keys * hot_frac), 1, n_keys))
    perm = rng.permutation(n_keys)
    hot, cold = perm[:n_hot], perm[n_hot:]
    pick_hot = rng.random(size) < hot_weight if len(cold) else np.ones(size, bool)
    hot_draw = hot[rng.integers(0, n_hot, size=size)]
    cold_draw = (cold[rng.integers(0, len(cold), size=size)]
                 if len(cold) else hot_draw)
    return np.where(pick_hot, hot_draw, cold_draw).astype(np.int64)


def sequential_ranks(rng: np.random.Generator, size: int, n_keys: int,
                     stride: int = 1) -> np.ndarray:
    """A scan from a random start, wrapping — the pattern that makes
    range-friendly structures shine and hashing baselines collapse."""
    start = int(rng.integers(0, n_keys))
    return (start + np.arange(size, dtype=np.int64) * int(stride)) % n_keys


DISTRIBUTIONS = {
    "uniform": uniform_ranks,
    "zipfian": zipfian_ranks,
    "hot_set": hot_set_ranks,
    "sequential": sequential_ranks,
}
