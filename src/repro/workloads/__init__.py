"""`repro.workloads` — seeded, composable benchmark workloads (DESIGN.md §10).

The paper restricts itself to read-only lookups with uniformly sampled
keys; this package opens the axis its successors attack: key-access
*distributions* (uniform, zipfian, hot-set, sequential) over present and
absent keys, *operation mixes* (read / insert / range blends in the
YCSB-A/B/C/E mold), and a replayable on-disk trace format, all fully
determined by a seed.  Every benchmark and test consumes the same
`Workload` object instead of ad-hoc `np.random` sampling.
"""
from repro.workloads.distributions import (DISTRIBUTIONS, hot_set_ranks,
                                           sequential_ranks, uniform_ranks,
                                           zipfian_ranks)
from repro.workloads.workload import (MIXES, OP_INSERT, OP_NAMES, OP_RANGE,
                                      OP_READ, Workload, make_point_queries,
                                      make_workload)
from repro.workloads.replay import (oracle_replay, oracle_scan_replay,
                                    replay_on_service)

__all__ = [
    "DISTRIBUTIONS",
    "uniform_ranks",
    "zipfian_ranks",
    "hot_set_ranks",
    "sequential_ranks",
    "MIXES",
    "OP_READ",
    "OP_INSERT",
    "OP_RANGE",
    "OP_NAMES",
    "Workload",
    "make_workload",
    "make_point_queries",
    "oracle_replay",
    "oracle_scan_replay",
    "replay_on_service",
]
