"""Operation mixes, the `Workload` trace object, and its on-disk format.

A `Workload` is a flat, replayable trace: one op code + one uint64
operand per step (plus a scan length for range ops), with the metadata
that produced it.  It is the unit every mixed-workload consumer shares —
`benchmarks/mixed_workload.py`, the mutable-index invariant tests, and
the absent-key query sampling in `data/sosd.py` all draw from here, so
"same seed" means "bit-identical operation stream" across all of them.

Semantics (DESIGN.md §10):

  read    operand is a lookup key; result is ``LB(key)`` over the merged
          (base + delta) view — the paper's lower-bound contract.
  insert  operand is a new key; set semantics (inserting a present key is
          a no-op), result is the 0/1 admitted flag.
  range   operand is the scan start key, ``aux`` the scan length; the
          positioning result is ``LB(key)``, identical to a read — the
          scan itself is sequential post-positioning work.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

import numpy as np

from repro.workloads.distributions import DISTRIBUTIONS

__all__ = ["OP_READ", "OP_INSERT", "OP_RANGE", "OP_NAMES", "MIXES",
           "Workload", "make_workload", "make_point_queries"]

OP_READ, OP_INSERT, OP_RANGE = 0, 1, 2
OP_NAMES = {OP_READ: "read", OP_INSERT: "insert", OP_RANGE: "range"}
_OP_CODES = {v: k for k, v in OP_NAMES.items()}

#: Named operation mixes in the YCSB mold (fractions over {read, insert,
#: range}).  ycsb_c == read_only is kept under both names so sweeps can
#: use the YCSB ladder uniformly.
MIXES: Dict[str, Dict[str, float]] = {
    "read_only": {"read": 1.0},
    "ycsb_a": {"read": 0.5, "insert": 0.5},
    "ycsb_b": {"read": 0.95, "insert": 0.05},
    "ycsb_c": {"read": 1.0},
    "ycsb_e": {"range": 0.95, "insert": 0.05},
}


@dataclasses.dataclass(frozen=True)
class Workload:
    """One replayable trace: parallel op/operand arrays + provenance."""

    ops: np.ndarray      # (m,) uint8 op codes
    keys: np.ndarray     # (m,) uint64 operands
    aux: np.ndarray      # (m,) int64: range length for OP_RANGE, else 0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def n_ops(self) -> int:
        return int(self.ops.size)

    def counts(self) -> Dict[str, int]:
        return {name: int(np.sum(self.ops == code))
                for code, name in OP_NAMES.items()}

    # -- on-disk trace format (one .npz, meta as embedded JSON) ----------
    def save(self, path: str) -> None:
        np.savez(path, ops=self.ops, keys=self.keys, aux=self.aux,
                 meta=np.frombuffer(
                     json.dumps(self.meta).encode(), dtype=np.uint8))

    @staticmethod
    def load(path: str) -> "Workload":
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta"]).decode()) if "meta" in z else {}
            return Workload(ops=z["ops"].astype(np.uint8),
                            keys=z["keys"].astype(np.uint64),
                            aux=z["aux"].astype(np.int64),
                            meta=meta)


def _resolve_mix(mix) -> Dict[str, float]:
    spec = MIXES[mix] if isinstance(mix, str) else dict(mix)
    probs = {op: float(spec.get(op, 0.0)) for op in ("read", "insert", "range")}
    total = sum(probs.values())
    if total <= 0:
        raise ValueError(f"mix {mix!r} has no positive op fraction")
    return {op: p / total for op, p in probs.items()}


def make_workload(keys: np.ndarray, n_ops: int, mix="ycsb_b",
                  dist: str = "zipfian", seed: int = 0,
                  present_frac: float = 0.9, range_len: int = 64,
                  **dist_kw) -> Workload:
    """Generate a seeded trace of ``n_ops`` operations over ``keys``.

    ``mix`` is a name from `MIXES` or a ``{op: fraction}`` dict; ``dist``
    names the rank sampler for read/range targets (`DISTRIBUTIONS`).
    Reads/ranges target a present key with probability ``present_frac``,
    else a uniform absent draw over the padded key range (the paper's §2
    validity definition covers every integer, so absent lookups are part
    of the contract, not an error path).  Insert operands are uniform
    interior draws; already-present ones dedup to no-ops at apply time.

    Determinism: one `np.random.Generator` seeded with ``seed`` drives
    every draw in a fixed order, so equal arguments give bit-identical
    traces on any host.
    """
    keys = np.asarray(keys, dtype=np.uint64)
    if keys.size == 0:
        raise ValueError("empty key set")
    probs = _resolve_mix(mix)
    sampler = DISTRIBUTIONS[dist]
    rng = np.random.default_rng(seed)

    codes = np.array([_OP_CODES[o] for o in ("read", "insert", "range")],
                     dtype=np.uint8)
    ops = rng.choice(codes, size=n_ops,
                     p=[probs["read"], probs["insert"], probs["range"]])

    lo, hi = int(keys[0]), int(keys[-1])
    operand = np.empty(n_ops, dtype=np.uint64)
    aux = np.zeros(n_ops, dtype=np.int64)

    is_point = ops != OP_INSERT          # read + range share the sampler
    n_point = int(is_point.sum())
    if n_point:
        ranks = sampler(rng, n_point, keys.size, **dist_kw)
        target = keys[ranks]
        absent = rng.random(n_point) >= present_frac
        if absent.any():
            target = target.copy()
            # upper bound clamped to 2^64 (exclusive): a key set may
            # legally contain UINT64_MAX (the mutable layer admits it)
            target[absent] = rng.integers(
                max(lo - 1000, 0), min(hi + 1000, 1 << 64),
                size=int(absent.sum()), dtype=np.uint64)
        operand[is_point] = target
    n_ins = n_ops - n_point
    if n_ins:
        operand[~is_point] = rng.integers(
            max(lo, 1), max(hi, 2), size=n_ins, dtype=np.uint64)
    aux[ops == OP_RANGE] = int(range_len)

    meta = dict(mix=(mix if isinstance(mix, str) else probs), dist=dist,
                seed=int(seed), n_keys=int(keys.size),
                present_frac=float(present_frac), range_len=int(range_len),
                **{k: (float(v) if isinstance(v, (int, float)) else v)
                   for k, v in dist_kw.items()})
    return Workload(ops=ops, keys=operand, aux=aux, meta=meta)


def make_point_queries(keys: np.ndarray, m: int, seed: int = 0,
                       present_frac: float = 0.8, dist: str = "uniform",
                       **dist_kw) -> np.ndarray:
    """Seeded point-query batch: ``present_frac`` sampled present keys
    (via the ``dist`` rank sampler) + uniform absent draws, shuffled.

    With ``dist="uniform"`` the draw sequence is exactly the one
    `data/sosd.make_queries` historically produced, so benchmark query
    streams stay bit-reproducible across the migration to this package
    (pinned by tests/test_workloads_mutable.py).
    """
    keys = np.asarray(keys, dtype=np.uint64)
    rng = np.random.default_rng(seed)
    n_present = int(m * present_frac)
    present = keys[DISTRIBUTIONS[dist](rng, n_present, keys.size, **dist_kw)]
    lo, hi = int(keys[0]), int(keys[-1])
    # the min() clamp only departs from the legacy draw where the legacy
    # expression overflowed uint64 (max key above 2^64-1001)
    absent = rng.integers(max(lo - 1000, 0), min(hi + 1000, 1 << 64),
                          size=m - n_present, dtype=np.uint64)
    q = np.concatenate([present, absent])
    rng.shuffle(q)
    return q.astype(np.uint64)
