"""Trace replay: the ground-truth oracle and the service driver.

`oracle_replay` is deliberately naive — a plain sorted numpy array,
`np.searchsorted` for every read, `np.insert` for every admitted insert.
It shares no code with the delta/merge machinery it checks, which is
what makes it an oracle: the mutable-index invariant (DESIGN.md §10.4)
is "every op's result equals this replay's, at every step, across any
number of compactions".

`replay_on_service` drives a `MutableLookupService` through the same
trace, preserving admission order (the order the oracle models), and
returns the per-op results aligned with the oracle's output.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.workloads.workload import OP_INSERT, Workload

__all__ = ["oracle_replay", "replay_on_service"]


def oracle_replay(base_keys: np.ndarray, wl: Workload) -> np.ndarray:
    """Per-op ground truth: LB position for reads/ranges, 0/1 admitted
    flag for inserts (set semantics — a present key is not re-inserted)."""
    arr = np.asarray(base_keys, dtype=np.uint64).copy()
    out = np.empty(wl.n_ops, dtype=np.int64)
    for i in range(wl.n_ops):
        k = wl.keys[i]
        if wl.ops[i] == OP_INSERT:
            p = int(np.searchsorted(arr, k, side="left"))
            if p < len(arr) and arr[p] == k:
                out[i] = 0
            else:
                arr = np.insert(arr, p, k)
                out[i] = 1
        else:
            out[i] = int(np.searchsorted(arr, k, side="left"))
    return out


def replay_on_service(wl: Workload, svc, chunk: int = 64,
                      timeout: Optional[float] = 60.0,
                      compact_every: Optional[int] = None) -> np.ndarray:
    """Drive a `MutableLookupService` through ``wl``; returns per-op
    results aligned with `oracle_replay` (positions for reads/ranges,
    admitted flags for inserts).

    Consecutive same-op runs are submitted as one request (up to
    ``chunk`` ops) — admission order equals trace order, which the
    single-flusher FIFO then turns into apply order, so the results are
    comparable to the oracle with no reordering bookkeeping.  When the
    service has no background flusher, the queue is drained in-line.
    ``compact_every`` forces a synchronous compaction every that many
    ops (on top of the service's own threshold trigger) — the invariant
    says results must not change, so replays use it to pin hot-swap
    correctness mid-trace.
    """
    futs = []      # (start, end, future)
    i = 0
    next_compact = compact_every
    while i < wl.n_ops:
        j = i
        op = wl.ops[i]
        while j < wl.n_ops and wl.ops[j] == op and j - i < chunk:
            j += 1
        ks = wl.keys[i:j]
        fut = svc.insert(ks) if op == OP_INSERT else svc.submit(ks)
        futs.append((i, j, fut))
        if svc._thread is None:
            svc.drain()
        if next_compact is not None and j >= next_compact:
            svc.force_compact()
            next_compact += compact_every
        i = j
    if svc._thread is None:
        svc.drain()
    out = np.empty(wl.n_ops, dtype=np.int64)
    for start, end, fut in futs:
        out[start:end] = fut.result(timeout)
    return out
