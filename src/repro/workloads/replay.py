"""Trace replay: the ground-truth oracle and the service driver.

`oracle_replay` is deliberately naive — a plain sorted numpy array,
`np.searchsorted` for every read, `np.insert` for every admitted insert.
It shares no code with the delta/merge machinery it checks, which is
what makes it an oracle: the mutable-index invariant (DESIGN.md §10.4)
is "every op's result equals this replay's, at every step, across any
number of compactions".

`replay_on_service` drives a `MutableLookupService` through the same
trace, preserving admission order (the order the oracle models), and
returns the per-op results aligned with the oracle's output.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.workloads.workload import OP_INSERT, OP_RANGE, Workload

__all__ = ["oracle_replay", "oracle_scan_replay", "replay_on_service"]

_UINT64_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def oracle_replay(base_keys: np.ndarray, wl: Workload) -> np.ndarray:
    """Per-op ground truth: LB position for reads/ranges, 0/1 admitted
    flag for inserts (set semantics — a present key is not re-inserted)."""
    out, _ = oracle_scan_replay(base_keys, wl, scan_windows=False)
    return out


def oracle_scan_replay(base_keys: np.ndarray, wl: Workload,
                       scan_windows: bool = True,
                       ) -> Tuple[np.ndarray, Dict[int, np.ndarray]]:
    """`oracle_replay` plus, for every OP_RANGE op, the materialized
    window: the ``aux[i]`` keys from the op's LB position over the array
    AS OF that step, padded past the end with UINT64_MAX — the same
    sentinel the plan's windowed gather uses, so service scans compare
    bit-for-bit.  Returns (per-op results, {op index: window})."""
    arr = np.asarray(base_keys, dtype=np.uint64).copy()
    out = np.empty(wl.n_ops, dtype=np.int64)
    windows: Dict[int, np.ndarray] = {}
    for i in range(wl.n_ops):
        k = wl.keys[i]
        if wl.ops[i] == OP_INSERT:
            p = int(np.searchsorted(arr, k, side="left"))
            if p < len(arr) and arr[p] == k:
                out[i] = 0
            else:
                arr = np.insert(arr, p, k)
                out[i] = 1
        else:
            p = int(np.searchsorted(arr, k, side="left"))
            out[i] = p
            if scan_windows and wl.ops[i] == OP_RANGE:
                m = int(wl.aux[i])
                w = np.full(m, _UINT64_MAX, dtype=np.uint64)
                seg = arr[p:p + m]
                w[:seg.size] = seg
                windows[i] = w
    return out, windows


def replay_on_service(wl: Workload, svc, chunk: int = 64,
                      timeout: Optional[float] = 60.0,
                      compact_every: Optional[int] = None,
                      scan_ranges: bool = False):
    """Drive a lookup service through ``wl``; returns per-op results
    aligned with `oracle_replay` (positions for reads/ranges, admitted
    flags for inserts).

    Consecutive same-op runs are submitted as one request (up to
    ``chunk`` ops) — admission order equals trace order, which the
    single-flusher FIFO then turns into apply order, so the results are
    comparable to the oracle with no reordering bookkeeping.  When the
    service has no background flusher, the queue is drained in-line.
    ``compact_every`` forces a synchronous compaction every that many
    ops (on top of the service's own threshold trigger) — the invariant
    says results must not change, so replays use it to pin hot-swap
    correctness mid-trace.

    With ``scan_ranges=True``, OP_RANGE ops execute END-TO-END as op
    kind "scan" (`svc.scan`): each range materializes its ``aux``-length
    record window through the plan's windowed gather, and the return
    value becomes ``(out, windows)`` with ``windows[i]`` comparable
    bit-for-bit to `oracle_scan_replay`'s.  Runs are split on the op
    kind AND scan length (a compile-shape axis).
    """
    futs = []      # (start, end, op, future)
    i = 0
    next_compact = compact_every
    while i < wl.n_ops:
        j = i
        op = wl.ops[i]
        while (j < wl.n_ops and wl.ops[j] == op and j - i < chunk
               and wl.aux[j] == wl.aux[i]):
            j += 1
        ks = wl.keys[i:j]
        if op == OP_INSERT:
            fut = svc.insert(ks)
        elif op == OP_RANGE and scan_ranges:
            fut = svc.scan(ks, int(wl.aux[i]))
        else:
            fut = svc.submit(ks)
        futs.append((i, j, op, fut))
        if svc._thread is None:
            svc.drain()
        if next_compact is not None and j >= next_compact:
            svc.force_compact()
            next_compact += compact_every
        i = j
    if svc._thread is None:
        svc.drain()
    out = np.empty(wl.n_ops, dtype=np.int64)
    windows: Dict[int, np.ndarray] = {}
    for start, end, op, fut in futs:
        res = fut.result(timeout)
        if op == OP_RANGE and scan_ranges:
            pos, win = res
            out[start:end] = pos
            for k in range(start, end):
                windows[k] = win[k - start]
        else:
            out[start:end] = res
    if scan_ranges:
        return out, windows
    return out
