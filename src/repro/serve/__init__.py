"""Serving: paged KV cache with learned-index page lookup, the token
batch engine, and the sharded learned-index lookup service
(`repro.serve.lookup`, DESIGN.md §9)."""
