"""Serving: paged KV cache with learned-index page lookup + batch engine."""
