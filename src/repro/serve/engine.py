"""Batched serving engine: prefill + decode with continuous batching.

Small-scale-runnable (the examples drive a smoke config on CPU) but
structured like the real thing: request queue, paged KV bookkeeping,
greedy sampling, per-request stop handling, step-level batching.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serve.common import MonotonicCounter
from repro.serve.kv_cache import PagedKVCache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_seq: int = 512, page_size: int = 16):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.kv = PagedKVCache(
            n_pages=max_batch * (max_seq // page_size + 1),
            page_size=page_size, max_seqs=max_batch,
            max_pages_per_seq=max_seq // page_size + 1)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self._rids = MonotonicCounter()
        cache_sh = M.cache_shapes(cfg, batch=max_batch, s_max=max_seq)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cache_sh)
        self.lens = np.zeros((max_batch,), np.int32)  # host truth for fills
        self._decode = jax.jit(
            lambda params, cache, toks: M.decode_step(cfg, params, cache, toks))
        self._prefill = jax.jit(
            lambda params, batch: M.forward(cfg, params, batch))

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 16) -> int:
        # Monotonic, never reused — the old queue/active-size formula
        # re-issued an rid once finished requests retired (two clients
        # would then collide in the results dict).
        rid = self._rids.next()
        self.queue.append(Request(rid, list(prompt), max_new))
        return rid

    def _admit(self):
        while self.queue and len(self.active) < self.max_batch:
            req = self.queue.pop(0)
            slot = next(i for i in range(self.max_batch)
                        if i not in self.slot_of.values())
            self.active[req.rid] = req
            self.slot_of[req.rid] = slot
            self.kv.add_sequence(slot, len(req.prompt))
            self._prefill_into_cache(req, slot)

    def _prefill_into_cache(self, req: Request, slot: int):
        """Run the prompt through decode steps to fill the cache slot.

        (A production engine prefills with one forward pass; the step-wise
        fill keeps this engine a single compiled decode graph — fine for
        the CPU-scale examples, and the dry-run lowers the real prefill.)
        """
        self.lens[slot] = 0
        for tok in req.prompt:
            toks = np.zeros((self.max_batch, 1), np.int32)
            toks[slot, 0] = tok
            self.cache = dict(self.cache, len=jnp.asarray(self.lens))
            _, new_cache = self._decode(self.params, self.cache,
                                        jnp.asarray(toks))
            self.lens[slot] += 1  # only this slot advances during prefill
            self.cache = dict(new_cache, len=jnp.asarray(self.lens))

    def step(self) -> Dict[int, int]:
        """One decode step for every active request; returns new tokens."""
        self._admit()
        if not self.active:
            return {}
        toks = np.zeros((self.max_batch, 1), np.int32)
        for rid, req in self.active.items():
            slot = self.slot_of[rid]
            last = req.out[-1] if req.out else req.prompt[-1]
            toks[slot, 0] = last
        self.cache = dict(self.cache, len=jnp.asarray(self.lens))
        logits, new_cache = self._decode(self.params, self.cache,
                                         jnp.asarray(toks))
        logits = np.asarray(logits, np.float32)
        emitted = {}
        for rid, req in list(self.active.items()):
            slot = self.slot_of[rid]
            tok = int(np.argmax(logits[slot][: self.cfg.vocab]))
            req.out.append(tok)
            self.kv.append_token(slot)
            self.lens[slot] += 1
            emitted[rid] = tok
            if len(req.out) >= req.max_new:
                req.done = True
                self.kv.free_sequence(slot)
                del self.active[rid]
                del self.slot_of[rid]
        self.cache = dict(new_cache, len=jnp.asarray(self.lens))
        return emitted

    def run(self, max_steps: int = 256) -> Dict[int, List[int]]:
        finished: Dict[int, List[int]] = {}
        all_reqs: Dict[int, Request] = {}
        for _ in range(max_steps):
            if not (self.queue or self.active):
                break
            for rid, req in self.active.items():
                all_reqs[rid] = req
            self.step()
        for rid, req in all_reqs.items():
            finished[rid] = req.out
        return finished
