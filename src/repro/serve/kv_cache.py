"""Paged KV cache with a learned-index page table (the end-to-end
integration the paper's conclusion calls for).

vLLM-style layout: the cache is a pool of fixed-size pages; each sequence
owns a scattered page list.  Two sorted-array lookups appear on the hot
path, and both are the paper's §2 operation:

  1. flat-slot -> request id: continuous batching packs all live tokens
     into one flat buffer; request boundaries are the cumulative lengths,
     so the mapping is upper_bound(cum_lens, slot).  Served by a LINEAR
     learned model + verified fixup window (the ids' CDF is near-linear by
     construction — the scheduler balances lengths), falling back to the
     tiled bounded_search kernel for the fixup.
  2. logical page -> physical page: a gather through the block table.

Host-side allocation (free list, fragmentation) is numpy; device-side
lookup is jit-compatible int32 math (no x64 needed).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro.kernels.bounded_search.ops import lower_bound_windows


@dataclasses.dataclass
class PageAllocator:
    """Host-side page pool: O(1) alloc/free via a free list."""

    n_pages: int
    page_size: int

    def __post_init__(self):
        self.free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self.owner: Dict[int, int] = {}

    def alloc(self, seq_id: int, n: int = 1) -> List[int]:
        if len(self.free) < n:
            raise MemoryError(f"KV pool exhausted ({n} pages requested, "
                              f"{len(self.free)} free)")
        pages = [self.free.pop() for _ in range(n)]
        for p in pages:
            self.owner[p] = seq_id
        return pages

    def release(self, pages: List[int]):
        for p in pages:
            self.owner.pop(p, None)
            self.free.append(p)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages


class LearnedSlotIndex:
    """flat token slot -> request id via a learned linear CDF model.

    Build: fit slope/intercept over (cum_lens, request ids) and VERIFY the
    worst-case error (same recipe as the RMI error tables: the bound is
    checked, not assumed).  Lookup: predict + fixup window lower-bound.
    """

    def __init__(self, cum_lens: np.ndarray):
        # cum_lens[i] = first flat slot of request i; last entry = total.
        self.cum = np.asarray(cum_lens, np.int64)
        n_req = len(self.cum) - 1
        total = max(int(self.cum[-1]), 1)
        self.slope = n_req / total
        # verified max error of the linear model at the boundaries
        pred = self.cum[:-1] * self.slope
        self.err = int(np.ceil(np.abs(pred - np.arange(n_req)).max())) + 1 \
            if n_req else 1
        self.n_req = n_req

    def lookup(self, slots):
        """slots: jnp int32 [m] -> request ids (jit-compatible)."""
        pred = (slots.astype(jnp.float32) * jnp.float32(self.slope))
        lo = jnp.clip(pred.astype(jnp.int32) - self.err, 0, self.n_req)
        cum = jnp.asarray(self.cum, jnp.int32)
        # upper_bound(cum, slot) - 1 == request id; reuse the tiled kernel
        # contract via its exact fallback (windows are tiny here).
        ub = lower_bound_windows(
            cum, slots.astype(jnp.int32) + 1, lo,
            max_width=2 * self.err + 2)
        return jnp.clip(ub - 1, 0, self.n_req - 1)


class PagedKVCache:
    """Block-table bookkeeping for one layer stack.

    Physical store: [n_pages, page_size, n_kv, hd] per k/v per layer
    (device); here we manage the table + allocator, the engine owns the
    buffers.  ``gather_spec`` produces the int32 indices a decode step
    needs to address scattered pages as if contiguous.
    """

    def __init__(self, n_pages: int, page_size: int, max_seqs: int,
                 max_pages_per_seq: int):
        self.alloc = PageAllocator(n_pages, page_size)
        self.page_size = page_size
        self.table = np.full((max_seqs, max_pages_per_seq), -1, np.int32)
        self.lens = np.zeros((max_seqs,), np.int32)
        self.pages: Dict[int, List[int]] = {}

    def add_sequence(self, seq_id: int, n_tokens: int):
        n_pages = -(-n_tokens // self.page_size)
        pages = self.alloc.alloc(seq_id, n_pages)
        self.pages[seq_id] = pages
        self.table[seq_id, :n_pages] = pages
        self.lens[seq_id] = n_tokens

    def append_token(self, seq_id: int):
        n = int(self.lens[seq_id])
        if n % self.page_size == 0:  # page boundary: grow
            new = self.alloc.alloc(seq_id, 1)[0]
            self.pages[seq_id].append(new)
            self.table[seq_id, n // self.page_size] = new
        self.lens[seq_id] = n + 1

    def free_sequence(self, seq_id: int):
        self.alloc.release(self.pages.pop(seq_id, []))
        self.table[seq_id] = -1
        self.lens[seq_id] = 0

    def gather_spec(self, seq_ids: np.ndarray):
        """For each seq: physical slot of every logical position.

        Returns int32 [len(seq_ids), max_len] flat indices into the page
        pool (page * page_size + offset), -1 past each length."""
        max_len = int(self.lens[seq_ids].max()) if len(seq_ids) else 0
        out = np.full((len(seq_ids), max(max_len, 1)), -1, np.int32)
        for r, sid in enumerate(seq_ids):
            n = int(self.lens[sid])
            logical = np.arange(n)
            phys_page = self.table[sid, logical // self.page_size]
            out[r, :n] = phys_page * self.page_size + logical % self.page_size
        return out

    def slot_index(self) -> LearnedSlotIndex:
        live = np.flatnonzero(self.lens > 0)
        cum = np.concatenate([[0], np.cumsum(self.lens[live])])
        return LearnedSlotIndex(cum)
