"""Small shared primitives for the serving layer.

`MonotonicCounter` is the one source of request ids for both engines
(token `ServeEngine` and the lookup service): ids must never be reused
while any holder can still reference them.  The old `ServeEngine.submit`
derived the rid from queue/active sizes, which re-issues an id as soon
as finished requests retire — two clients then collide in the results
dict.  A counter is trivially unique and, being monotonic, also gives a
free happens-before order for FIFO assertions in tests.
"""
from __future__ import annotations

import itertools
import threading


class MonotonicCounter:
    """Thread-safe monotonically increasing id source.

    `itertools.count.__next__` is atomic under CPython's GIL, but the
    lock keeps the invariant explicit (and true on GIL-free builds).
    """

    def __init__(self, start: int = 0):
        self._it = itertools.count(start)
        self._lock = threading.Lock()

    def next(self) -> int:
        with self._lock:
            return next(self._it)
