"""`MutableLookupService`: reads AND writes through one admission queue.

The mutable face of the lookup service (DESIGN.md §10.5).  Inserts are
admitted through the very same `MicroBatcher` as reads — tagged
``kind="insert"`` — so a single flusher sees one total admission order
and applies it faithfully: a taken batch is split into consecutive
same-kind runs; insert runs land in the `MutableIndex` delta (futures
resolve to per-key 0/1 admitted flags), read runs pin ONE
(generation, delta) view and dispatch the merged lookup through the
sharded dispatcher.  That ordering is exactly what the oracle-replay
invariant is stated against: any read admitted after an insert observes
it once flushed.

Compaction: after an insert run pushes the delta past
``compact_threshold``, a background compaction thread folds base+delta
into a fresh generation via `IndexRegistry.build_and_publish` (the §9.3
hot-swap — rebuilds never block admission or dispatch) and prunes the
delta to the keys admitted mid-rebuild.  Reads in flight complete
against the view they pinned; compaction never changes merged content,
only where it lives, so results are invariant across the swap.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core import spec as spec_mod
from repro.obs.trace import maybe_span
from repro.serve.lookup.admission import LookupFuture
from repro.serve.lookup.executor import AsyncContext, WorkItem
from repro.serve.lookup.registry import DEFAULT_NAME, Generation
from repro.serve.lookup.service import LookupService, LookupServiceConfig

__all__ = ["MutableLookupService", "MutableLookupServiceConfig"]


@dataclasses.dataclass(frozen=True)
class MutableLookupServiceConfig(LookupServiceConfig):
    compact_threshold: int = 4096   # delta keys that trigger a compaction
    auto_compact: bool = True       # spawn the background compactor
    #: Optional budget tuner (DESIGN.md §12.4): when set, every
    #: compaction re-runs the spec search against the delta-merged key
    #: set — the rebuilt generation's spec (and backend) follow the
    #: data instead of staying pinned to the construction-time choice.
    tuner: Optional[spec_mod.Tuner] = None


class MutableLookupService(LookupService):
    #: seconds to wait before respawning the compactor after a failed
    #: compaction — bounds rebuild churn when every rebuild is doomed
    #: (e.g. a builder bug on the merged key set)
    COMPACT_RETRY_BACKOFF_S = 5.0

    def __init__(self, keys: np.ndarray,
                 config: Optional[MutableLookupServiceConfig] = None,
                 mesh=None, counter=None):
        self.mindex = None   # MutableIndex, created by the first swap_keys
        self._compact_thread: Optional[threading.Thread] = None
        self._compact_spawn_mu = threading.Lock()
        self._compact_fail_t: Optional[float] = None
        self.last_compaction_error: Optional[BaseException] = None
        cfg = config if config is not None else MutableLookupServiceConfig()
        if cfg.topology is not None or cfg.shards > 1:
            # the merged (base + delta) view is a single global rank
            # space; range-routing it needs per-shard delta partitioning
            # (ROADMAP open item 3's tiered layer is the natural home)
            raise ValueError(
                "MutableLookupService does not support a routed topology"
                " yet — serve writes through a broadcast service")
        super().__init__(keys, config=cfg, mesh=mesh, counter=counter)

    # -- index lifecycle -------------------------------------------------
    def swap_keys(self, keys: np.ndarray) -> Generation:
        """Replace the WHOLE key set (fresh base, empty delta)."""
        # deferred import: repro.mutable depends on this package's registry
        from repro.mutable.index import MutableIndex

        if self.mindex is None:
            self.mindex = MutableIndex(
                keys, spec=self.cfg.resolved_spec(),
                tuner=self.cfg.tuner,
                compact_threshold=self.cfg.compact_threshold,
                registry=self.registry, name=DEFAULT_NAME,
                pad_quantum=self.cfg.pad_quantum)
            view = self.mindex.view()
        else:
            view = self.mindex.reset(keys)
        self.metrics.set_delta_gauge(
            delta_keys=0, threshold=self.cfg.compact_threshold)
        if self.health is not None:
            self.health.note_delta(0, self.cfg.compact_threshold)
        return view.generation

    # -- client surface --------------------------------------------------
    def insert(self, keys, client=None) -> LookupFuture:
        """Admit an insert request; the future resolves to an int64 0/1
        admitted flag per input key (0 = key already present)."""
        _, fut = self.batcher.submit(keys, kind="insert", client=client)
        return fut

    # -- flusher ---------------------------------------------------------
    def _process_batch(self, batch) -> None:
        """Unlike the immutable service (one pinned context per batch),
        the context re-pins PER RUN: an insert run changes the delta,
        and a read/scan run admitted after it in the same batch must
        observe it — the oracle admission-order invariant."""
        for run in self._runs(batch, key=lambda r: r.kind):
            self._dispatch_run(run[0].kind, run)   # ctx=None: pin per run

    def _dispatch_run(self, kind: str, run, ctx=None) -> None:
        """Insert runs land in the delta; reads and scans route through
        the base service's kind dispatcher."""
        if kind == "insert":
            self._apply_inserts(run)
        else:
            super()._dispatch_run(kind, run, ctx)

    def _pin_context(self):
        """Each run pins one immutable (generation, delta) PAIR — the
        atomic unit that keeps a concurrent compaction from being
        observed half-applied (delta key counted twice or dropped).
        Scans go through the plan's merged-scan transform (sorted union
        of the base and delta windows == a scan over the fully merged
        array).  With health on, reads run the instrumented merged
        executable — same merged ranks, plus BASE-plan stats (the base
        model is what the health record describes)."""
        view = self.mindex.view()
        delta_dev = view.delta.device
        gen = view.generation

        def scan_for(m: int):
            fn = view.scan_fn(m)
            return lambda q: fn(q, delta_dev)

        if self.health is not None:
            ifn = gen.instrumented_merged_fn()
            return (lambda q, n_valid: ifn(q, n_valid, delta_dev),
                    scan_for, gen.version)
        return view.lookup, scan_for, gen.version

    def _insert_apply(self, run) -> np.ndarray:
        """Land one insert run in the delta (host-side, in admission
        order) and record the write-side metrics; returns the per-key
        admitted flags.  Shared by both executors — the async dispatch
        thread applies it at the run's turn, so a later read run in the
        same batch pins a view that already observes it."""
        keys = (run[0].keys if len(run) == 1
                else np.concatenate([r.keys for r in run]))
        t0 = time.perf_counter()
        admitted = self.mindex.insert(keys)
        self.metrics.observe_insert_batch(
            n_keys=keys.size, admitted=int(admitted.sum()),
            t_start=t0, t_end=time.perf_counter())
        self.metrics.set_delta_gauge(
            delta_keys=self.mindex.delta_count,
            threshold=self.mindex.compact_threshold)
        if self.health is not None:
            self.health.note_delta(self.mindex.delta_count,
                                   self.mindex.compact_threshold)
        if self.cfg.auto_compact and self.mindex.needs_compaction:
            self._spawn_compaction()
        return admitted

    def _apply_inserts(self, run) -> None:
        t0 = time.perf_counter()
        try:
            admitted = self._insert_apply(run)
        except BaseException as e:  # noqa: BLE001 — fail the run, not the flusher
            for r in run:
                r.future._set_exception(e)
            return
        off = 0
        for r in run:
            r.future._set_result(admitted[off:off + r.keys.size])
            off += r.keys.size
        if self.recorder is not None:
            t_end = time.perf_counter()
            for r in run:
                self.recorder.request(r.rid, kind="insert",
                                      n_keys=r.keys.size,
                                      t_submit=r.t_submit,
                                      t_launch=t0, t_end=t_end)

    # -- async executor plumbing (DESIGN.md §13) --------------------------
    def _async_context(self) -> AsyncContext:
        """Pin one (generation, delta) view as a cacheable context.  The
        merged fn takes the padded delta as an ARGUMENT (``bind``), so
        the cached executable survives insert traffic; the padded delta
        LENGTH is part of the key — it is a compile-shape axis, and a
        pow2 pad-boundary crossing is a (correct, observable) miss."""
        view = self.mindex.view()
        delta_dev = view.delta.device
        instrumented = self.health is not None
        return AsyncContext(
            key=(view.generation.version, int(delta_dev.shape[0])),
            read_fn=(view.generation.instrumented_merged_fn()
                     if instrumented else view.merged_fn),
            scan_fn=view.scan_fn,
            bind=(delta_dev,),
            sample_key=int(np.asarray(view.generation.data[:1])[0]),
            instrumented=instrumented)

    def _async_work_items(self, batch):
        """Re-pin PER RUN (the sync `_process_batch` contract): an
        insert item is applied when the executor reaches it, and the
        generator resumes with a fresh view for the next run."""
        for run in self._runs(batch, key=lambda r: r.kind):
            kind = run[0].kind
            if kind == "insert":
                yield WorkItem(kind="insert", group=list(run),
                               apply_fn=self._insert_apply)
            else:
                yield from self._async_items_for_run(
                    kind, run, self._async_context())

    def _complete_insert_slot(self, slot) -> None:
        """Resolve a host-ready insert slot in ring order — results were
        computed at apply time; completion only keeps FIFO semantics."""
        admitted = slot.host
        off = 0
        for r in slot.group:
            r.future._set_result(admitted[off:off + r.keys.size])
            off += r.keys.size
        if self.recorder is not None:
            t_end = time.perf_counter()
            for r in slot.group:
                self.recorder.request(r.rid, kind="insert",
                                      n_keys=r.keys.size,
                                      t_submit=r.t_submit,
                                      t_launch=slot.t_launch, t_end=t_end)

    # -- compaction ------------------------------------------------------
    def _spawn_compaction(self) -> None:
        with self._compact_spawn_mu:
            if self._compact_thread is not None and self._compact_thread.is_alive():
                return   # one compactor at a time; it re-checks on exit
            if (self._compact_fail_t is not None
                    and time.perf_counter() - self._compact_fail_t
                    < self.COMPACT_RETRY_BACKOFF_S):
                return   # recent failure: back off instead of churning
            t = threading.Thread(target=self._compact_and_record,
                                 name="lookup-compactor", daemon=True)
            self._compact_thread = t
            t.start()

    def _compact_and_record(self, reraise: bool = False) -> Optional[Generation]:
        t0 = time.perf_counter()
        try:
            with maybe_span(self.recorder, "compaction", cat="lifecycle",
                            delta_keys=int(self.mindex.delta_count)):
                gen = self.mindex.compact()
        except BaseException as e:  # noqa: BLE001 — observable, not thread-fatal
            self.metrics.observe_compaction_failure()
            self.last_compaction_error = e
            self._compact_fail_t = time.perf_counter()
            if reraise:
                raise
            return None
        if gen is None:
            return None
        self._compact_fail_t = None
        self.last_compaction_error = None
        self.metrics.observe_compaction(duration_s=time.perf_counter() - t0)
        self.metrics.set_delta_gauge(
            delta_keys=self.mindex.delta_count,
            threshold=self.mindex.compact_threshold)
        if self.health is not None:
            self.health.note_delta(self.mindex.delta_count,
                                   self.mindex.compact_threshold)
        return gen

    def force_compact(self) -> Optional[Generation]:
        """Synchronous compaction (tests/benchmarks); waits for any
        in-flight background compaction first, then folds what remains.
        Unlike the background path, a failing rebuild raises here."""
        t = self._compact_thread
        if t is not None and t.is_alive():
            t.join()
        return self._compact_and_record(reraise=True)

    def stop(self) -> None:
        super().stop()
        t = self._compact_thread
        if t is not None and t.is_alive():
            t.join()
