"""Async admission queue + micro-batcher (DESIGN.md §9.1).

Requests carry variable-length uint64 key arrays.  Admission is
continuous (callers never block on submit) and flushing is governed by
the two classic triggers of a serving micro-batcher:

  size      pending keys reached ``max_batch`` — flush immediately;
  deadline  the OLDEST pending request has waited ``deadline_s`` — flush
            whatever is pending, however small.

``take()`` drains whole requests in admission order, so completion is
FIFO per client by construction: a request's future can only resolve
after every earlier request's future (batches are dispatched by a single
flusher, in take order).  A request larger than ``max_batch`` is not
split — it forms an oversize batch on its own; the dispatcher pads to a
power-of-two bucket anyway, so the compile-cache cost is the same.

Fairness (optional): with ``max_client_keys`` set, a client that passes
its id to ``submit`` may hold at most that many pending keys — the
(minimal) defense against one client monopolizing every flush window.
``client_rate=(rate, burst)`` adds a per-client token bucket on top:
each client's bucket refills at ``rate`` keys/second up to ``burst``
tokens, and a submit needing more tokens than the bucket holds is
rejected.  Both defenses raise `ClientBacklogFull` immediately
(backpressure at admission, the cheapest point); the strict-FIFO
default behavior is unchanged when unset or the client anonymous.

Requests carry a ``kind`` tag ("read" by default); scans ride the same
queue with ``kind="scan"`` (``aux`` = scan length) and the mutable
service admits inserts with ``kind="insert"``, so reads, scans, and
writes share one admission order — the property the oracle-replay
invariant is stated against.

Latency classes (DESIGN.md §17 satellite): requests also carry a
``priority`` class with a per-class deadline budget
(``class_deadlines={"interactive": 0.002, "batch": 0.05}``).  The
deadline trigger fires at the EARLIEST ``t_submit + deadline(class)``
over everything pending, so an interactive request landing behind
queued batch traffic still bounds its own wait — batch requests merely
stop forcing eager tiny flushes.  Admission order (and therefore FIFO
completion) is unchanged: classes shape WHEN a flush happens, never
reorder requests within it.  Unknown classes fall back to the default
``deadline_s``, and with ``class_deadlines`` unset the behavior is
exactly the classic single-deadline batcher.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.common import MonotonicCounter


class ClientBacklogFull(RuntimeError):
    """Raised at submit() when a client exceeds its pending-key cap."""


class LookupFuture:
    """Per-request completion handle (stdlib-free, two-method surface)."""

    def __init__(self, rid: int, n_keys: int):
        self.rid = rid
        self.n_keys = n_keys
        self._event = threading.Event()
        self._result: Optional[np.ndarray] = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError(f"lookup rid={self.rid} not done")
        if self._exc is not None:
            raise self._exc
        return self._result

    # -- producer side (service internals only) -------------------------
    def _set_result(self, value: np.ndarray) -> None:
        self._result = value
        self._event.set()

    def _set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()


@dataclasses.dataclass
class PendingRequest:
    rid: int
    keys: np.ndarray          # 1-D uint64
    future: LookupFuture
    t_submit: float           # perf_counter at admission
    kind: str = "read"        # "read" | "scan" | "insert" (mutable service)
    aux: int = 0              # scan length for kind="scan", else 0
    client: Optional[object] = None   # fairness-cap accounting id
    #: Admission-time shard routing (DESIGN.md §16): ``(topology, shard
    #: id per key)`` when a router is installed.  Dispatch consumes it
    #: only if the topology object is IDENTICAL to the pinned one — a
    #: hot-swap in between invalidates the tag and dispatch re-routes.
    route: Optional[tuple] = None
    #: Latency class: picks the deadline budget at admission and the
    #: per-class latency accounting in `ServiceMetrics`.
    priority: str = "interactive"


class MicroBatcher:
    """Thread-safe admission queue with size/deadline flush policy."""

    def __init__(self, max_batch: int, deadline_s: float,
                 counter: Optional[MonotonicCounter] = None,
                 max_client_keys: Optional[int] = None,
                 client_rate: Optional[Tuple[float, float]] = None,
                 recorder=None,
                 class_deadlines: Optional[dict] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_client_keys is not None and max_client_keys < 1:
            raise ValueError("max_client_keys must be >= 1")
        if client_rate is not None:
            rate, burst = client_rate
            if rate <= 0 or burst < 1:
                raise ValueError("client_rate needs rate > 0 and burst >= 1")
            client_rate = (float(rate), float(burst))
        if class_deadlines is not None:
            class_deadlines = {str(k): float(v)
                               for k, v in class_deadlines.items()}
            if any(v <= 0 for v in class_deadlines.values()):
                raise ValueError("class deadlines must be > 0 seconds")
        self.max_batch = int(max_batch)
        self.deadline_s = float(deadline_s)
        self.class_deadlines = class_deadlines
        self.max_client_keys = max_client_keys
        self.client_rate = client_rate
        #: optional `repro.obs.trace.SpanRecorder`: admission instants
        #: (one per rid — the trace's request-id origin) and rejections
        self.recorder = recorder
        self._counter = counter if counter is not None else MonotonicCounter()
        #: Optional routing hook ``keys -> (topology, shard ids)`` run at
        #: admission (outside the condition lock) — the vectorized route
        #: step of the range-routed topology.  Installed/cleared by the
        #: service's publish hook; best-effort: a failing router admits
        #: the request untagged and dispatch routes it itself.
        self.router = None
        self._pending: "collections.deque[PendingRequest]" = collections.deque()
        self._n_keys = 0
        #: earliest (t_submit + class deadline) over pending requests —
        #: maintained incrementally on submit, recomputed on take; with
        #: no class map this is always the head's deadline (FIFO submit
        #: times are monotone), i.e. the classic behavior.
        self._next_deadline = float("inf")
        self._client_keys: dict = {}
        self._buckets: dict = {}   # client -> (tokens, last_refill_t)
        self._cond = threading.Condition()

    # -- admission -------------------------------------------------------
    def _check_rate_locked(self, client, n_keys: int, now: float) -> None:
        """Token bucket: refill, then spend ``n_keys`` or reject.  Burst
        bounds the instantaneous spike; rate the sustained key/s."""
        rate, burst = self.client_rate
        tokens, last = self._buckets.get(client, (burst, now))
        tokens = min(burst, tokens + (now - last) * rate)
        if n_keys > tokens:
            self._buckets[client] = (tokens, now)
            raise ClientBacklogFull(
                f"client {client!r} rate-limited: {n_keys} keys > "
                f"{tokens:.1f} tokens (rate={rate}/s, burst={burst:.0f})")
        self._buckets[client] = (tokens - n_keys, now)

    def deadline_for(self, priority: str) -> float:
        """The flush budget of one latency class (falls back to the
        default ``deadline_s`` for unknown classes)."""
        if self.class_deadlines is None:
            return self.deadline_s
        return self.class_deadlines.get(priority, self.deadline_s)

    def submit(self, keys, kind: str = "read", aux: int = 0,
               client=None,
               priority: str = "interactive") -> Tuple[int, LookupFuture]:
        # Always copy: the request may sit queued for deadline_s, and a
        # client reusing its buffer must not mutate keys already admitted.
        keys = np.array(keys, dtype=np.uint64, copy=True).ravel()
        if keys.size == 0:
            raise ValueError("empty key array")
        rid = self._counter.next()
        fut = LookupFuture(rid, keys.size)
        req = PendingRequest(rid, keys, fut, time.perf_counter(),
                             kind=kind, aux=int(aux), client=client,
                             priority=str(priority))
        router = self.router
        if router is not None and kind != "insert":
            try:
                req.route = router(keys)
            except Exception:   # noqa: BLE001 — routing is best-effort here
                req.route = None
        try:
            with self._cond:
                if client is not None:
                    # backlog cap first (checks without consuming), then the
                    # token bucket (consumes) — a cap rejection must not burn
                    # tokens, and a rate rejection must not count as backlog.
                    if self.max_client_keys is not None:
                        held = self._client_keys.get(client, 0)
                        if held + keys.size > self.max_client_keys:
                            raise ClientBacklogFull(
                                f"client {client!r} holds {held} pending keys; "
                                f"+{keys.size} exceeds cap {self.max_client_keys}")
                    if self.client_rate is not None:
                        # timestamp read INSIDE the lock: refills stay monotone
                        # under concurrent submits of the same client
                        self._check_rate_locked(client, keys.size,
                                                time.perf_counter())
                    if self.max_client_keys is not None:
                        self._client_keys[client] = (
                            self._client_keys.get(client, 0) + keys.size)
                self._pending.append(req)
                self._n_keys += keys.size
                self._next_deadline = min(
                    self._next_deadline,
                    req.t_submit + self.deadline_for(req.priority))
                self._cond.notify_all()
        except ClientBacklogFull:
            if self.recorder is not None:
                self.recorder.instant("admission_rejected", cat="admission",
                                      rid=rid, kind=kind,
                                      n_keys=int(keys.size))
            raise
        if self.recorder is not None:
            # outside the condition lock: tracing must not stretch the
            # admission critical section every submitter contends on
            self.recorder.instant("admit", cat="admission", t=req.t_submit,
                                  rid=rid, kind=kind, n_keys=int(keys.size))
        return rid, fut

    def pending_keys_of(self, client) -> int:
        with self._cond:
            return self._client_keys.get(client, 0)

    # -- introspection ---------------------------------------------------
    @property
    def pending_keys(self) -> int:
        with self._cond:
            return self._n_keys

    @property
    def pending_requests(self) -> int:
        with self._cond:
            return len(self._pending)

    # -- flush policy ----------------------------------------------------
    def _ready_locked(self, now: float) -> bool:
        if not self._pending:
            return False
        if self._n_keys >= self.max_batch:
            return True
        return now >= self._next_deadline

    def ready(self) -> bool:
        with self._cond:
            return self._ready_locked(time.perf_counter())

    def wait_ready(self, timeout: Optional[float] = None,
                   until=None) -> bool:
        """Block until a flush is due (size OR deadline) or `timeout`.

        ``until`` is an optional predicate checked on every wake-up:
        when it turns true the wait returns False immediately — paired
        with `wake()`, a flusher can wait with no timeout at all and
        still shut down promptly (no polling loop)."""
        t_end = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while True:
                if until is not None and until():
                    return False
                now = time.perf_counter()
                if self._ready_locked(now):
                    return True
                # sleep until the earliest pending class deadline or the
                # caller's timeout, whichever is sooner; a submit()
                # notify wakes us early to re-check the size trigger (or
                # a tighter deadline a new request just introduced).
                waits = []
                if self._pending:
                    waits.append(self._next_deadline - now)
                if t_end is not None:
                    if now >= t_end:
                        return False
                    waits.append(t_end - now)
                self._cond.wait(timeout=min(waits) if waits else None)

    def wake(self) -> None:
        """Nudge every `wait_ready` waiter to re-check its ``until``
        predicate (shutdown signal — state here does not change)."""
        with self._cond:
            self._cond.notify_all()

    def take(self, force: bool = False) -> List[PendingRequest]:
        """Drain whole requests, in order, up to ``max_batch`` keys.

        Returns [] when no flush is due (unless ``force``).  Always takes
        at least one request when it takes anything, so an oversize
        request cannot deadlock the queue.
        """
        with self._cond:
            if not self._pending:
                return []
            if not force and not self._ready_locked(time.perf_counter()):
                return []
            out: List[PendingRequest] = []
            taken = 0
            while self._pending:
                nxt = self._pending[0]
                if out and taken + nxt.keys.size > self.max_batch:
                    break
                out.append(self._pending.popleft())
                taken += nxt.keys.size
            self._n_keys -= taken
            self._next_deadline = min(
                (r.t_submit + self.deadline_for(r.priority)
                 for r in self._pending), default=float("inf"))
            for r in out:
                if r.client is not None and r.client in self._client_keys:
                    left = self._client_keys[r.client] - r.keys.size
                    if left > 0:
                        self._client_keys[r.client] = left
                    else:
                        del self._client_keys[r.client]
            # prune refilled-to-burst buckets: a full bucket is identical
            # to no bucket, and ephemeral client ids must not leak memory
            if self.client_rate is not None and self._buckets:
                rate, burst = self.client_rate
                now = time.perf_counter()
                for c in [c for c, (tok, last) in self._buckets.items()
                          if tok + (now - last) * rate >= burst]:
                    del self._buckets[c]
            return out
