"""Index generations with atomic hot-swap (DESIGN.md §9.3).

A `Generation` is one fully-built, immutable serving unit: the
`IndexBuild` (state pytree + interpreting functions), the device copy of
the sorted key array, the `LookupPlan` the build lowers to, and the
plan-compiled lookup for the generation's backend.  The registry's only
mutable cell is a name -> Generation pointer; `publish` replaces that
pointer AFTER the build completes, so a reader can observe the old
generation or the new one, never a half-built one.  Swapping does not
drain in-flight batches: a dispatched batch pins the generation it was
taken with (`service._process_batch` reads `current()` exactly once per
batch via `_pin_context`; the mutable service re-pins per same-kind run
so reads observe earlier insert runs) and completes against it even if
a swap lands mid-batch.

Rebuilds (`build_and_publish`) run entirely outside the lock — index
construction is seconds of host-side numpy (benchmarks/build_times.csv)
and must never stall admission or dispatch.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import base
from repro.core import spec as spec_mod
from repro.core.plan import LookupPlan
from repro.obs.trace import maybe_span
from repro.serve.common import MonotonicCounter
from repro.serve.lookup.dispatch import make_plan

DEFAULT_NAME = "default"


@dataclasses.dataclass(frozen=True)
class Generation:
    """One immutable, fully-built serving generation."""

    version: int
    build: base.IndexBuild
    data: Any                 # jnp device copy of the sorted keys
    plan: LookupPlan          # the build lowered to the plan IR
    fn: Callable              # plan-compiled lookup: queries -> positions
    n_keys: int
    backend: str = "jnp"      # plan backend this generation serves with
    #: The validated `IndexSpec` this generation was built from — the
    #: serializable address of the serving unit (hot-swap, sharded
    #: dispatch, and the services are spec-addressable through it).
    #: `spec.backend`/`spec.last_mile` always reflect what the
    #: generation actually serves with.
    spec: Optional[spec_mod.IndexSpec] = None

    def scan_fn(self, m: int) -> Callable:
        """Plan-compiled scan (positions + m-record window), cached on
        the plan per (m, backend) — op kind "scan" dispatches here."""
        return self.plan.compile_scan(m, backend=self.backend)

    def instrumented_fn(self) -> Callable:
        """Plan-compiled instrumented lookup ``(q, n_valid) -> (LB,
        health stats)`` — same positions as ``fn`` bit-for-bit, plus the
        device-reduced stats the health monitor folds in."""
        return self.plan.compile_instrumented(backend=self.backend)

    def instrumented_merged_fn(self) -> Callable:
        """Instrumented merged-view lookup ``(q, n_valid, delta) ->
        (merged LB, base-plan health stats)`` for the mutable service."""
        return self.plan.compile_instrumented_merged(backend=self.backend)


class IndexRegistry:
    def __init__(self):
        self._lock = threading.Lock()
        self._versions = MonotonicCounter()
        self._current: Dict[str, Generation] = {}
        self._subscribers: list = []
        #: optional `repro.obs.trace.SpanRecorder` (set by the owning
        #: service): hot-swap builds and publish instants become
        #: lifecycle spans, so a latency blip during a swap is visually
        #: attributable in the exported trace.
        self.recorder = None
        #: optional `repro.obs.health.HealthMonitor` (set by the owning
        #: service): every publish opens a per-generation health record
        #: keyed by version, so stats from a batch that completes against
        #: a just-retired generation still land in ITS record.
        self.health = None

    def subscribe(self, callback) -> None:
        """Register ``callback(name, generation)`` to run after every
        publish (outside the registry lock, on the publishing thread).
        The executable cache hangs its invalidation-on-swap here: the
        moment a new generation is visible, stale executables are
        evicted and a warm-up of the new generation can be scheduled.
        Callbacks must be cheap or hand off — a publish can come from a
        compaction thread holding its own locks."""
        with self._lock:
            self._subscribers.append(callback)

    def current(self, name: str = DEFAULT_NAME) -> Generation:
        with self._lock:
            gen = self._current.get(name)
        if gen is None:
            raise KeyError(f"no generation published under {name!r}")
        return gen

    def publish(self, build: base.IndexBuild, data,
                name: str = DEFAULT_NAME,
                last_mile: Optional[str] = None,
                backend: str = "jnp",
                spec: Optional[spec_mod.IndexSpec] = None) -> Generation:
        """Lower a COMPLETE IndexBuild to its plan, wrap it into a
        generation, and swap it in.  ``spec`` defaults to the spec the
        build carries (`spec.build` stamps it into ``meta``) and is
        re-aligned to the backend/last-mile the generation serves with."""
        plan = make_plan(build, data, last_mile=last_mile)
        if spec is None:
            spec = build.meta.get("spec")
        if spec is not None:
            spec = spec.replace(backend=backend,
                                last_mile=last_mile if last_mile is not None
                                else spec.last_mile)
        gen = Generation(
            version=self._versions.next(),
            build=build,
            data=data,
            plan=plan,
            fn=plan.compile(backend=backend),
            n_keys=int(data.shape[0]),
            backend=backend,
            spec=spec,
        )
        with self._lock:
            self._current[name] = gen
            subscribers = list(self._subscribers)
        if self.health is not None:
            self.health.on_publish(gen)
        if self.recorder is not None:
            self.recorder.instant("publish", cat="lifecycle", reg_name=name,
                                  version=gen.version, index=gen.plan.name,
                                  n_keys=gen.n_keys)
        for cb in subscribers:
            cb(name, gen)
        return gen

    def build_and_publish(self, index, keys: np.ndarray,
                          hyper: Optional[Dict[str, Any]] = None,
                          name: str = DEFAULT_NAME,
                          last_mile: Optional[str] = None,
                          backend: Optional[str] = None) -> Generation:
        """Rebuild on a fresh key set, then swap — build is outside the
        lock, the swap is one pointer assignment.

        ``index`` is an `IndexSpec` (the declarative path — DESIGN.md
        §12; ``hyper`` must then be None and explicit ``last_mile``/
        ``backend`` args override the spec's) or a registry name with a
        ``hyper`` dict (legacy callers), which is folded into a
        validated spec so every build runs through `spec.build`.
        """
        sp = spec_mod.coerce(index, hyper, backend=backend,
                             last_mile=last_mile)
        keys = np.asarray(keys, dtype=np.uint64)
        with maybe_span(self.recorder, "index_build", cat="lifecycle",
                        reg_name=name, index=sp.index, n_keys=int(keys.size)):
            build = spec_mod.build(sp, keys)
            data = jnp.asarray(keys)
        return self.publish(build, data, name=name, last_mile=sp.last_mile,
                            backend=sp.backend, spec=sp)

    def health_records(self, window_s: float = 10.0) -> list:
        """Per-generation health records (empty when no monitor is
        attached) — the registry-facing view `/health.json` exports."""
        if self.health is None:
            return []
        return self.health.records(window_s)
