"""Index generations with atomic hot-swap (DESIGN.md §9.3).

A `Generation` is one fully-built, immutable serving unit: the
`IndexBuild` (state pytree + interpreting functions), the device copy of
the sorted key array, the `LookupPlan` the build lowers to, and the
plan-compiled lookup for the generation's backend.  The registry's only
mutable cell is a name -> Generation pointer; `publish` replaces that
pointer AFTER the build completes, so a reader can observe the old
generation or the new one, never a half-built one.  Swapping does not
drain in-flight batches: a dispatched batch pins the generation it was
taken with (`service._process_batch` reads `current()` exactly once per
batch via `_pin_context`; the mutable service re-pins per same-kind run
so reads observe earlier insert runs) and completes against it even if
a swap lands mid-batch.

Rebuilds (`build_and_publish`) run entirely outside the lock — index
construction is seconds of host-side numpy (benchmarks/build_times.csv)
and must never stall admission or dispatch.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import base
from repro.core import spec as spec_mod
from repro.core.plan import LookupPlan
from repro.obs.trace import maybe_span
from repro.serve.common import MonotonicCounter
from repro.serve.lookup.dispatch import make_plan
from repro.serve.lookup.topology import ShardTopology

DEFAULT_NAME = "default"


@dataclasses.dataclass(frozen=True)
class Generation:
    """One immutable, fully-built serving generation."""

    version: int
    build: base.IndexBuild
    data: Any                 # jnp device copy of the sorted keys
    plan: LookupPlan          # the build lowered to the plan IR
    fn: Callable              # plan-compiled lookup: queries -> positions
    n_keys: int
    backend: str = "jnp"      # plan backend this generation serves with
    #: The validated `IndexSpec` this generation was built from — the
    #: serializable address of the serving unit (hot-swap, sharded
    #: dispatch, and the services are spec-addressable through it).
    #: `spec.backend`/`spec.last_mile` always reflect what the
    #: generation actually serves with.
    spec: Optional[spec_mod.IndexSpec] = None
    #: Shard index inside a RoutedGeneration (None for broadcast
    #: generations) — threaded into per-shard health records.
    shard: Optional[int] = None

    def scan_fn(self, m: int) -> Callable:
        """Plan-compiled scan (positions + m-record window), cached on
        the plan per (m, backend) — op kind "scan" dispatches here."""
        return self.plan.compile_scan(m, backend=self.backend)

    def fn_for(self, donate: bool = False) -> Callable:
        """Plan-compiled lookup, optionally donating the query buffer
        (safe on the dispatcher's staged placements; no-op on CPU)."""
        return self.plan.compile(backend=self.backend, donate=donate)

    def instrumented_fn(self, donate: bool = False) -> Callable:
        """Plan-compiled instrumented lookup ``(q, n_valid) -> (LB,
        health stats)`` — same positions as ``fn`` bit-for-bit, plus the
        device-reduced stats the health monitor folds in."""
        return self.plan.compile_instrumented(backend=self.backend,
                                              donate=donate)

    def instrumented_merged_fn(self) -> Callable:
        """Instrumented merged-view lookup ``(q, n_valid, delta) ->
        (merged LB, base-plan health stats)`` for the mutable service."""
        return self.plan.compile_instrumented_merged(backend=self.backend)


@dataclasses.dataclass(frozen=True, eq=False)
class RoutedGeneration:
    """One published *set* of per-shard generations plus the topology
    that routes into them (DESIGN.md §16).

    Swaps atomically as a unit: the registry pointer flips to the whole
    RoutedGeneration, so a pinned batch observes one consistent
    (topology, shard builds) pair even while a re-publish is in flight.
    Shard ``s`` serves keys in ``(split[s-1], split[s]]`` with its own
    (smaller, per-slice tuned) plan; the routed global rank is
    ``topology.offsets[s] + LB_local``.
    """

    version: int
    topology: ShardTopology
    shards: Tuple[Generation, ...]
    spec: Optional[spec_mod.IndexSpec] = None
    backend: str = "jnp"
    _scan_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_keys(self) -> int:
        return self.topology.n_keys

    @property
    def shard_versions(self) -> Tuple[int, ...]:
        return tuple(s.version for s in self.shards)

    @property
    def plan(self) -> LookupPlan:
        """First shard's plan — shape/name probe only; never dispatch
        through it directly (it covers one key range)."""
        return self.shards[0].plan

    @property
    def point_only(self) -> bool:
        return any(s.plan.point_only for s in self.shards)

    @property
    def max_err(self) -> int:
        return max(s.plan.bounds.max_err for s in self.shards)

    @property
    def max_scan_len(self) -> int:
        """Largest exact routed scan width: a shard-s window is repaired
        with the first ``m`` records of shard s+1, which only covers the
        spill when every shard holds at least ``m`` keys."""
        return self.topology.min_shard_len

    def shard_scan_fn(self, s: int, m: int) -> Callable:
        """Scan for shard ``s``: the shard-local window merged with the
        head of shard ``s+1``.  All shard-s records sort strictly below
        all shard-(s+1) records (boundaries are snapped to duplicate
        runs), so the first ``m`` of the sorted union is exactly the
        global window — the same argument as the delta merged scan."""
        key = (int(s), int(m))
        fn = self._scan_cache.get(key)
        if fn is not None:
            return fn
        gen = self.shards[s]
        if s == len(self.shards) - 1:
            fn = gen.scan_fn(m)          # sentinel padding is global here
        else:
            import jax
            from repro.core.plan import _window_gather

            run = gen.plan.lb_expr(backend=gen.backend)
            data = gen.plan.data
            head = self.shards[s + 1].data[:m]

            def scan(q):
                pos = run(q)
                wb = _window_gather(data, pos, m)
                spill = jnp.broadcast_to(head[None, :], (q.shape[0], m))
                merged = jnp.sort(
                    jnp.concatenate([wb, spill], axis=1), axis=1)[:, :m]
                return pos, merged

            fn = jax.jit(scan)
        self._scan_cache[key] = fn
        return fn


class IndexRegistry:
    def __init__(self):
        self._lock = threading.Lock()
        self._versions = MonotonicCounter()
        self._current: Dict[str, Generation] = {}
        self._subscribers: list = []
        #: optional `repro.obs.trace.SpanRecorder` (set by the owning
        #: service): hot-swap builds and publish instants become
        #: lifecycle spans, so a latency blip during a swap is visually
        #: attributable in the exported trace.
        self.recorder = None
        #: optional `repro.obs.health.HealthMonitor` (set by the owning
        #: service): every publish opens a per-generation health record
        #: keyed by version, so stats from a batch that completes against
        #: a just-retired generation still land in ITS record.
        self.health = None

    def subscribe(self, callback) -> None:
        """Register ``callback(name, generation)`` to run after every
        publish (outside the registry lock, on the publishing thread).
        The executable cache hangs its invalidation-on-swap here: the
        moment a new generation is visible, stale executables are
        evicted and a warm-up of the new generation can be scheduled.
        Callbacks must be cheap or hand off — a publish can come from a
        compaction thread holding its own locks."""
        with self._lock:
            self._subscribers.append(callback)

    def current(self, name: str = DEFAULT_NAME) -> Generation:
        with self._lock:
            gen = self._current.get(name)
        if gen is None:
            raise KeyError(f"no generation published under {name!r}")
        return gen

    def publish(self, build: base.IndexBuild, data,
                name: str = DEFAULT_NAME,
                last_mile: Optional[str] = None,
                backend: str = "jnp",
                spec: Optional[spec_mod.IndexSpec] = None) -> Generation:
        """Lower a COMPLETE IndexBuild to its plan, wrap it into a
        generation, and swap it in.  ``spec`` defaults to the spec the
        build carries (`spec.build` stamps it into ``meta``) and is
        re-aligned to the backend/last-mile the generation serves with."""
        gen = self.make_generation(build, data, last_mile=last_mile,
                                   backend=backend, spec=spec)
        with self._lock:
            self._current[name] = gen
            subscribers = list(self._subscribers)
        if self.health is not None:
            self.health.on_publish(gen)
        if self.recorder is not None:
            self.recorder.instant("publish", cat="lifecycle", reg_name=name,
                                  version=gen.version, index=gen.plan.name,
                                  n_keys=gen.n_keys)
        for cb in subscribers:
            cb(name, gen)
        return gen

    def publish_prebuilt(self, gen: Generation,
                         name: str = DEFAULT_NAME) -> Generation:
        """Swap in a Generation made earlier with `make_generation` —
        the autotune retuner's path (DESIGN.md §17): the candidate is
        compiled and oracle-VERIFIED off the hot path first, and the
        very object that passed verification is what goes live
        (publish-after-verify, never rebuild-after-verify).  Same
        health/trace/subscriber fan-out as `publish`."""
        with self._lock:
            self._current[name] = gen
            subscribers = list(self._subscribers)
        if self.health is not None:
            self.health.on_publish(gen)
        if self.recorder is not None:
            self.recorder.instant("publish", cat="lifecycle", reg_name=name,
                                  version=gen.version, index=gen.plan.name,
                                  n_keys=gen.n_keys)
        for cb in subscribers:
            cb(name, gen)
        return gen

    def make_generation(self, build: base.IndexBuild, data,
                        last_mile: Optional[str] = None,
                        backend: str = "jnp",
                        spec: Optional[spec_mod.IndexSpec] = None,
                        shard: Optional[int] = None) -> Generation:
        """Lower a build to a versioned Generation WITHOUT publishing it
        — the routed publish path assembles several of these and swaps
        them in as one unit."""
        plan = make_plan(build, data, last_mile=last_mile)
        if spec is None:
            spec = build.meta.get("spec")
        if spec is not None:
            spec = spec.replace(backend=backend,
                                last_mile=last_mile if last_mile is not None
                                else spec.last_mile)
        return Generation(
            version=self._versions.next(),
            build=build,
            data=data,
            plan=plan,
            fn=plan.compile(backend=backend),
            n_keys=int(data.shape[0]),
            backend=backend,
            spec=spec,
            shard=shard,
        )

    def publish_routed(self, shard_gens, topology: ShardTopology,
                       name: str = DEFAULT_NAME,
                       spec: Optional[spec_mod.IndexSpec] = None,
                       backend: str = "jnp") -> RoutedGeneration:
        """Swap a complete shard set in as one RoutedGeneration."""
        rgen = RoutedGeneration(
            version=self._versions.next(),
            topology=topology,
            shards=tuple(shard_gens),
            spec=spec,
            backend=backend,
        )
        with self._lock:
            self._current[name] = rgen
            subscribers = list(self._subscribers)
        if self.health is not None:
            self.health.on_publish_group(rgen.shards)
        if self.recorder is not None:
            self.recorder.instant(
                "publish", cat="lifecycle", reg_name=name,
                version=rgen.version, index=rgen.plan.name,
                n_keys=rgen.n_keys, n_shards=topology.n_shards)
        for cb in subscribers:
            cb(name, rgen)
        return rgen

    def build_and_publish_routed(self, index, keys: np.ndarray,
                                 topology: ShardTopology,
                                 hyper: Optional[Dict[str, Any]] = None,
                                 name: str = DEFAULT_NAME,
                                 last_mile: Optional[str] = None,
                                 backend: Optional[str] = None,
                                 tuner: Optional[spec_mod.Tuner] = None
                                 ) -> RoutedGeneration:
        """Build one generation per topology range and swap the set in.

        With a ``tuner``, each shard's spec is searched against ONLY its
        slice (per-shard byte budget = total / shards); without one,
        every shard reuses the coerced spec — smaller slices still give
        tighter error bounds for the same hyperparameters.
        """
        sp = spec_mod.coerce(index, hyper, backend=backend,
                             last_mile=last_mile)
        keys = np.asarray(keys, dtype=np.uint64)
        offs = topology.offsets
        shard_specs = [sp] * topology.n_shards
        builds = [None] * topology.n_shards
        if tuner is not None:
            results = tuner.tune_shards(keys, offs)
            shard_specs = [r.spec for r in results]
            builds = [r.build for r in results]
        gens = []
        with maybe_span(self.recorder, "index_build", cat="lifecycle",
                        reg_name=name, index=sp.index,
                        n_keys=int(keys.size),
                        n_shards=topology.n_shards):
            for s in range(topology.n_shards):
                sl = keys[offs[s]:offs[s + 1]]
                b = builds[s] if builds[s] is not None \
                    else spec_mod.build(shard_specs[s], sl)
                gens.append(self.make_generation(
                    b, jnp.asarray(sl),
                    last_mile=shard_specs[s].last_mile,
                    backend=shard_specs[s].backend,
                    spec=shard_specs[s], shard=s))
        return self.publish_routed(gens, topology, name=name, spec=sp,
                                   backend=sp.backend)

    def build_and_publish(self, index, keys: np.ndarray,
                          hyper: Optional[Dict[str, Any]] = None,
                          name: str = DEFAULT_NAME,
                          last_mile: Optional[str] = None,
                          backend: Optional[str] = None) -> Generation:
        """Rebuild on a fresh key set, then swap — build is outside the
        lock, the swap is one pointer assignment.

        ``index`` is an `IndexSpec` (the declarative path — DESIGN.md
        §12; ``hyper`` must then be None and explicit ``last_mile``/
        ``backend`` args override the spec's) or a registry name with a
        ``hyper`` dict (legacy callers), which is folded into a
        validated spec so every build runs through `spec.build`.
        """
        sp = spec_mod.coerce(index, hyper, backend=backend,
                             last_mile=last_mile)
        keys = np.asarray(keys, dtype=np.uint64)
        with maybe_span(self.recorder, "index_build", cat="lifecycle",
                        reg_name=name, index=sp.index, n_keys=int(keys.size)):
            build = spec_mod.build(sp, keys)
            data = jnp.asarray(keys)
        return self.publish(build, data, name=name, last_mile=sp.last_mile,
                            backend=sp.backend, spec=sp)

    def health_records(self, window_s: float = 10.0) -> list:
        """Per-generation health records (empty when no monitor is
        attached) — the registry-facing view `/health.json` exports."""
        if self.health is None:
            return []
        return self.health.records(window_s)
