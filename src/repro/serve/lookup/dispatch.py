"""Sharded dispatch: one plan-compiled lookup over the `data` mesh axis (§9.2).

Generalizes mode (c) of `benchmarks/parallel_scaling.py` into a reusable
engine.  The query batch is padded to a power-of-two bucket (a multiple
of the shard count), placed over the mesh's data axis through the
`repro.dist.sharding` activation rule for the logical `batch` axis, and
run through a `repro.core.plan.LookupPlan` executable — the dispatcher
shards PLANS, not hand-rolled closures: pass a plan and it compiles (and
caches) the lookup for the requested backend, or pass any jitted
callable (e.g. a merged-view or scan executable) directly.  jit picks
the partitioning up from the input sharding, so the very same compiled
lookup serves 1 or N devices; the index state and the key array stay
replicated (they are the small side — the paper's learned indexes are
KB–MB against GB of data).

Bit-exactness: every lane of the plan pipeline is an independent
gather/compare chain over the same replicated arrays, so the sharded
result is identical — not approximately, bit-for-bit — to the
single-device result on the same queries (pinned by
tests/test_serve_lookup.py on all four surrogate datasets, and across
backends by tests/test_plan.py).  Pad lanes repeat the first real key
and are sliced off before completion.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.dist import sharding as SH

#: Smallest dispatch width: keeps tiny deadline-flush batches from
#: compiling one program per size, and matches the 128-lane TPU register.
PAD_QUANTUM = 128


def make_plan(build, data_jnp, last_mile: Optional[str] = None):
    """Lower one index generation to its `LookupPlan`.

    ``last_mile`` defaults to the hyperparameter the index was built
    with, falling back to binary — same policy as the benchmarks.
    """
    return plan_mod.lower(build, data_jnp, last_mile=last_mile)


def data_axis_mesh():
    """1-D mesh over every local device, axis named `data` — the serving
    analogue of the production mesh's data axis (launch/mesh.py)."""
    return jax.make_mesh((len(jax.devices()),), ("data",))


class ShardedDispatcher:
    """Pads, places, and runs query batches over the data mesh axis."""

    def __init__(self, mesh=None, pad_quantum: int = PAD_QUANTUM,
                 recorder=None):
        self.mesh = data_axis_mesh() if mesh is None else mesh
        self.pad_quantum = int(pad_quantum)
        # one rule walk for everyone: the dist layer owns the policy
        self.n_shards = SH.dispatch_groups(mesh=self.mesh,
                                           rules=SH.ACT_RULES)
        #: optional `repro.obs.trace.SpanRecorder`: the synchronous
        #: dispatch path splits into a pad+place span (host-side data
        #: movement) and a device span (launch + block), so a slow batch
        #: names which half it spent its time in.
        self.recorder = recorder

    def padded_size(self, m: int) -> int:
        """Next power-of-two >= max(m, quantum), then up to a multiple of
        the shard count — bounds distinct compiled shapes at log2(max)."""
        p = self.pad_quantum
        while p < m:
            p <<= 1
        r = p % self.n_shards
        return p + (self.n_shards - r if r else 0)

    def query_sharding(self, p: int):
        """The placement of a padded query batch — one rule walk through
        the dist layer (also what AOT executable lowering keys on)."""
        return SH.act_sharding((p,), ("batch",), self.mesh)

    def place(self, q_padded: np.ndarray):
        """Device-put one already-padded batch over the data axis."""
        q_padded = np.asarray(q_padded, dtype=np.uint64)
        return jax.device_put(jnp.asarray(q_padded),
                              self.query_sharding(q_padded.size))

    def pad_and_place(self, keys: np.ndarray):
        """Pad to the pow2 bucket and place on the mesh; returns
        ``(device batch, padded size)`` — the launch half of dispatch."""
        keys = np.asarray(keys, dtype=np.uint64)
        m = keys.size
        p = self.padded_size(m)
        if p != m:
            q = np.empty(p, np.uint64)
            q[:m] = keys
            q[m:] = keys[0]  # any valid key: lanes are independent
        else:
            q = keys
        return self.place(q), p

    @staticmethod
    def finalize(out, m: int, instrumented: bool = False):
        """Block on a launched computation and slice off the pad lanes —
        the completion half of dispatch (the only point that waits on
        the device, which is what the async executor overlaps).

        With ``instrumented``, ``out`` is ``(payload, packed stats)``:
        the payload is finalized recursively while the packed stats
        vector — already a fixed-size device reduction with pad lanes
        masked out on device — crosses to host in ONE transfer, never
        sliced.
        """
        if instrumented:
            payload, stats = out
            return (ShardedDispatcher.finalize(payload, m),
                    np.asarray(stats))
        if isinstance(out, tuple):
            return tuple(np.asarray(o)[:m] for o in out)
        return np.asarray(out, dtype=np.int64)[:m]

    def __call__(self, fn, keys: np.ndarray, backend: str = "jnp",
                 n_valid_arg: bool = False):
        """Run a plan (compiled on demand for ``backend``) or any jitted
        lookup callable on `keys`, synchronously: launch then finalize.

        Returns int64 positions for plain lookups; executables that
        return a tuple (e.g. a plan's scan: positions + record window)
        come back as a tuple of host arrays, each sliced to the real
        batch size along axis 0.  ``n_valid_arg=True`` passes the real
        (pre-pad) batch size as a dynamic int32 scalar second argument —
        the instrumented-executable convention.
        """
        from repro.obs.trace import maybe_span

        if isinstance(fn, plan_mod.LookupPlan):
            fn = fn.compile(backend=backend)
        keys = np.asarray(keys, dtype=np.uint64)
        with maybe_span(self.recorder, "pad_place", cat="serve",
                        n_keys=int(keys.size)):
            qj, p = self.pad_and_place(keys)
        with maybe_span(self.recorder, "device", cat="serve",
                        padded=int(p), n_shards=self.n_shards):
            out = fn(qj, np.int32(keys.size)) if n_valid_arg else fn(qj)
            return self.finalize(out, keys.size,
                                 instrumented=n_valid_arg)
