"""Sharded dispatch: one plan-compiled lookup over the `data` mesh axis (§9.2).

Generalizes mode (c) of `benchmarks/parallel_scaling.py` into a reusable
engine.  The query batch is padded to a power-of-two bucket (a multiple
of the shard count), placed over the mesh's data axis through the
`repro.dist.sharding` activation rule for the logical `batch` axis, and
run through a `repro.core.plan.LookupPlan` executable — the dispatcher
shards PLANS, not hand-rolled closures: pass a plan and it compiles (and
caches) the lookup for the requested backend, or pass any jitted
callable (e.g. a merged-view or scan executable) directly.  jit picks
the partitioning up from the input sharding, so the very same compiled
lookup serves 1 or N devices; the index state and the key array stay
replicated (they are the small side — the paper's learned indexes are
KB–MB against GB of data).

Bit-exactness: every lane of the plan pipeline is an independent
gather/compare chain over the same replicated arrays, so the sharded
result is identical — not approximately, bit-for-bit — to the
single-device result on the same queries (pinned by
tests/test_serve_lookup.py on all four surrogate datasets, and across
backends by tests/test_plan.py).  Pad lanes repeat the first real key
and are sliced off before completion.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.dist import sharding as SH

#: Smallest dispatch width: keeps tiny deadline-flush batches from
#: compiling one program per size, and matches the 128-lane TPU register.
PAD_QUANTUM = 128


def make_plan(build, data_jnp, last_mile: Optional[str] = None):
    """Lower one index generation to its `LookupPlan`.

    ``last_mile`` defaults to the hyperparameter the index was built
    with, falling back to binary — same policy as the benchmarks.
    """
    return plan_mod.lower(build, data_jnp, last_mile=last_mile)


def data_axis_mesh():
    """1-D mesh over every local device, axis named `data` — the serving
    analogue of the production mesh's data axis (launch/mesh.py)."""
    return jax.make_mesh((len(jax.devices()),), ("data",))


class ShardedDispatcher:
    """Pads, places, and runs query batches over the data mesh axis."""

    def __init__(self, mesh=None, pad_quantum: int = PAD_QUANTUM,
                 recorder=None):
        self.mesh = data_axis_mesh() if mesh is None else mesh
        self.pad_quantum = int(pad_quantum)
        # one rule walk for everyone: the dist layer owns the policy
        self.n_shards = SH.dispatch_groups(mesh=self.mesh,
                                           rules=SH.ACT_RULES)
        #: optional `repro.obs.trace.SpanRecorder`: the synchronous
        #: dispatch path splits into a pad+place span (host-side data
        #: movement) and a device span (launch + block), so a slow batch
        #: names which half it spent its time in.
        self.recorder = recorder
        # Pinned host staging buffers, one per pow2 bucket: padding into
        # a reused buffer instead of a fresh np.empty per batch.
        # Single-writer is guaranteed by the service's dispatch lock
        # (sync) / the executor's launch mutex (async); `pad_and_place`
        # blocks on the placement before returning, because the
        # host-to-device copy is asynchronous and the next batch reuses
        # the buffer.
        self._staging: dict = {}
        self.staging_hits = 0
        self.staging_allocs = 0

    def padded_size(self, m: int) -> int:
        """Next power-of-two >= max(m, quantum), then up to a multiple of
        the shard count — bounds distinct compiled shapes at log2(max)."""
        p = self.pad_quantum
        while p < m:
            p <<= 1
        r = p % self.n_shards
        return p + (self.n_shards - r if r else 0)

    def query_sharding(self, p: int):
        """The placement of a padded query batch — one rule walk through
        the dist layer (also what AOT executable lowering keys on)."""
        return SH.act_sharding((p,), ("batch",), self.mesh)

    def place(self, q_padded: np.ndarray):
        """Device-put one already-padded batch over the data axis."""
        q_padded = np.asarray(q_padded, dtype=np.uint64)
        return jax.device_put(jnp.asarray(q_padded),
                              self.query_sharding(q_padded.size))

    def pad_and_place(self, keys: np.ndarray):
        """Pad to the pow2 bucket and place on the mesh; returns
        ``(device batch, padded size)`` — the launch half of dispatch."""
        keys = np.asarray(keys, dtype=np.uint64)
        m = keys.size
        p = self.padded_size(m)
        if p != m:
            q = self._staging.get(p)
            if q is None:
                # Deliberately 64-byte-MISALIGNED view: XLA's CPU
                # zero-copy fast path aliases an owning, 64-byte-aligned
                # numpy array into the "device" buffer outright (x64
                # mode preserves uint64, so nothing forces a convert-
                # copy), and an aliased staging buffer corrupts every
                # in-flight batch the moment the next batch pads into
                # it.  Misalignment forces real copy semantics on every
                # placement.
                raw = np.empty(p + 8, np.uint64)
                off = 1 if raw.ctypes.data % 64 == 0 else 0
                q = raw[off:off + p]
                self._staging[p] = q
                self.staging_allocs += 1
            else:
                self.staging_hits += 1
            q[:m] = keys
            q[m:] = keys[0]  # any valid key: lanes are independent
        else:
            q = keys
        qj = self.place(q)
        if q is not keys:
            # The staging buffer is rewritten by the very next batch of
            # this bucket, but jax's host-to-device transfer reads the
            # host bytes ASYNCHRONOUSLY — returning before the copy has
            # happened lets batch N+1's pad overwrite batch N's queries
            # in flight (observed as a whole sub-batch answering for the
            # following batch).  Block on the placement: the wait is the
            # memcpy only, device compute still overlaps.
            jax.block_until_ready(qj)
        return qj, p

    @staticmethod
    def finalize(out, m: int, instrumented: bool = False):
        """Block on a launched computation and slice off the pad lanes —
        the completion half of dispatch (the only point that waits on
        the device, which is what the async executor overlaps).

        With ``instrumented``, ``out`` is ``(payload, packed stats)``:
        the payload is finalized recursively while the packed stats
        vector — already a fixed-size device reduction with pad lanes
        masked out on device — crosses to host in ONE transfer, never
        sliced.
        """
        if instrumented:
            payload, stats = out
            return (ShardedDispatcher.finalize(payload, m),
                    np.asarray(stats))
        if isinstance(out, tuple):
            return tuple(np.asarray(o)[:m] for o in out)
        return np.asarray(out, dtype=np.int64)[:m]

    def __call__(self, fn, keys: np.ndarray, backend: str = "jnp",
                 n_valid_arg: bool = False):
        """Run a plan (compiled on demand for ``backend``) or any jitted
        lookup callable on `keys`, synchronously: launch then finalize.

        Returns int64 positions for plain lookups; executables that
        return a tuple (e.g. a plan's scan: positions + record window)
        come back as a tuple of host arrays, each sliced to the real
        batch size along axis 0.  ``n_valid_arg=True`` passes the real
        (pre-pad) batch size as a dynamic int32 scalar second argument —
        the instrumented-executable convention.
        """
        from repro.obs.trace import maybe_span

        if isinstance(fn, plan_mod.LookupPlan):
            fn = fn.compile(backend=backend)
        keys = np.asarray(keys, dtype=np.uint64)
        with maybe_span(self.recorder, "pad_place", cat="serve",
                        n_keys=int(keys.size)):
            qj, p = self.pad_and_place(keys)
        with maybe_span(self.recorder, "device", cat="serve",
                        padded=int(p), n_shards=self.n_shards):
            out = fn(qj, np.int32(keys.size)) if n_valid_arg else fn(qj)
            return self.finalize(out, keys.size,
                                 instrumented=n_valid_arg)


# ---------------------------------------------------------------------------
# Range-routed dispatch (DESIGN.md §16)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RoutedContext:
    """Everything one routed batch pins at dispatch time.

    ``lane_ctxs[s][r]`` is the executor context (read/scan executables +
    cache key) of replica ``r`` of shard ``s`` — the executable cache
    keys on ``(shard generation version, replica)``, so AOT executables
    stay committed to their lane's device.  Fields are intentionally
    untyped: the executor imports this class, not the other way round.
    """

    topology: Any                       # ShardTopology
    lane_ctxs: Tuple[Tuple[Any, ...], ...]
    offsets: Tuple[int, ...]
    versions: Tuple[int, ...]           # per-shard generation versions
    version: int                        # RoutedGeneration version
    instrumented: bool = False

    @property
    def key(self):
        """Executor slot identity — mirrors AsyncContext.key[0]."""
        return (self.version,)


class _RoutedHandle:
    """One launched routed batch: per-shard in-flight outputs plus the
    inverse permutation that restores admission order at finalize."""

    def __init__(self, subs, order, counts, padded, m, kind,
                 instrumented, rctx):
        self.subs = subs                # [(shard, lane out), ...]
        self.order = order              # admission index per sorted key
        self.counts = counts            # keys per shard (all shards)
        self.padded = padded            # summed per-shard padded sizes
        self.m = m
        self.kind = kind
        self.instrumented = instrumented
        self.rctx = rctx

    def finalize(self):
        """Block per shard, lift local ranks to global (``+ offsets[s]``),
        and gather through the inverse permutation — results come back in
        exact admission order, which is what keeps routed completion FIFO
        per request.  Returns ``(result, stats, padded)`` where ``stats``
        is a list of ``(shard generation version, packed stats)``.
        """
        offs = self.rctx.offsets
        starts = np.zeros(len(self.counts) + 1, dtype=np.int64)
        np.cumsum(self.counts, out=starts[1:])
        pos = np.empty(self.m, dtype=np.int64)
        win = None
        stats = []
        for s, out in self.subs:
            c = int(self.counts[s])
            fin = ShardedDispatcher.finalize(out, c, self.instrumented)
            if self.instrumented:
                fin, st = fin
                stats.append((self.rctx.versions[s], st))
            idx = self.order[starts[s]:starts[s] + c]
            if isinstance(fin, tuple):        # scan: (pos, window)
                if win is None:
                    win = np.empty((self.m,) + fin[1].shape[1:],
                                   fin[1].dtype)
                pos[idx] = np.asarray(fin[0], dtype=np.int64) + offs[s]
                win[idx] = fin[1]
            else:
                pos[idx] = fin + offs[s]
        if win is not None:
            return (pos, win), stats, self.padded
        return pos, stats, self.padded


class RoutedDispatcher:
    """Scatter/gather dispatch over range-partitioned shard lanes.

    One single-device `ShardedDispatcher` per (shard, replica) lane —
    each lane reuses the broadcast dispatcher's padding, staging, and
    placement machinery verbatim, just pinned to its own device.  The
    route step buckets each admitted key to its owning shard (host
    searchsorted at admission, or the device branchless upper bound via
    `ShardTopology.route_device`); per-shard sub-batches launch without
    blocking, and `_RoutedHandle.finalize` gathers them back into
    admission order.  Per-device work drops from O(batch) to
    O(batch/shards).
    """

    def __init__(self, topology, devices=None,
                 pad_quantum: int = PAD_QUANTUM, recorder=None):
        self.pad_quantum = int(pad_quantum)
        self.recorder = recorder
        self._rr_lock = threading.Lock()
        self.lanes_epoch = 0
        self._devices = list(devices) if devices is not None \
            else list(jax.devices())
        self._build_lanes(topology)

    def _build_lanes(self, topology):
        groups = SH.shard_replica_groups(self._devices, topology.replicas)
        self.lanes = tuple(
            tuple(ShardedDispatcher(
                mesh=jax.sharding.Mesh(np.array([dev]), ("data",)),
                pad_quantum=self.pad_quantum, recorder=self.recorder)
                for dev in grp)
            for grp in groups)
        self._rr = [0] * len(groups)
        self.replicas = tuple(topology.replicas)

    def set_replicas(self, topology) -> bool:
        """Rebuild lanes when the shard/replica layout changes; bumps
        ``lanes_epoch`` so cached lane contexts are re-derived."""
        if (len(self.lanes) == topology.n_shards
                and self.replicas == tuple(topology.replicas)):
            return False
        self._build_lanes(topology)
        self.lanes_epoch += 1
        return True

    @property
    def n_shards(self) -> int:
        return len(self.lanes)

    def padded_size(self, m: int) -> int:
        """Worst-case single-lane bucket for warm planning (actual
        routed padding is per sub-batch)."""
        return self.lanes[0][0].padded_size(m)

    def _pick(self, s: int) -> int:
        """Round-robin read fan-out over shard ``s``'s replicas."""
        with self._rr_lock:
            r = self._rr[s]
            self._rr[s] = (r + 1) % len(self.lanes[s])
        return r

    @property
    def staging_allocs(self) -> int:
        return sum(d.staging_allocs for grp in self.lanes for d in grp)

    @property
    def staging_hits(self) -> int:
        return sum(d.staging_hits for grp in self.lanes for d in grp)

    @staticmethod
    def routes_for(group, topology):
        """Admission-time shard ids for a batch of requests, or None if
        any request missed the route step or was routed against a
        different (hot-swapped) topology — identity, not equality: a
        republish must force a re-route."""
        sids = []
        for req in group:
            route = getattr(req, "route", None)
            if route is None or route[0] is not topology:
                return None
            sids.append(route[1])
        return np.concatenate(sids) if sids else None

    def launch(self, rctx: RoutedContext, kind: str, aux: int,
               keys: np.ndarray, routes=None, exec_cache=None):
        """Scatter one admitted batch over its shard lanes; returns a
        `_RoutedHandle` (completion is the handle's ``finalize``).

        ``exec_cache`` (async path) resolves each lane's AOT executable;
        without it (sync path) the lane context's jitted callables run
        directly.  Empty shards launch nothing.
        """
        from repro.obs.trace import maybe_span

        keys = np.asarray(keys, dtype=np.uint64)
        m = keys.size
        topo = rctx.topology
        instr = rctx.instrumented and kind != "scan"
        with maybe_span(self.recorder, "route", cat="serve",
                        n_keys=int(m), n_shards=self.n_shards):
            sid = routes if routes is not None else topo.route(keys)
            order = np.argsort(sid, kind="stable")
            counts = np.bincount(sid, minlength=self.n_shards)
            sorted_keys = keys[order]
        subs = []
        padded = 0
        start = 0
        for s in range(self.n_shards):
            c = int(counts[s])
            if c == 0:
                continue
            sub = sorted_keys[start:start + c]
            start += c
            r = self._pick(s)
            lane = self.lanes[s][r]
            ctx = rctx.lane_ctxs[s][r]
            qj, p = lane.pad_and_place(sub)
            padded += p
            make_fn = ((lambda c=ctx: c.read_fn) if kind != "scan"
                       else (lambda c=ctx, a=aux: c.scan_fn(int(a))))
            if exec_cache is not None:
                exe = exec_cache.get(ctx, kind, aux, p, make_fn, lane)
            else:
                exe = make_fn()
            out = exe(qj, np.int32(c)) if instr else exe(qj)
            subs.append((s, out))
        return _RoutedHandle(subs, order, counts, padded, m, kind,
                             instr, rctx)

    def __call__(self, rctx: RoutedContext, kind: str, aux: int,
                 keys: np.ndarray, routes=None):
        """Synchronous routed dispatch: launch then finalize."""
        return self.launch(rctx, kind, aux, keys, routes=routes).finalize()
