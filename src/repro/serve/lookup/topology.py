"""Range-partitioned serving topology (DESIGN.md §16).

A ``ShardTopology`` splits the sorted key space into contiguous ranges.
Shard ``s`` owns the half-open key interval

    (split_points[s-1], split_points[s]]        (uint64, inclusive right)

so a query ``q`` routes to ``searchsorted(split_points, q, side='left')``:
queries below the global minimum land in shard 0, queries above the global
maximum land in the last shard, and a query exactly equal to a split point
routes to the shard that *owns* that key (``side='left'`` is load-bearing:
``split_points[s]`` IS shard ``s``'s last key, and its lower-bound rank —
the first occurrence of that key — lives inside shard ``s``).  Boundaries are snapped left to
the first occurrence of the boundary key, so every duplicate of a split
key lives entirely inside one shard — that is what makes the routed
lower-bound rank ``offsets[s] + LB_local(q)`` bit-identical to the global
``LB(q)`` even for duplicated keys.

The topology is a value object carried by registry generations; the
dispatcher, health monitor, and metrics all consume it read-only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class ShardTopology:
    """Contiguous range partition of a sorted uint64 key space.

    ``split_points`` has ``n_shards - 1`` entries: ``split_points[s]`` is
    the last key owned by shard ``s`` (i.e. ``keys[offsets[s+1] - 1]``).
    ``offsets`` has ``n_shards + 1`` entries into the global sorted array.
    ``replicas[s]`` is the read fan-out of shard ``s`` (>= 1).
    """

    split_points: np.ndarray           # uint64[S-1]
    offsets: Tuple[int, ...]           # len S+1, offsets[0] == 0
    replicas: Tuple[int, ...]          # len S, each >= 1
    n_keys: int
    _dev_splits: dict = dataclasses.field(default_factory=dict, repr=False)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_keys(cls, keys, n_shards: int,
                  replicas: int | Sequence[int] = 1) -> "ShardTopology":
        """Equal-count range partition of a *sorted* uint64 key array.

        Raw equal-count boundaries are snapped left to the first
        occurrence of the boundary key so duplicates never straddle a
        split; collapsed boundaries are deduped, so the effective shard
        count can be smaller than requested on heavily-duplicated data.
        """
        keys = np.asarray(keys)
        n = int(keys.size)
        if n == 0:
            raise ValueError("cannot build a topology over zero keys")
        n_shards = int(n_shards)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        n_shards = min(n_shards, n)
        raw = [round(s * n / n_shards) for s in range(1, n_shards)]
        offs = [0]
        for off in raw:
            # Snap left so every duplicate of the boundary key lands in
            # the *later* shard (routing sends q == split to the earlier
            # shard, which then owns the full duplicate run's LB rank).
            snapped = int(np.searchsorted(keys, keys[off], side="left"))
            if snapped > offs[-1]:
                offs.append(snapped)
        offs.append(n)
        splits = np.asarray([keys[o - 1] for o in offs[1:-1]],
                            dtype=np.uint64)
        s_eff = len(offs) - 1
        if isinstance(replicas, int):
            reps = (int(replicas),) * s_eff
        else:
            reps = tuple(int(r) for r in replicas)[:s_eff]
            reps = reps + (1,) * (s_eff - len(reps))
        if any(r < 1 for r in reps):
            raise ValueError("every shard needs at least one replica")
        return cls(split_points=splits, offsets=tuple(offs),
                   replicas=reps, n_keys=n)

    @classmethod
    def single(cls, n_keys: int) -> "ShardTopology":
        """Degenerate one-shard topology (routes everything to shard 0)."""
        return cls(split_points=np.empty(0, dtype=np.uint64),
                   offsets=(0, int(n_keys)), replicas=(1,),
                   n_keys=int(n_keys))

    # -- shape -----------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.offsets) - 1

    @property
    def shard_sizes(self) -> Tuple[int, ...]:
        return tuple(self.offsets[s + 1] - self.offsets[s]
                     for s in range(self.n_shards))

    @property
    def min_shard_len(self) -> int:
        return min(self.shard_sizes)

    # -- routing ---------------------------------------------------------
    def route(self, keys) -> np.ndarray:
        """Host-side shard id per key (int64), admission-time path."""
        if self.n_shards == 1:
            return np.zeros(np.asarray(keys).shape, dtype=np.int64)
        return np.searchsorted(self.split_points,
                               np.asarray(keys, dtype=np.uint64),
                               side="left").astype(np.int64)

    def route_device(self, q):
        """Device-side shard id per key via the branchless lower bound.

        Same primitive the lookup kernels use (``side='left'`` = first
        split >= q, so a query equal to a split routes to the shard that
        owns it), and the routed path stays a pure jnp expression when
        routing inside a jitted program.
        """
        import jax.numpy as jnp
        from repro.kernels.common import branchless_lower_bound

        if self.n_shards == 1:
            return jnp.zeros(q.shape, dtype=jnp.int32)
        key = ("splits", q.dtype.name) if hasattr(q, "dtype") else "splits"
        splits = self._dev_splits.get(key)
        if splits is None:
            splits = jnp.asarray(self.split_points)
            self._dev_splits[key] = splits
        m = int(splits.shape[0])
        lo = jnp.zeros(q.shape, dtype=jnp.int32)
        hi = jnp.full(q.shape, m - 1, dtype=jnp.int32)
        return branchless_lower_bound(splits, q.astype(splits.dtype),
                                      lo, hi, max_width=m, side="left",
                                      index_dtype=jnp.int32)

    # -- replica policy --------------------------------------------------
    def rebalanced(self, traffic_hist,
                   total_replicas: Optional[int] = None) -> "ShardTopology":
        """New topology with replicas re-apportioned to observed traffic.

        ``traffic_hist`` is the PR 8 key-space traffic histogram — counts
        over equal-width *rank* buckets of the global key space.  Each
        bucket's mass is prorated onto the shard rank ranges it overlaps;
        replica seats are then assigned largest-remainder with a floor of
        one per shard, holding the total seat count fixed (or growing it
        to ``total_replicas``).
        """
        hist = np.asarray(traffic_hist, dtype=np.float64)
        total = int(total_replicas if total_replicas is not None
                    else sum(self.replicas))
        s_eff = self.n_shards
        total = max(total, s_eff)
        if hist.size == 0 or hist.sum() <= 0:
            share = np.full(s_eff, 1.0 / s_eff)
        else:
            edges = np.linspace(0, self.n_keys, hist.size + 1)
            share = np.zeros(s_eff)
            for s in range(s_eff):
                lo, hi = self.offsets[s], self.offsets[s + 1]
                # fraction of each rank bucket inside [lo, hi)
                overlap = (np.minimum(edges[1:], hi)
                           - np.maximum(edges[:-1], lo))
                frac = np.clip(overlap, 0.0, None) / np.maximum(
                    edges[1:] - edges[:-1], 1e-9)
                share[s] = float((hist * frac).sum())
            share = share / share.sum() if share.sum() > 0 else np.full(
                s_eff, 1.0 / s_eff)
        return self._apportion(share, total)

    def rebalanced_from_masses(self, masses,
                               total_replicas: Optional[int] = None
                               ) -> "ShardTopology":
        """Same policy, driven by per-shard traffic masses directly
        (what the service reads off each shard's health record)."""
        masses = np.asarray(masses, dtype=np.float64)
        total = int(total_replicas if total_replicas is not None
                    else sum(self.replicas))
        s_eff = self.n_shards
        total = max(total, s_eff)
        share = (masses / masses.sum() if masses.sum() > 0
                 else np.full(s_eff, 1.0 / s_eff))
        return self._apportion(share, total)

    def _apportion(self, share: np.ndarray, total: int) -> "ShardTopology":
        s_eff = self.n_shards
        quota = share * (total - s_eff)   # floor of 1 seat each, then LR
        reps = np.ones(s_eff, dtype=np.int64) + np.floor(quota).astype(
            np.int64)
        rem = quota - np.floor(quota)
        for s in np.argsort(-rem)[: total - int(reps.sum())]:
            reps[s] += 1
        return ShardTopology(split_points=self.split_points,
                             offsets=self.offsets,
                             replicas=tuple(int(r) for r in reps),
                             n_keys=self.n_keys)

    def describe(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "n_keys": self.n_keys,
            "shard_sizes": list(self.shard_sizes),
            "replicas": list(self.replicas),
            "split_points": [int(s) for s in self.split_points],
        }
