"""Continuous-batching async executor + executable cache (DESIGN.md §13).

The synchronous dispatch path (`LookupService._dispatch_once`) is serial
batch-at-a-time: take a batch, trace/compile on first contact, block on
the device, complete futures, only then admit the next batch.  The p99
of that loop is bounded by Python dispatch and first-touch compilation,
not by kernel time (`benchmarks/results/serve_throughput.json`).  This
module rebuilds the path the way LLM inference servers do:

  executable cache   `ExecutableCache` maps ``(context key, kind, aux,
                     pow2 batch bucket)`` to a ready-to-run executable —
                     AOT-lowered (`jitted.lower(...).compile()`) against
                     the dispatcher's padded bucket shape and batch
                     sharding where the callable supports it, the primed
                     jit wrapper otherwise.  Steady-state dispatch never
                     re-traces or re-compiles; warm-up primes the common
                     buckets at `start()` and again after every hot-swap
                     (`IndexRegistry` publish subscription), off the
                     dispatch thread.

  double buffering   the DISPATCH thread takes a batch, pins its
                     context, pads, places, and LAUNCHES the device step
                     without blocking on it (jax async dispatch); the
                     COMPLETION thread blocks on device results and
                     resolves futures.  Admission and host-side
                     completion of batch N overlap the in-flight device
                     execution of batch N+1.

  slot ring          launched batches ride a bounded FIFO ring of
                     in-flight slots.  A straggler (scan run, cold
                     bucket) occupies one slot; admission (`submit`)
                     never blocks, and the dispatch thread only waits
                     when the whole ring is full — bounded in-flight
                     memory, no unbounded queue growth.  Completing
                     slots strictly in ring order preserves the global
                     admission order, hence per-client FIFO completion.

Every result is bit-identical to the synchronous path: both execute the
same plan-compiled programs over the same padded buckets, and positions/
windows are exact integers (pinned across the index × backend matrix by
tests/test_serve_executor.py).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.obs.trace import maybe_span
from repro.serve.lookup.dispatch import RoutedContext

__all__ = ["AsyncContext", "AsyncExecutor", "ExecutableCache", "WorkItem"]


@dataclasses.dataclass(frozen=True)
class AsyncContext:
    """One pinned lookup context, executable-cache addressable.

    ``key`` namespaces the cache: everything the compiled program
    depends on beyond operand shapes — the generation version (and, for
    merged mutable views, the padded delta length, a compile-shape
    axis).  ``bind`` holds extra device operands appended after the
    query batch (the padded delta for merged lookups); they vary per
    view without invalidating the cached executable, which is exactly
    why the merged fn takes the delta as an ARGUMENT, not a closure.
    """

    key: Tuple                 # hashable; key[0] is the generation version
    read_fn: Callable          # (q, *bind) -> positions
    scan_fn: Callable          # m -> ((q, *bind) -> (positions, window))
    bind: Tuple = ()           # device operands appended after q
    sample_key: int = 1        # a valid key for warm-up dummy batches
    #: Health telemetry (DESIGN.md §15): when set, ``read_fn`` is the
    #: plan's instrumented executable ``(q, n_valid, *bind) -> (pos,
    #: stats)`` — reads pass the real batch size as a dynamic int32
    #: scalar and completion strips the stats off for the monitor.
    instrumented: bool = False


@dataclasses.dataclass
class WorkItem:
    """One dispatchable unit: a same-kind request group + how to run it."""

    kind: str                           # "read" | "scan" | "insert"
    group: List                         # PendingRequests, admission order
    ctx: Optional[AsyncContext] = None  # device kinds only
    aux: int = 0                        # scan length for kind="scan"
    apply_fn: Optional[Callable] = None  # host op (inserts): group -> array


@dataclasses.dataclass
class _Slot:
    """One in-flight ring entry.  Exactly one of (out, host, error) is
    meaningful: a launched device computation, a host-side result that
    is already final (inserts), or a launch failure to propagate."""

    group: List
    kind: str
    out: Any = None              # in-flight device output (async dispatch)
    m: int = 0                   # real key count (pre-padding)
    padded: int = 0
    host: Any = None             # host-ready result (inserts)
    error: Optional[BaseException] = None
    t_submit_oldest: float = 0.0
    t_launch: float = 0.0
    is_insert: bool = False
    version: int = -1            # generation the stats (if any) belong to
    instrumented: bool = False   # out is (payload, packed health stats)
    routed: bool = False         # out is a dispatch._RoutedHandle


_STOP = object()


class ExecutableCache:
    """(context key, kind, aux, bucket) -> ready-to-run executable.

    The cache makes compilation an explicit, observable event instead of
    a silent p99 outlier: a **miss** builds the executable (AOT when the
    callable is a jitted function, fallback to the callable itself — the
    plan layer's jit wrappers keep their own shape-keyed trace cache, so
    a stored wrapper never re-traces for a bucket it has seen); a
    **hit** dispatches a pre-compiled program with only data operands
    changing.  Counters feed `ServiceMetrics` so a zero steady-state hit
    rate (per-batch recompiles) is a test failure, not a latency
    mystery.  `invalidate(keep_version=...)` evicts every entry of older
    generations on hot-swap; in-flight slots hold direct references to
    their executables, so eviction never races a running batch.
    """

    def __init__(self, metrics=None, recorder=None):
        self._mu = threading.Lock()
        self._exes: dict = {}
        self.hits = 0
        self.misses = 0
        self.warm_compiles = 0
        self.metrics = metrics
        #: optional `repro.obs.trace.SpanRecorder`: every build becomes
        #: a "compile" span — the p99 outlier the cache exists to hide
        #: is visible (and attributable) in the exported trace.
        self.recorder = recorder

    # -- stats -----------------------------------------------------------
    def counters(self) -> Tuple[int, int]:
        with self._mu:
            return self.hits, self.misses

    @property
    def hit_rate(self) -> float:
        with self._mu:
            n = self.hits + self.misses
            return self.hits / n if n else 0.0

    def __len__(self) -> int:
        with self._mu:
            return len(self._exes)

    # -- build/get -------------------------------------------------------
    @staticmethod
    def _build(fn, bucket: int, bind: Tuple, dispatcher,
               instrumented: bool = False):
        """AOT-lower ``fn`` for the padded bucket (batch-sharded query +
        replicated bind operands) when it supports `.lower`; otherwise
        return the callable unchanged (jit wrappers carry their own
        per-shape cache; injected plain callables just run).
        Instrumented executables take the real batch size as a dynamic
        int32 scalar between the query and the bind operands — ONE
        compiled program per bucket, not one per occupancy."""
        import jax
        import jax.numpy as jnp

        lower = getattr(fn, "lower", None)
        if lower is None:
            return fn
        try:
            sds_q = jax.ShapeDtypeStruct(
                (bucket,), jnp.uint64,
                sharding=dispatcher.query_sharding(bucket))
            sds_args = ([jax.ShapeDtypeStruct((), jnp.int32)]
                        if instrumented else [])
            sds_args += [jax.ShapeDtypeStruct(b.shape, b.dtype)
                         for b in bind]
            return lower(sds_q, *sds_args).compile()
        except Exception:   # noqa: BLE001 — AOT is an optimization only
            return fn

    def get(self, ctx: AsyncContext, kind: str, aux: int, bucket: int,
            make_fn: Callable, dispatcher, warm: bool = False):
        """Return the executable for one cell, building it on miss.

        ``make_fn`` produces the source callable (``gen.fn``, a merged
        fn, a scan executable); it only runs on a miss.  ``warm=True``
        counts the build as a warm-up compile instead of a serving-path
        miss, so steady-state hit-rate assertions are not diluted by
        deliberate priming.
        """
        key = (ctx.key, kind, int(aux), int(bucket))
        with self._mu:
            exe = self._exes.get(key)
            hit = exe is not None
            # warm-up traffic never counts toward serving hit/miss: the
            # steady-state hit-rate assertion must measure real batches
            if warm:
                self.warm_compiles += 0 if hit else 1
            elif hit:
                self.hits += 1
            else:
                self.misses += 1
        if exe is None:
            with maybe_span(self.recorder, "compile", cat="compile",
                            kind=kind, aux=int(aux), bucket=int(bucket),
                            version=ctx.key[0], warm=bool(warm)):
                exe = self._build(
                    make_fn(), bucket, ctx.bind, dispatcher,
                    instrumented=ctx.instrumented and kind == "read")
            with self._mu:
                self._exes[key] = exe
        if self.metrics is not None:
            self.metrics.note_cache(hit=hit, warm=warm)
        return exe

    def invalidate(self, keep_version=None) -> int:
        """Evict entries; with ``keep_version`` set, only entries whose
        context belongs to another generation go (hot-swap policy: the
        new generation's warm-up repopulates, old executables die).
        Accepts a single version or an iterable of versions to keep —
        a routed publish keeps the RoutedGeneration's version AND every
        per-shard generation version (lane contexts key on those)."""
        with self._mu:
            if keep_version is None:
                n = len(self._exes)
                self._exes.clear()
                return n
            keep = (set(keep_version)
                    if isinstance(keep_version, (set, frozenset, tuple,
                                                 list))
                    else {keep_version})
            stale = [k for k in self._exes if k[0][0] not in keep]
            for k in stale:
                del self._exes[k]
            return len(stale)

    def warmup(self, ctx: AsyncContext, buckets, dispatcher,
               scan_lengths=()) -> int:
        """Prime read (and optionally scan) executables for ``buckets``
        and run one dummy batch through each — after this, the first
        real batch of a warmed bucket is a cache hit with no trace, no
        compile, no first-touch initialization.  Runs off the dispatch
        thread (service `start()`, or the post-publish warm thread)."""
        import jax

        n = 0
        cells = [("read", 0, lambda: ctx.read_fn)]
        cells += [("scan", int(m), (lambda m=m: ctx.scan_fn(int(m))))
                  for m in scan_lengths]
        host_dummy = {int(b): np.full(int(b), ctx.sample_key, np.uint64)
                      for b in buckets}
        for bucket in buckets:
            for kind, aux, make_fn in cells:
                exe = self.get(ctx, kind, aux, int(bucket), make_fn,
                               dispatcher, warm=True)
                args = ((np.int32(bucket),)
                        if ctx.instrumented and kind == "read" else ())
                # fresh placement per cell: a donating executable
                # invalidates its input buffer, so cells must not share
                # one placed dummy
                dummy = dispatcher.place(host_dummy[int(bucket)])
                jax.block_until_ready(exe(dummy, *args, *ctx.bind))
                n += 1
        return n


class AsyncExecutor:
    """Slot-ring continuous batching over one service's dispatch path.

    Two daemon threads once `start()`ed:

      dispatch    waits on the micro-batcher, takes batches in admission
                  order, walks the service's work items (re-pinning per
                  run for the mutable service), resolves executables
                  through the cache, and LAUNCHES device work without
                  blocking; host work (inserts) is applied inline so a
                  later read run observes it — then rides the ring as an
                  already-final slot to keep completion in order.
      completion  pops slots in FIFO order, blocks on device results,
                  slices per request, resolves futures, records the
                  decomposed latencies.

    Stopped, it degrades to an inline engine: `drain()` launches and
    completes everything on the caller's thread, so synchronous tests
    and the `lookup()` convenience keep working without threads.
    """

    def __init__(self, service, slots: int = 4):
        if slots < 2:
            raise ValueError("async executor needs >= 2 slots "
                             "(double buffering)")
        self.svc = service
        self.slots = int(slots)
        self._ring: "queue.Queue" = queue.Queue(maxsize=self.slots)
        self._launch_mu = threading.Lock()   # serializes take+launch order
        self._inflight = 0
        self._inflight_cv = threading.Condition()
        self._stop = threading.Event()
        self._dispatch_t: Optional[threading.Thread] = None
        self._complete_t: Optional[threading.Thread] = None

    @property
    def running(self) -> bool:
        return self._dispatch_t is not None

    # -- lifecycle -------------------------------------------------------
    def start(self) -> threading.Thread:
        """Spawn the dispatch + completion pair; returns the dispatch
        thread (the service exposes it as its flusher `_thread`)."""
        if self._dispatch_t is not None:
            return self._dispatch_t
        self._stop.clear()
        self._complete_t = threading.Thread(
            target=self._completion_loop, name="lookup-completer",
            daemon=True)
        self._dispatch_t = threading.Thread(
            target=self._dispatch_loop, name="lookup-dispatcher",
            daemon=True)
        self._complete_t.start()
        self._dispatch_t.start()
        return self._dispatch_t

    def stop(self) -> None:
        """Join both threads, completing every admitted request: the
        dispatch loop force-drains admissions on its way out, the
        completion loop runs the ring dry before honoring the sentinel,
        and a final inline drain covers the join window."""
        if self._dispatch_t is None:
            return
        self._stop.set()
        self.svc.batcher.wake()
        self._dispatch_t.join()
        self._ring.put(_STOP)
        self._complete_t.join()
        self._dispatch_t = None
        self._complete_t = None
        self._stop.clear()
        self.drain()   # anything admitted during the join window

    # -- loops -----------------------------------------------------------
    def _dispatch_loop(self) -> None:
        svc = self.svc
        while not self._stop.is_set():
            if svc.batcher.wait_ready(timeout=5.0,
                                      until=self._stop.is_set):
                with self._launch_mu:
                    batch = svc.batcher.take(force=False)
                    if batch:
                        self._launch_batch(batch)
        # exit path: launch everything admitted before stop()
        self._drain_launches()

    def _completion_loop(self) -> None:
        while True:
            slot = self._ring.get()
            if slot is _STOP:
                return
            self._complete_slot(slot)

    # -- launching -------------------------------------------------------
    def _launch_batch(self, batch) -> None:
        """Walk the service's work items lazily and in order: an insert
        item is APPLIED when reached, so the next run's pinned context
        observes it (the admission-order invariant), while device items
        launch without blocking."""
        for item in self.svc._async_work_items(batch):
            self._launch_item(item)

    def _launch_item(self, item: WorkItem) -> None:
        svc = self.svc
        group = item.group
        t_oldest = group[0].t_submit
        if item.kind == "insert":
            t0 = time.perf_counter()
            try:
                host = item.apply_fn(group)
            except BaseException as e:   # noqa: BLE001 — fail the run only
                self._put(_Slot(group=group, kind=item.kind, error=e,
                                t_submit_oldest=t_oldest, t_launch=t0,
                                is_insert=True))
                return
            self._put(_Slot(group=group, kind=item.kind, host=host,
                            m=sum(r.keys.size for r in group),
                            t_submit_oldest=t_oldest, t_launch=t0,
                            is_insert=True))
            return

        keys = (group[0].keys if len(group) == 1
                else np.concatenate([r.keys for r in group]))
        t0 = time.perf_counter()
        routed = isinstance(item.ctx, RoutedContext)
        try:
            ctx = item.ctx
            if routed:
                routes = svc.dispatcher.routes_for(group, ctx.topology)
                out = svc.dispatcher.launch(
                    ctx, item.kind, item.aux, keys, routes=routes,
                    exec_cache=svc.exec_cache)   # launches, never blocks
                padded = out.padded
            else:
                make_fn = ((lambda: ctx.read_fn) if item.kind == "read"
                           else (lambda: ctx.scan_fn(item.aux)))
                q, padded = svc.dispatcher.pad_and_place(keys)
                exe = svc.exec_cache.get(ctx, item.kind, item.aux, padded,
                                         make_fn, svc.dispatcher)
                instr = ctx.instrumented and item.kind == "read"
                args = (np.int32(keys.size),) if instr else ()
                out = exe(q, *args, *ctx.bind)   # async dispatch: no block
        except BaseException as e:       # noqa: BLE001 — fail the group only
            self._put(_Slot(group=group, kind=item.kind, error=e,
                            t_submit_oldest=t_oldest, t_launch=t0))
            return
        rec = svc.recorder
        if rec is not None:
            # one span per launched slot, carrying the (contiguous,
            # admission-ordered) rid range it holds — the link between
            # request spans and the device work that served them
            rec.add("launch", t0, time.perf_counter(), cat="serve",
                    kind=item.kind, padded=int(padded),
                    n_keys=int(keys.size), n_requests=len(group),
                    rid_first=group[0].rid, rid_last=group[-1].rid)
        self._put(_Slot(group=group, kind=item.kind, out=out, m=keys.size,
                        padded=padded, t_submit_oldest=t_oldest,
                        t_launch=t0,
                        version=ctx.version if routed else ctx.key[0],
                        instrumented=False if routed else instr,
                        routed=routed))

    def _put(self, slot: _Slot) -> None:
        with self._inflight_cv:
            self._inflight += 1
            depth = self._inflight
        if self.svc.metrics is not None:
            self.svc.metrics.note_slot_depth(depth)
        if self.running:
            self._ring.put(slot)   # blocks when the ring is full: bounded
            return
        # inline mode has no completion thread to make room — keep the
        # bounded-ring invariant by completing the oldest slot here
        while True:
            try:
                self._ring.put_nowait(slot)
                return
            except queue.Full:
                self._complete_slot(self._ring.get())

    # -- completion ------------------------------------------------------
    def _complete_slot(self, slot: _Slot) -> None:
        svc = self.svc
        try:
            if slot.error is not None:
                for r in slot.group:
                    r.future._set_exception(slot.error)
            elif slot.is_insert:
                svc._complete_insert_slot(slot)
            else:
                t_wait = time.perf_counter()
                try:
                    if slot.routed:
                        out, route_stats, _ = slot.out.finalize()
                    else:
                        out = svc.dispatcher.finalize(
                            slot.out, slot.m,
                            instrumented=slot.instrumented)
                except BaseException as e:   # noqa: BLE001 — device failure
                    for r in slot.group:     # fails the slot, not the loop
                        r.future._set_exception(e)
                    return
                t_end = time.perf_counter()
                if slot.routed:
                    # per-shard stats land in each SHARD generation's
                    # health record; route skew feeds the metrics
                    for ver, stats in route_stats:
                        svc._note_health(ver, stats, t_end)
                    if svc.metrics is not None:
                        svc.metrics.observe_route(slot.out.counts,
                                                  slot.out.padded)
                elif slot.instrumented:
                    # instrumented read: route the device-reduced stats
                    # to the record of the generation the slot ran on
                    out, stats = out
                    svc._note_health(slot.version, stats, t_end)
                off = 0
                for r in slot.group:
                    end = off + r.keys.size
                    r.future._set_result(
                        tuple(o[off:end] for o in out)
                        if isinstance(out, tuple) else out[off:end])
                    off = end
                rec = svc.recorder
                if rec is not None:
                    rec.add("finalize", t_wait, t_end, cat="serve",
                            kind=slot.kind, n_keys=slot.m,
                            rid_first=slot.group[0].rid,
                            rid_last=slot.group[-1].rid)
                    for r in slot.group:
                        rec.request(r.rid, kind=r.kind,
                                    n_keys=r.keys.size,
                                    t_submit=r.t_submit,
                                    t_launch=slot.t_launch, t_end=t_end)
                svc.metrics.observe_batch(
                    n_keys=slot.m, padded=slot.padded,
                    n_requests=len(slot.group),
                    t_oldest_submit=slot.t_submit_oldest,
                    t_start=slot.t_launch, t_end=t_end,
                    per_request=[(r.t_submit, r.keys.size, r.priority)
                                 for r in slot.group])
        finally:
            with self._inflight_cv:
                self._inflight -= 1
                self._inflight_cv.notify_all()

    # -- synchronous faces ------------------------------------------------
    def _drain_launches(self) -> int:
        """Force-take and launch until the admission queue is empty."""
        n = 0
        with self._launch_mu:
            while True:
                batch = self.svc.batcher.take(force=True)
                if not batch:
                    return n
                self._launch_batch(batch)
                n += 1

    def _complete_ring_inline(self) -> None:
        """Run the completion side on the caller's thread (no-thread
        mode: synchronous tests, `lookup()` without `start()`)."""
        while True:
            try:
                slot = self._ring.get_nowait()
            except queue.Empty:
                return
            self._complete_slot(slot)

    def _wait_idle(self, timeout: Optional[float] = None) -> bool:
        with self._inflight_cv:
            return self._inflight_cv.wait_for(
                lambda: self._inflight == 0, timeout=timeout)

    def flush(self) -> bool:
        """Launch one due batch if any; wait until in-flight work is
        complete (same observable effect as the sync `flush`)."""
        launched = False
        with self._launch_mu:
            batch = self.svc.batcher.take(force=False)
            if batch:
                self._launch_batch(batch)
                launched = True
        self._settle()
        return launched

    def drain(self) -> int:
        """Force-dispatch until the queue is empty AND every launched
        slot has completed; returns the batch count.  Safe to call from
        any thread, with or without the loops running."""
        n = self._drain_launches()
        self._settle()
        return n

    def _settle(self) -> None:
        if self.running:
            self._wait_idle()
        else:
            self._complete_ring_inline()
