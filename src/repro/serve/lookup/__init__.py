"""`repro.serve.lookup` — sharded, batched, async-admission lookup service.

The layer between the index structures (`repro.core`) and the workload
drivers (DESIGN.md §9).  Requests carrying small key arrays are admitted
asynchronously, coalesced by a deadline/size micro-batcher, dispatched as
one device-sharded plan-compiled lookup (`repro.core.plan`: index bounds
+ last-mile stage, jnp or Pallas backend) over the `data` mesh axis, and
completed through per-request futures.  Index generations hot-swap
atomically: a rebuild on a fresh key set becomes visible between
batches, never inside one.
"""
from repro.serve.lookup.admission import (ClientBacklogFull, LookupFuture,
                                          MicroBatcher)
from repro.serve.lookup.dispatch import (RoutedContext, RoutedDispatcher,
                                         ShardedDispatcher, make_plan)
from repro.serve.lookup.executor import (AsyncContext, AsyncExecutor,
                                         ExecutableCache)
from repro.serve.lookup.metrics import ServiceMetrics
from repro.serve.lookup.mutable_service import (MutableLookupService,
                                                MutableLookupServiceConfig)
from repro.serve.lookup.registry import (Generation, IndexRegistry,
                                         RoutedGeneration)
from repro.serve.lookup.service import (DEFAULT_HYPER, LookupService,
                                        LookupServiceConfig, default_spec)
from repro.serve.lookup.topology import ShardTopology

__all__ = [
    "DEFAULT_HYPER",
    "default_spec",
    "AsyncContext",
    "AsyncExecutor",
    "ExecutableCache",
    "ClientBacklogFull",
    "LookupFuture",
    "MicroBatcher",
    "ShardedDispatcher",
    "make_plan",
    "ServiceMetrics",
    "Generation",
    "IndexRegistry",
    "LookupService",
    "LookupServiceConfig",
    "MutableLookupService",
    "MutableLookupServiceConfig",
    "RoutedContext",
    "RoutedDispatcher",
    "RoutedGeneration",
    "ShardTopology",
]
