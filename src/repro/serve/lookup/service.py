"""`LookupService`: admission -> micro-batch -> sharded dispatch (§9).

The serving analogue of `ServeEngine`, for index lookups instead of
tokens: clients `submit()` small uint64 key arrays and get futures;
a single flusher (either the background thread started by `start()`,
or explicit `flush()`/`drain()` calls in synchronous tests/benchmarks)
drains the micro-batcher in admission order and runs one sharded fused
lookup per batch.  One flusher + in-order draining gives FIFO completion
per client for free.

Results are LB positions (`D[pos]` is the smallest key >= query — the
paper's lower-bound semantics, DESIGN.md §2), bit-identical to a direct
single-device `repro.core` lookup on the same queries.

Hot-swap: `swap_keys(new_keys)` rebuilds off-thread-safe (outside every
lock) and publishes atomically; batches in flight complete against the
generation they were dispatched with — nothing drains, nothing blocks.

Executors (DESIGN.md §13): ``executor="sync"`` is the loop above — the
bit-exact reference every other path is pinned against.
``executor="async"`` swaps in the continuous-batching engine
(`serve.lookup.executor`): a pre-compiled executable cache keyed by
(generation, kind, batch bucket), a dispatch thread that launches device
work without blocking on it, and a bounded ring of in-flight slots
completed in FIFO order — admission and completion overlap the in-flight
device step, and steady-state p99 is bounded by kernel time instead of
Python dispatch + first-touch compiles.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core import spec as spec_mod
from repro.obs.alerts import AlertEngine, AlertRule, default_rules
from repro.obs.health import HealthMonitor
from repro.obs.trace import SpanRecorder, maybe_span
from repro.serve.common import MonotonicCounter
from repro.serve.lookup.admission import LookupFuture, MicroBatcher
from repro.serve.lookup.dispatch import (PAD_QUANTUM, RoutedContext,
                                         RoutedDispatcher, ShardedDispatcher)
from repro.serve.lookup.executor import (AsyncContext, AsyncExecutor,
                                         ExecutableCache, WorkItem)
from repro.serve.lookup.metrics import ServiceMetrics
from repro.serve.lookup.registry import (DEFAULT_NAME, Generation,
                                         IndexRegistry, RoutedGeneration)
from repro.serve.lookup.topology import ShardTopology


#: One source of truth for the serving-default hyperparameters — the
#: numbers the README/DESIGN-cited throughput sweep publishes; the serve
#: driver demos the same configuration.
DEFAULT_HYPER = {
    "rmi": dict(branching=4096),
    "pgm": dict(eps=64),
    "radix_spline": dict(eps=32, radix_bits=16),
}


def default_spec(index: str, backend: str = "jnp") -> spec_mod.IndexSpec:
    """The serving-default `IndexSpec` for one index family."""
    return spec_mod.IndexSpec(index, dict(DEFAULT_HYPER.get(index, {})),
                              backend=backend).validated()


@dataclasses.dataclass(frozen=True)
class LookupServiceConfig:
    index: str = "rmi"                 # repro.core.base.REGISTRY name
    hyper: Dict[str, Any] = dataclasses.field(default_factory=dict)
    last_mile: Optional[str] = None    # None -> the build's own choice
    backend: str = "jnp"               # LookupPlan backend ("jnp" | "pallas")
    max_batch: int = 4096              # keys per dispatch (flush trigger)
    deadline_ms: float = 2.0           # oldest-request flush deadline
    #: Per-latency-class flush budgets in ms (DESIGN.md §17 satellite),
    #: e.g. ``{"interactive": 1.0, "batch": 20.0}``: the deadline
    #: trigger fires at the earliest pending (submit + class budget);
    #: unknown classes fall back to ``deadline_ms``.  None = single
    #: deadline for everything (classic behavior).
    class_deadline_ms: Optional[Dict[str, float]] = None
    pad_quantum: int = PAD_QUANTUM
    max_client_keys: Optional[int] = None   # per-client pending-key cap
    client_rate: Optional[tuple] = None     # per-client (rate keys/s, burst)
    max_scan_length: int = 4096             # per-request scan-window cap
    #: Declarative alternative to index/hyper/backend/last_mile: when
    #: set, the spec wins WHOLESALE (the four field-wise knobs are
    #: ignored) — one serializable value addresses the whole build.
    spec: Optional[spec_mod.IndexSpec] = None
    #: Dispatch engine: "sync" (serial take -> block -> complete, the
    #: bit-exact reference) or "async" (continuous batching — executable
    #: cache + double buffering + slot ring, DESIGN.md §13).
    executor: str = "sync"
    slots: int = 4                          # async in-flight slot ring depth
    #: Batch buckets the async warm-up pre-compiles; () = every pow2
    #: bucket from pad_quantum up to padded(max_batch) — the shapes
    #: steady traffic actually dispatches.
    warm_buckets: Tuple[int, ...] = ()
    #: Scan lengths warmed alongside (each is a compile-shape axis).
    warm_scan_lengths: Tuple[int, ...] = ()
    #: Observability (DESIGN.md §14).  ``trace`` turns on the structured
    #: span recorder (bounded ring of ``trace_capacity`` spans: per-
    #: request ids from admission through launch/completion, plus
    #: compile/hot-swap/warm-up/compaction lifecycle spans) exported as
    #: Chrome-trace JSON via ``service.recorder.to_chrome()``.  Off by
    #: default: the disabled path costs one ``is None`` check per site.
    trace: bool = False
    trace_capacity: int = 65536
    #: Rolling-window metrics resolution: the ring holds ``window_slots``
    #: sub-histograms of ``window_slot_s`` seconds each, merged at read
    #: by ``metrics.windowed(window_s=...)``.
    window_slot_s: float = 0.5
    window_slots: int = 240
    #: Optional p99 SLO target: request latencies above it burn error
    #: budget, reported per window (`slo_budget_burn`).
    slo_p99_ms: Optional[float] = None
    #: Index-health telemetry (DESIGN.md §15).  On by default: reads
    #: dispatch the plan's instrumented executable — bit-identical
    #: positions plus O(buckets) device-reduced stats per batch — and a
    #: `HealthMonitor` keeps per-generation displacement/traffic/drift
    #: records behind `health_snapshot()` / `/health.json`.
    health: bool = True
    #: Alert rules evaluated over `health_snapshot()` keys; None -> the
    #: shipped `repro.obs.alerts.default_rules()`, () -> no rules.
    alert_rules: Optional[Tuple[AlertRule, ...]] = None
    #: Range-routed serving topology (DESIGN.md §16).  ``shards > 1``
    #: partitions the key space into that many equal-count ranges, each
    #: with its own (smaller) index generation, and replaces broadcast
    #: dispatch with scatter/gather routing — per-device work drops from
    #: O(batch) to O(batch/shards).  ``topology`` pins an explicit
    #: `ShardTopology` instead (wins over ``shards``/``replicas``, and
    #: forces the routed path even with one shard).
    shards: int = 1
    replicas: int = 1                       # read fan-out per shard
    topology: Optional[ShardTopology] = None
    #: Per-shard spec search: each shard's `IndexSpec` tuned against
    #: ONLY its slice (per-shard byte budget = Tuner.max_bytes / shards).
    #: None -> every shard reuses the service's resolved spec.
    shard_tuner: Optional[spec_mod.Tuner] = None
    #: Donate the staged query buffer to XLA (the executable reuses its
    #: memory).  None -> auto: on for non-CPU backends, off on CPU where
    #: donation is a no-op with a warning.
    donate_queries: Optional[bool] = None
    #: Self-driving tuning (DESIGN.md §17): an
    #: `repro.autotune.AutotuneConfig` attaches a `ShadowRetuner` to
    #: this service — alert-triggered workload-aware retunes, oracle-
    #: verified hot-swaps, `/autotune.json` surface.  With
    #: ``autotune.daemon`` the retuner thread starts/stops with the
    #: service; otherwise drive it via ``service.autotune.poll_once()``.
    autotune: Optional[Any] = None

    def resolved_spec(self) -> spec_mod.IndexSpec:
        """The validated `IndexSpec` every build of this service uses."""
        if self.spec is not None:
            return self.spec.validated()
        return spec_mod.coerce(self.index, self.hyper,
                               backend=self.backend,
                               last_mile=self.last_mile)


class LookupService:
    def __init__(self, keys: np.ndarray,
                 config: Optional[LookupServiceConfig] = None,
                 mesh=None, counter: Optional[MonotonicCounter] = None):
        self.cfg = config if config is not None else LookupServiceConfig()
        if self.cfg.executor not in ("sync", "async"):
            raise ValueError(
                f"executor must be 'sync' or 'async', "
                f"got {self.cfg.executor!r}")
        #: §14 span recorder, or None when tracing is off — every
        #: instrumentation site on the serve path shares this one object
        self.recorder = (SpanRecorder(self.cfg.trace_capacity)
                         if self.cfg.trace else None)
        self.registry = IndexRegistry()
        self.registry.recorder = self.recorder
        #: §15 per-generation health monitor, or None when disabled —
        #: attached to the registry BEFORE the first publish so the
        #: initial generation gets a record too
        shards_hint = (self.cfg.topology.n_shards
                       if self.cfg.topology is not None
                       else max(1, self.cfg.shards))
        self.health = (HealthMonitor(slot_s=self.cfg.window_slot_s,
                                     n_slots=self.cfg.window_slots,
                                     keep=max(8, 2 * (shards_hint + 1)))
                       if self.cfg.health else None)
        self.registry.health = self.health
        #: §15 alert engine — always present (rules may be empty); it
        #: only evaluates when asked (`check_alerts`/endpoints/doctor)
        self.alerts = AlertEngine(
            rules=(default_rules() if self.cfg.alert_rules is None
                   else self.cfg.alert_rules))
        self.dispatcher = ShardedDispatcher(
            mesh=mesh, pad_quantum=self.cfg.pad_quantum,
            recorder=self.recorder)
        self.metrics = ServiceMetrics(
            slo_p99_ms=self.cfg.slo_p99_ms,
            window_slot_s=self.cfg.window_slot_s,
            window_slots=self.cfg.window_slots)
        self.batcher = MicroBatcher(
            self.cfg.max_batch, self.cfg.deadline_ms / 1e3,
            counter=counter if counter is not None else MonotonicCounter(),
            max_client_keys=self.cfg.max_client_keys,
            client_rate=self.cfg.client_rate,
            recorder=self.recorder,
            class_deadlines=(
                {k: v / 1e3
                 for k, v in self.cfg.class_deadline_ms.items()}
                if self.cfg.class_deadline_ms is not None else None))
        self._dispatch_lock = threading.Lock()   # one batch at a time
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._warm_thread: Optional[threading.Thread] = None
        self.exec_cache = ExecutableCache(metrics=self.metrics,
                                          recorder=self.recorder)
        self._async = (AsyncExecutor(self, slots=self.cfg.slots)
                       if self.cfg.executor == "async" else None)
        # routed state: the current RoutedGeneration (None on the
        # broadcast path) and the pinned-context cache keyed on
        # (generation version, lane epoch, instrumented)
        self._routed: Optional[RoutedGeneration] = None
        self._rctx_cache: Dict[Tuple, RoutedContext] = {}
        import jax
        self._donate = (self.cfg.donate_queries
                        if self.cfg.donate_queries is not None
                        else jax.default_backend() != "cpu")
        # every publish lands here: routed topology/router updates for
        # both executors, plus (async only) invalidation-on-swap — so
        # compaction rebuilds (which publish without going through
        # swap_keys) evict stale executables too
        self.registry.subscribe(self._on_publish)
        self.swap_keys(keys)
        #: §17 shadow retuner, or None — constructed AFTER the first
        #: publish so its trigger polls always see a live generation
        if self.cfg.autotune is not None:
            from repro.autotune import ShadowRetuner
            self.autotune = ShadowRetuner(self, self.cfg.autotune)
        else:
            self.autotune = None

    # -- index lifecycle -------------------------------------------------
    def _resolve_topology(self, keys) -> Optional[ShardTopology]:
        """The serving topology for one key set, or None for broadcast.
        An explicit ``cfg.topology`` always routes (even single-shard —
        that is the degeneration-parity path); ``shards > 1`` builds an
        equal-count partition fresh per key set."""
        if self.cfg.topology is not None:
            return self.cfg.topology
        if self.cfg.shards > 1:
            return ShardTopology.from_keys(keys, self.cfg.shards,
                                           self.cfg.replicas)
        return None

    def swap_keys(self, keys: np.ndarray) -> Generation:
        """Rebuild on a fresh key set and hot-swap it in (no draining).
        Builds go through the config's resolved `IndexSpec`, so the
        published generation is spec-addressable (`Generation.spec`).
        With a routed topology this publishes one generation per range
        plus the topology, as a single atomic `RoutedGeneration`."""
        keys = np.asarray(keys, dtype=np.uint64)
        topo = self._resolve_topology(keys)
        if topo is None:
            return self.registry.build_and_publish(
                self.cfg.resolved_spec(), keys)
        return self.registry.build_and_publish_routed(
            self.cfg.resolved_spec(), keys, topo,
            tuner=self.cfg.shard_tuner)

    @property
    def generation(self) -> Generation:
        return self.registry.current()

    # -- client surface --------------------------------------------------
    def submit(self, keys, client=None,
               priority: str = "interactive") -> LookupFuture:
        """Admit one request; never blocks.  Completion needs a flusher:
        either the background thread (`start()`/`with svc:`) or explicit
        `flush()`/`drain()` calls — a future submitted with neither
        stays pending until one of them runs.  ``client`` is an optional
        fairness id: with `max_client_keys` configured, an over-backlog
        client's submit raises `ClientBacklogFull` instead of queueing.
        ``priority`` is the latency class: it selects the flush budget
        (``cfg.class_deadline_ms``) and the per-class latency row in
        `ServiceMetrics`."""
        _, fut = self.batcher.submit(keys, client=client,
                                     priority=priority)
        return fut

    def scan(self, keys, length: int, client=None) -> LookupFuture:
        """Admit one range-scan request (op kind "scan"): the future
        resolves to ``(positions, window)`` where ``window[i]`` holds the
        ``length`` records from ``LB(keys[i])`` (UINT64_MAX sentinel past
        the end) — the plan's `compile_scan` materialization, so YCSB-E
        traces execute end-to-end instead of position-only."""
        # bound the client-supplied length: the window is a [B, length]
        # gather AND a compile-shape axis (each distinct length caches a
        # compiled executable), so it must not be client-unbounded.  A
        # routed topology tightens the bound to the smallest shard — a
        # shard's spill window only repairs up to min_shard_len records.
        gen = self.generation
        max_len = self.cfg.max_scan_length
        if isinstance(gen, RoutedGeneration):
            max_len = min(max_len, gen.max_scan_len)
        if not 1 <= length <= max_len:
            raise ValueError(f"scan length must be in [1, {max_len}]")
        # reject point-only indexes at admission (cheapest point); the
        # per-group guard in _complete_run still covers the race where a
        # hot-swap to a point-only index lands after admission
        point_only = (gen.point_only if isinstance(gen, RoutedGeneration)
                      else gen.plan.point_only)
        if point_only:
            raise ValueError(
                f"index {gen.plan.name!r} is point-only: no scans")
        _, fut = self.batcher.submit(keys, kind="scan", aux=int(length),
                                     client=client)
        return fut

    def lookup(self, keys, timeout: Optional[float] = 30.0) -> np.ndarray:
        """Synchronous convenience: submit + ensure progress + wait."""
        fut = self.submit(keys)
        if self._thread is None:
            self.drain()
        return fut.result(timeout)

    # -- flushing --------------------------------------------------------
    def _dispatch_once(self, force: bool = False) -> bool:
        """Take + process one batch; returns whether one was taken.

        Serialized by `_dispatch_lock`: take order == dispatch order ==
        completion order, which is the FIFO guarantee.
        """
        with self._dispatch_lock:
            batch = self.batcher.take(force=force)
            if not batch:
                return False
            self._process_batch(batch)
            return True

    @staticmethod
    def _runs(batch, key):
        """Yield maximal consecutive runs of `batch` sharing `key(req)`,
        in order — the one splitter every dispatch path shares."""
        i = 0
        while i < len(batch):
            j = i
            while j < len(batch) and key(batch[j]) == key(batch[i]):
                j += 1
            yield batch[i:j]
            i = j

    def _process_batch(self, batch) -> None:
        """Split the taken batch into consecutive same-kind runs and
        dispatch each — admission order is preserved within and across
        runs, so FIFO completion per client still holds.  The lookup
        context (`_pin_context`) is read ONCE for the whole batch: a
        hot-swap lands between batches, never inside one.  (The mutable
        subclass re-pins per run instead — an insert run changes the
        delta and a later read run in the same batch must observe it.)"""
        ctx = self._pin_context()
        for run in self._runs(batch, key=lambda r: r.kind):
            self._dispatch_run(run[0].kind, run, ctx)

    def _dispatch_run(self, kind: str, run, ctx=None) -> None:
        """Route one same-kind run; subclasses add kinds (inserts)."""
        if ctx is None:
            ctx = self._pin_context()
        if isinstance(ctx, RoutedContext):
            if kind == "scan":
                for group in self._runs(run, key=lambda r: r.aux):
                    self._complete_routed("scan", list(group),
                                          int(group[0].aux), ctx)
            else:
                self._complete_routed("read", list(run), 0, ctx)
            return
        lookup_fn, scan_for, version = ctx
        if kind == "scan":
            self._dispatch_scans(run, scan_for)
        else:
            self._dispatch_reads(run, lookup_fn, version)

    def _pin_context(self):
        """``(lookup_fn, m -> scan executable, version)`` bound to ONE
        immutable generation — the snapshot a batch completes against.
        With health on, ``lookup_fn`` is the plan's INSTRUMENTED
        executable (same positions bit-for-bit, plus device-reduced
        stats); ``version`` routes those stats to the right record.
        Routed generations pin a `RoutedContext` instead (the whole
        topology + per-lane executables snapshot)."""
        gen = self.registry.current()
        if isinstance(gen, RoutedGeneration):
            return self._routed_context(gen)
        if self.health is not None:
            return gen.instrumented_fn(), gen.scan_fn, gen.version
        return gen.fn, gen.scan_fn, gen.version

    def _routed_context(self, gen: RoutedGeneration) -> RoutedContext:
        """One executable-cache-addressable context per (generation,
        lane layout): every (shard, replica) lane gets its own
        `AsyncContext` keyed ``(shard version, replica)`` so AOT
        executables stay committed to their lane's device."""
        instrumented = self.health is not None
        key = (gen.version, self.dispatcher.lanes_epoch, instrumented)
        rctx = self._rctx_cache.get(key)
        if rctx is not None:
            return rctx
        lane_ctxs = []
        for s, sgen in enumerate(gen.shards):
            reps = []
            read_fn = (sgen.instrumented_fn(donate=self._donate)
                       if instrumented else sgen.fn_for(self._donate))
            scan_fn = (lambda m, s=s, g=gen: g.shard_scan_fn(s, int(m)))
            for r in range(len(self.dispatcher.lanes[s])):
                reps.append(AsyncContext(
                    key=(sgen.version, r),
                    read_fn=read_fn,
                    scan_fn=scan_fn,
                    bind=(),
                    sample_key=int(np.asarray(sgen.data[:1])[0]),
                    instrumented=instrumented))
            lane_ctxs.append(tuple(reps))
        rctx = RoutedContext(
            topology=gen.topology,
            lane_ctxs=tuple(lane_ctxs),
            offsets=tuple(gen.topology.offsets),
            versions=gen.shard_versions,
            version=gen.version,
            instrumented=instrumented)
        self._rctx_cache[key] = rctx
        return rctx

    def _complete_routed(self, kind: str, group, aux: int,
                         rctx: RoutedContext) -> None:
        """Synchronous routed dispatch of one same-(kind, aux) group:
        scatter over shard lanes, finalize (gather in admission order),
        complete futures — the routed twin of `_complete_run`."""
        keys = (group[0].keys if len(group) == 1
                else np.concatenate([r.keys for r in group]))
        t0 = time.perf_counter()
        try:
            routes = self.dispatcher.routes_for(group, rctx.topology)
            handle = self.dispatcher.launch(rctx, kind, aux, keys,
                                            routes=routes)
            out, stats, padded = handle.finalize()
        except BaseException as e:  # noqa: BLE001 — fail the group only
            for r in group:
                r.future._set_exception(e)
            return
        t1 = time.perf_counter()
        for ver, st in stats:
            self._note_health(ver, st, t1)
        self.metrics.observe_route(handle.counts, padded)
        self._finish_group(group, out, t0, t1, keys.size, padded)

    def _complete_run(self, group, make_fn, version: int = -1,
                      instrumented: bool = False) -> None:
        """Dispatch one request group through ``make_fn()`` and complete
        its futures in order; tuple results (scans) are sliced per array.
        Failures fail the group's futures, never the flusher — including
        executable CONSTRUCTION failures (``make_fn`` runs inside the
        guard: scan compilation rejects point-only plans).  Instrumented
        reads strip the stats dict off the result and fold it into the
        health record of ``version`` — futures never see it."""
        keys = (group[0].keys if len(group) == 1
                else np.concatenate([r.keys for r in group]))
        t0 = time.perf_counter()
        try:
            out = self.dispatcher(make_fn(), keys,
                                  n_valid_arg=instrumented)
        except BaseException as e:  # noqa: BLE001 — fail the group, not the flusher
            for r in group:
                r.future._set_exception(e)
            return
        t1 = time.perf_counter()
        if instrumented:
            out, stats = out
            self._note_health(version, stats, t1)
        self._finish_group(group, out, t0, t1, keys.size,
                           self.dispatcher.padded_size(keys.size))

    def _finish_group(self, group, out, t0: float, t1: float,
                      n_keys: int, padded: int) -> None:
        """Shared completion tail of both sync paths: slice the batch
        result per request in admission order, resolve futures, record
        request spans, and fold the batch into the metrics."""
        off = 0
        for r in group:
            end = off + r.keys.size
            r.future._set_result(tuple(o[off:end] for o in out)
                                 if isinstance(out, tuple) else out[off:end])
            off = end
        if self.recorder is not None:
            for r in group:
                self.recorder.request(r.rid, kind=r.kind,
                                      n_keys=r.keys.size,
                                      t_submit=r.t_submit,
                                      t_launch=t0, t_end=t1)
        self.metrics.observe_batch(
            n_keys=n_keys,
            padded=padded,
            n_requests=len(group),
            t_oldest_submit=group[0].t_submit,
            t_start=t0, t_end=t1,
            per_request=[(r.t_submit, r.keys.size, r.priority)
                         for r in group])

    def _dispatch_reads(self, batch, lookup_fn, version: int = -1) -> None:
        self._complete_run(batch, lambda: lookup_fn, version=version,
                           instrumented=self.health is not None)

    def _dispatch_scans(self, batch, scan_for) -> None:
        """Dispatch a run of scan requests, grouped by scan length (the
        static window width is a compile-shape axis).  Futures resolve to
        ``(positions, window)`` per request.  `_dispatch_run` is the one
        resolver of the pinned context these run against."""
        for group in self._runs(batch, key=lambda r: r.aux):
            m = int(group[0].aux)
            self._complete_run(group, lambda m=m: scan_for(m))

    # -- async executor plumbing (DESIGN.md §13) --------------------------
    def _async_context(self) -> AsyncContext:
        """Pin one generation as an executable-cache-addressable context:
        the async analogue of `_pin_context` (same snapshot semantics —
        a hot-swap lands between batches, never inside one).  Routed
        generations return the (cached) `RoutedContext` — the executor
        branches on the type."""
        gen = self.registry.current()
        if isinstance(gen, RoutedGeneration):
            return self._routed_context(gen)
        instrumented = self.health is not None
        return AsyncContext(
            key=(gen.version,),
            read_fn=gen.instrumented_fn() if instrumented else gen.fn,
            scan_fn=gen.scan_fn,
            bind=(),
            sample_key=int(np.asarray(gen.data[:1])[0]),
            instrumented=instrumented)

    def _async_work_items(self, batch):
        """Lazily yield `WorkItem`s for one taken batch, in admission
        order — the async twin of `_process_batch`, with the context
        pinned ONCE for the whole batch (the mutable subclass re-pins
        per run and interleaves insert application)."""
        ctx = self._async_context()
        for run in self._runs(batch, key=lambda r: r.kind):
            yield from self._async_items_for_run(run[0].kind, run, ctx)

    def _async_items_for_run(self, kind, run, ctx):
        if kind == "scan":
            # scan length is a compile-shape axis: split like the sync path
            for group in self._runs(run, key=lambda r: r.aux):
                yield WorkItem(kind="scan", group=list(group), ctx=ctx,
                               aux=int(group[0].aux))
        else:
            yield WorkItem(kind="read", group=list(run), ctx=ctx)

    def _complete_insert_slot(self, slot) -> None:
        """Resolve a host-ready insert slot (mutable service only)."""
        raise NotImplementedError(
            "insert completion on a read-only service")

    def _resolved_warm_buckets(self, dispatcher=None):
        d = self.dispatcher if dispatcher is None else dispatcher
        if self.cfg.warm_buckets:
            return tuple(sorted({d.padded_size(int(b))
                                 for b in self.cfg.warm_buckets}))
        # every pow2 bucket steady traffic can dispatch at: quantum ..
        # padded(max_batch) — log2-many executables, compiled once
        buckets, b = [], d.padded_size(1)
        top = d.padded_size(self.cfg.max_batch)
        while b < top:
            buckets.append(b)
            b = d.padded_size(b + 1)
        buckets.append(top)
        return tuple(buckets)

    def warm_now(self) -> int:
        """Synchronously prime the executable cache for the CURRENT
        generation over the configured warm buckets; returns the number
        of warmed cells.  `start()` runs this before serving; hot-swaps
        re-run it off-thread (`_on_publish`)."""
        if self._async is None:
            return 0
        ctx = self._async_context()
        if isinstance(ctx, RoutedContext):
            return self._warm_routed(ctx)
        buckets = self._resolved_warm_buckets()
        with maybe_span(self.recorder, "warmup", cat="lifecycle",
                        version=ctx.key[0], n_buckets=len(buckets)):
            return self.exec_cache.warmup(
                ctx, buckets, self.dispatcher,
                scan_lengths=self.cfg.warm_scan_lengths)

    def warm_wait(self, timeout: Optional[float] = None) -> None:
        """Block until the background re-warm kicked off by the last
        hot-swap publish finishes (no-op when none is in flight) — so a
        caller that just swapped can measure steady-state serving
        without racing the warm thread's compiles for CPU."""
        w = self._warm_thread
        if w is not None and w.is_alive():
            w.join(timeout)

    def _warm_routed(self, rctx: RoutedContext) -> int:
        """Prime every (shard, replica) lane's executables on that
        lane's own dispatcher — AOT executables are device-committed,
        so each lane needs its own warm pass."""
        n = 0
        with maybe_span(self.recorder, "warmup", cat="lifecycle",
                        version=rctx.version,
                        n_shards=self.dispatcher.n_shards):
            for s, grp in enumerate(self.dispatcher.lanes):
                for r, lane in enumerate(grp):
                    n += self.exec_cache.warmup(
                        rctx.lane_ctxs[s][r],
                        self._resolved_warm_buckets(lane), lane,
                        scan_lengths=self.cfg.warm_scan_lengths)
        return n

    def _on_publish(self, name: str, gen) -> None:
        """Registry publish hook: track the routed topology (both
        executors route at admission through it), then — async only —
        evict stale generations' executables and re-warm the new one
        WITHOUT blocking the publisher (a compaction thread may be
        mid-swap holding its own locks — warming there would deadlock)."""
        if name != DEFAULT_NAME:
            return
        if isinstance(gen, RoutedGeneration):
            if not isinstance(self.dispatcher, RoutedDispatcher):
                self.dispatcher = RoutedDispatcher(
                    gen.topology, pad_quantum=self.cfg.pad_quantum,
                    recorder=self.recorder)
            else:
                self.dispatcher.set_replicas(gen.topology)
            self._routed = gen
            self._rctx_cache.clear()
            # admission-time routing: each submit tags its request with
            # (topology, shard ids); a later hot-swap invalidates the
            # tag by object identity and dispatch re-routes
            self.batcher.router = (
                lambda keys, t=gen.topology: (t, t.route(keys)))
            keep = (gen.version,) + gen.shard_versions
        else:
            self._routed = None
            self.batcher.router = None
            keep = gen.version
        if self._async is None:
            return
        self.exec_cache.invalidate(keep_version=keep)
        if self._thread is None:
            # not serving: start() warms synchronously before the first
            # dispatch, and a never-started service must not leave a
            # compile thread behind at interpreter teardown
            return
        t = threading.Thread(target=self._warm_retry,
                             name="lookup-warmer", daemon=True)
        self._warm_thread = t
        t.start()

    def rebalance_replicas(self, total_replicas: Optional[int] = None,
                           window_s: float = 10.0) -> Tuple[int, ...]:
        """Re-apportion replica seats to the shards that actually take
        the traffic (the PR 8 per-shard traffic windows): the hottest
        range gets the replicas.  Only the read fan-out changes — split
        points and offsets stay, so admission-time routes remain valid.
        Returns the new per-shard replica counts."""
        gen = self.registry.current()
        if not isinstance(gen, RoutedGeneration):
            raise ValueError("rebalance_replicas needs a routed topology")
        masses = []
        for sgen in gen.shards:
            mass = 0.0
            if self.health is not None:
                rec = self.health.get(sgen.version)
                if rec is not None:
                    mass = float(np.sum(rec.traffic_window(window_s)))
            masses.append(mass)
        topo = gen.topology.rebalanced_from_masses(
            masses, total_replicas=total_replicas)
        if self.dispatcher.set_replicas(topo):
            self._rctx_cache.clear()
        return topo.replicas

    def _warm_retry(self) -> None:
        """Warm the current context, tolerating construction windows
        (the mutable service publishes its first generation before its
        view pointer exists — retry briefly, then give up quietly: a
        missed warm only costs one first-touch compile per bucket)."""
        deadline = time.perf_counter() + 5.0
        while True:
            try:
                self.warm_now()
                return
            except Exception:   # noqa: BLE001 — warm-up is best-effort
                if time.perf_counter() >= deadline:
                    return
                time.sleep(0.005)

    # -- index-health telemetry (DESIGN.md §15) ---------------------------
    def _note_health(self, version: int, stats, t_end: float) -> None:
        """Fold one completed batch's device-reduced stats into the
        health record of the generation it ran against (both executors'
        completion paths land here)."""
        if self.health is not None:
            self.health.accumulate(version, stats, t=t_end)

    def health_snapshot(self, window_s: float = 10.0) -> Dict[str, float]:
        """ONE flat key namespace over service + window + model health —
        what alert rules evaluate and `/health.json` exports: the
        lifetime `ServiceMetrics` snapshot, the trailing-window metrics
        under a ``window_`` prefix (``window_covered_s`` reports actual
        coverage), and the current generation's health keys."""
        snap = self.metrics.snapshot()
        win = self.metrics.windowed(window_s)
        snap["window_covered_s"] = win.pop("window_s")
        snap.update({f"window_{k}": v for k, v in win.items()})
        if self.health is not None:
            snap.update(self.health.snapshot(window_s))
        snap["trace_dropped"] = float(self.recorder.n_dropped
                                      if self.recorder is not None else 0)
        snap["inflight_saturation"] = (
            snap.get("mean_inflight_slots", 0.0) / self.cfg.slots
            if self._async is not None and self.cfg.slots else 0.0)
        snap["serving"] = 1.0 if self._thread is not None else 0.0
        if self.autotune is not None:
            st = self.autotune.status()
            snap["autotune_alive"] = 1.0 if st.get("alive") else 0.0
            snap["autotune_triggered"] = float(st.get("n_triggered", 0))
            snap["autotune_swapped"] = float(st.get("n_swapped", 0))
            snap["autotune_rejected"] = float(st.get("n_rejected", 0))
        return snap

    def check_alerts(self, window_s: float = 10.0) -> list:
        """Evaluate every alert rule against a fresh `health_snapshot`;
        returns the events emitted by THIS evaluation (state transitions
        only — steady firing/ok emits nothing)."""
        return self.alerts.evaluate(self.health_snapshot(window_s))

    def health_status(self, window_s: float = 10.0):
        """``(http_status, doc)`` for liveness surfaces (`/healthz`):
        503 when the background flusher is not running or a critical
        alert is firing, 200 otherwise.  Evaluates the rules first so
        the answer reflects NOW, not the last poll."""
        self.check_alerts(window_s)
        firing = self.alerts.firing()
        critical = self.alerts.firing(severity="critical")
        serving = self._thread is not None
        ok = serving and not critical
        doc = {"status": "ok" if ok else "unhealthy",
               "serving": serving,
               "firing": firing, "critical": critical}
        return (200 if ok else 503), doc

    def flush(self) -> bool:
        """Dispatch one due batch if any (size or deadline trigger)."""
        if self._async is not None:
            return self._async.flush()
        return self._dispatch_once(force=False)

    def drain(self) -> int:
        """Force-dispatch until the queue is empty; returns batch count.
        In async mode this also waits for every in-flight slot, so no
        future is left unresolved when it returns."""
        if self._async is not None:
            return self._async.drain()
        n = 0
        while self._dispatch_once(force=True):
            n += 1
        return n

    # -- background flusher ----------------------------------------------
    def start(self) -> "LookupService":
        if self._thread is not None:
            return self
        if self._async is not None:
            # prime the common buckets BEFORE serving: steady-state
            # dispatch then never traces or compiles (§13 warm-up)
            self.warm_now()
            self._thread = self._async.start()
            self._start_autotune()
            return self
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                if self.batcher.wait_ready(timeout=5.0,
                                           until=self._stop.is_set):
                    self._dispatch_once(force=False)
            self.drain()   # complete everything admitted before stop()

        self._thread = threading.Thread(
            target=_loop, name="lookup-flusher", daemon=True)
        self._thread.start()
        self._start_autotune()
        return self

    def _start_autotune(self) -> None:
        """Start the shadow-retuner daemon alongside the flusher (only
        when the config asked for one — `poll_once` stays available for
        explicit/test-driven retunes either way)."""
        at = self.autotune
        if at is not None and at.cfg.daemon:
            at.start()

    def stop(self) -> None:
        """Stop the background flusher, completing everything admitted so
        far.  The service stays usable afterwards — in synchronous mode
        (submit + flush/drain), or via a later start()."""
        if self._thread is None:
            return
        if self.autotune is not None:
            self.autotune.stop()   # no retunes against a draining service
        if self._async is not None:
            self._async.stop()
            self._thread = None
            w = self._warm_thread
            if w is not None and w.is_alive():
                w.join()   # never strand a compile thread past stop()
            return
        self._stop.set()
        self.batcher.wake()
        self._thread.join()
        self._thread = None
        self.drain()       # anything admitted during the join window
        self._stop.clear()

    def __enter__(self) -> "LookupService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
