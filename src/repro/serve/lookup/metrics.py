"""Per-batch serving metrics (DESIGN.md §9.4, §10.5).

Everything the throughput benchmark and the ops story need, with no
dependencies: a log-spaced latency histogram (fixed memory, exact enough
for p50/p99 at 5% bucket resolution), batch occupancy (real keys /
padded dispatch width — the price of the deadline trigger), and
aggregate lookups/sec over the serving window.  The mutable service
adds write-side observations: insert batches/admissions, the current
delta occupancy gauge (delta keys / compaction threshold), and
compaction count + latency.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional


class LatencyHistogram:
    """Log-spaced histogram over [1us, ~84s), growth factor 1.05."""

    def __init__(self, lo_s: float = 1e-6, factor: float = 1.05,
                 n_buckets: int = 360):
        self.lo_s = lo_s
        self.factor = factor
        self.bounds: List[float] = []
        b = lo_s
        for _ in range(n_buckets):
            self.bounds.append(b)
            b *= factor
        self.counts = [0] * (n_buckets + 1)
        self.n = 0
        self.total_s = 0.0

    def record(self, seconds: float) -> None:
        i = 0
        for i, ub in enumerate(self.bounds):
            if seconds < ub:
                break
        else:
            i = len(self.bounds)
        self.counts[i] += 1
        self.n += 1
        self.total_s += seconds

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile (0 if empty)."""
        if self.n == 0:
            return 0.0
        target = q * self.n
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return self.bounds[i] if i < len(self.bounds) else float("inf")
        return self.bounds[-1]

    @property
    def mean(self) -> float:
        return self.total_s / self.n if self.n else 0.0


class ServiceMetrics:
    """Aggregated per-batch observations; `snapshot()` is the read API."""

    def __init__(self):
        self._lock = threading.Lock()
        self.batch_latency = LatencyHistogram()
        self.queue_latency = LatencyHistogram()
        #: end-to-end: oldest submit -> futures resolved.  With the async
        #: executor, p99 decomposes as queue (admission->dispatch) +
        #: batch (dispatch->complete) ~= request — the §13 observability
        #: contract that makes a p99 regression attributable.
        self.request_latency = LatencyHistogram()
        self.n_batches = 0
        self.n_keys = 0
        self.n_requests = 0
        self.sum_occupancy = 0.0
        self.t_first: Optional[float] = None
        self.t_last: Optional[float] = None
        # -- executor observability (async executor; zero otherwise) -----
        self.cache_hits = 0
        self.cache_misses = 0
        self.warm_compiles = 0
        self.sum_inflight = 0
        self.n_inflight_obs = 0
        self.max_inflight = 0
        # -- write side (mutable service; zero for read-only services) --
        self.insert_latency = LatencyHistogram()
        self.compaction_latency = LatencyHistogram()
        self.n_insert_batches = 0
        self.n_insert_keys = 0
        self.n_admitted = 0
        self.n_compactions = 0
        self.n_compaction_failures = 0
        self.delta_keys = 0
        self.delta_threshold = 0

    def observe_batch(self, *, n_keys: int, padded: int, n_requests: int,
                      t_oldest_submit: float, t_start: float,
                      t_end: float) -> None:
        with self._lock:
            self.n_batches += 1
            self.n_keys += n_keys
            self.n_requests += n_requests
            self.sum_occupancy += n_keys / max(padded, 1)
            self.batch_latency.record(t_end - t_start)
            self.queue_latency.record(t_start - t_oldest_submit)
            self.request_latency.record(t_end - t_oldest_submit)
            if self.t_first is None:
                self.t_first = t_start
            self.t_last = t_end

    def note_cache(self, *, hit: bool, warm: bool = False) -> None:
        """One executable-cache access (from `ExecutableCache.get`).
        Warm-up accesses only count their compiles — hit-rate reflects
        serving traffic alone."""
        with self._lock:
            if warm:
                if not hit:
                    self.warm_compiles += 1
            elif hit:
                self.cache_hits += 1
            else:
                self.cache_misses += 1

    def note_slot_depth(self, depth: int) -> None:
        """In-flight slot count observed at one launch."""
        with self._lock:
            self.sum_inflight += depth
            self.n_inflight_obs += 1
            if depth > self.max_inflight:
                self.max_inflight = depth

    def observe_insert_batch(self, *, n_keys: int, admitted: int,
                             t_start: float, t_end: float) -> None:
        with self._lock:
            self.n_insert_batches += 1
            self.n_insert_keys += n_keys
            self.n_admitted += admitted
            self.insert_latency.record(t_end - t_start)
            if self.t_first is None:
                self.t_first = t_start
            self.t_last = t_end

    def observe_compaction(self, *, duration_s: float) -> None:
        # counts + latency only: the delta gauge has a single writer
        # (`set_delta_gauge`, fed the real post-compaction count)
        with self._lock:
            self.n_compactions += 1
            self.compaction_latency.record(duration_s)

    def observe_compaction_failure(self) -> None:
        with self._lock:
            self.n_compaction_failures += 1

    def set_delta_gauge(self, *, delta_keys: int, threshold: int) -> None:
        with self._lock:
            self.delta_keys = int(delta_keys)
            self.delta_threshold = int(threshold)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            window = ((self.t_last - self.t_first)
                      if self.n_batches and self.t_last > self.t_first else 0.0)
            return {
                "batches": self.n_batches,
                "requests": self.n_requests,
                "lookups": self.n_keys,
                "lookups_per_s": (self.n_keys / window) if window else 0.0,
                "mean_occupancy": (self.sum_occupancy / self.n_batches
                                   if self.n_batches else 0.0),
                "mean_batch_ms": self.batch_latency.mean * 1e3,
                "p50_batch_ms": self.batch_latency.quantile(0.50) * 1e3,
                "p99_batch_ms": self.batch_latency.quantile(0.99) * 1e3,
                "mean_queue_ms": self.queue_latency.mean * 1e3,
                "p99_queue_ms": self.queue_latency.quantile(0.99) * 1e3,
                "mean_request_ms": self.request_latency.mean * 1e3,
                "p50_request_ms": self.request_latency.quantile(0.50) * 1e3,
                "p99_request_ms": self.request_latency.quantile(0.99) * 1e3,
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "cache_hit_rate": (
                    self.cache_hits / (self.cache_hits + self.cache_misses)
                    if self.cache_hits + self.cache_misses else 0.0),
                "warm_compiles": self.warm_compiles,
                "mean_inflight_slots": (self.sum_inflight
                                        / self.n_inflight_obs
                                        if self.n_inflight_obs else 0.0),
                "max_inflight_slots": self.max_inflight,
                "insert_batches": self.n_insert_batches,
                "insert_keys": self.n_insert_keys,
                "admitted": self.n_admitted,
                "mean_insert_ms": self.insert_latency.mean * 1e3,
                "compactions": self.n_compactions,
                "compaction_failures": self.n_compaction_failures,
                "mean_compaction_ms": self.compaction_latency.mean * 1e3,
                "p99_compaction_ms": self.compaction_latency.quantile(0.99) * 1e3,
                "delta_keys": self.delta_keys,
                "delta_occupancy": (self.delta_keys / self.delta_threshold
                                    if self.delta_threshold else 0.0),
            }
