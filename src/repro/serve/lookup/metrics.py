"""Per-batch serving metrics (DESIGN.md §9.4, §10.5, §14).

Everything the throughput benchmark and the ops story need: log-spaced
latency histograms (`repro.obs.windows.LatencyHistogram` — O(log n)
bisect record, since this runs under the metrics lock on every batch
completion), batch occupancy (real keys / padded dispatch width — the
price of the deadline trigger), and aggregate lookups/sec over the
serving window.  The mutable service adds write-side observations:
insert batches/admissions, the current delta occupancy gauge (delta
keys / compaction threshold), and compaction count + latency.

Beyond the lifetime aggregates, every request latency also lands in a
`repro.obs.windows.WindowedMetrics` ring, so `windowed(window_s=...)`
answers "what is the p99 *now*" — the §14 rolling-window surface (with
optional SLO target + error-budget burn) that a mid-run regression
cannot hide from and that a p99-aware Tuner objective consumes.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple

from repro.obs.windows import LatencyHistogram, WindowedMetrics

__all__ = ["LatencyHistogram", "ServiceMetrics", "WindowedMetrics"]


class ServiceMetrics:
    """Aggregated per-batch observations; `snapshot()` is the read API."""

    def __init__(self, slo_p99_ms: Optional[float] = None,
                 window_slot_s: float = 0.5, window_slots: int = 240):
        self._lock = threading.Lock()
        self.batch_latency = LatencyHistogram()
        self.queue_latency = LatencyHistogram()
        #: end-to-end: submit -> future resolved.  With the async
        #: executor, p99 decomposes as queue (admission->dispatch) +
        #: batch (dispatch->complete) ~= request — the §13 observability
        #: contract that makes a p99 regression attributable.  Recorded
        #: PER REQUEST when the dispatch path passes `per_request`
        #: observations (both executors do), per batch otherwise.
        self.request_latency = LatencyHistogram()
        #: rolling-window request latencies (§14.2): same observations
        #: as `request_latency`, sliced by completion time.
        self.windows = WindowedMetrics(slot_s=window_slot_s,
                                       n_slots=window_slots,
                                       slo_p99_ms=slo_p99_ms)
        self.n_batches = 0
        self.n_keys = 0
        self.n_requests = 0
        self.sum_occupancy = 0.0
        self.t_first: Optional[float] = None
        self.t_last: Optional[float] = None
        # -- executor observability (async executor; zero otherwise) -----
        self.cache_hits = 0
        self.cache_misses = 0
        self.warm_compiles = 0
        self.sum_inflight = 0
        self.n_inflight_obs = 0
        self.max_inflight = 0
        # -- write side (mutable service; zero for read-only services) --
        self.insert_latency = LatencyHistogram()
        self.compaction_latency = LatencyHistogram()
        self.n_insert_batches = 0
        self.n_insert_keys = 0
        self.n_admitted = 0
        self.n_compactions = 0
        self.n_compaction_failures = 0
        self.delta_keys = 0
        self.delta_threshold = 0
        # -- latency classes (DESIGN.md §17 satellite) -------------------
        #: per-priority-class request counts/keys + latency histogram,
        #: populated when per_request observations carry a class tag
        self._class_stats: Dict[str, Dict] = {}
        # -- routed topology (DESIGN.md §16; zero for broadcast) ---------
        self.n_routed_batches = 0
        self.sum_route_skew = 0.0      # per-batch max/mean shard load
        self.max_route_skew = 0.0
        self._shard_stats: Dict[int, Dict[str, float]] = {}

    def observe_route(self, counts, padded: int) -> None:
        """One completed routed batch: per-shard key counts (including
        zeros for untouched shards) and the summed padded width.  Skew
        is max/mean over ALL shards — 1.0 is a perfectly balanced batch,
        n_shards is everything-in-one-shard."""
        counts = [int(c) for c in counts]
        total = sum(counts)
        n_shards = len(counts)
        mean = total / n_shards if n_shards else 0.0
        skew = (max(counts) / mean) if mean > 0 else 0.0
        with self._lock:
            self.n_routed_batches += 1
            self.sum_route_skew += skew
            if skew > self.max_route_skew:
                self.max_route_skew = skew
            for s, c in enumerate(counts):
                st = self._shard_stats.setdefault(
                    s, {"keys": 0, "batches": 0, "sum_occupancy": 0.0})
                if c:
                    st["keys"] += c
                    st["batches"] += 1
                    # per-shard occupancy vs an even split of the padded
                    # width: how full this shard's sub-batch ran
                    st["sum_occupancy"] += c / max(padded / n_shards, 1)

    def per_shard(self) -> list:
        """Per-shard load rows for the exporters (`/metrics.json` and
        the ``shard``-labelled Prometheus families)."""
        with self._lock:
            rows = []
            for s in sorted(self._shard_stats):
                st = self._shard_stats[s]
                rows.append({
                    "shard": s,
                    "keys": st["keys"],
                    "batches": st["batches"],
                    "mean_occupancy": (st["sum_occupancy"] / st["batches"]
                                       if st["batches"] else 0.0),
                })
            return rows

    def per_class(self) -> list:
        """Per-latency-class rows (requests, keys, p50/p99) — empty
        until a dispatch path reports 3-tuple per_request observations."""
        with self._lock:
            rows = []
            for name in sorted(self._class_stats):
                st = self._class_stats[name]
                rows.append({
                    "priority": name,
                    "requests": st["requests"],
                    "keys": st["keys"],
                    "mean_request_ms": st["latency"].mean * 1e3,
                    "p50_request_ms": st["latency"].quantile(0.50) * 1e3,
                    "p99_request_ms": st["latency"].quantile(0.99) * 1e3,
                })
            return rows

    def observe_batch(self, *, n_keys: int, padded: int, n_requests: int,
                      t_oldest_submit: float, t_start: float,
                      t_end: float,
                      per_request: Optional[Sequence[Tuple]] = None
                      ) -> None:
        """One completed dispatch.  ``per_request`` carries the batch's
        ``(t_submit, n_keys)`` — or ``(t_submit, n_keys, priority)`` —
        per request: request latency is then recorded per request
        (exactly what the trace's request spans hold, so trace-derived
        and histogram p99 reconcile) instead of once per batch at the
        oldest submit.  A 3-tuple's latency class additionally lands in
        the per-class counters/histograms (`snapshot()`'s ``class_*``
        keys)."""
        with self._lock:
            self.n_batches += 1
            self.n_keys += n_keys
            self.n_requests += n_requests
            self.sum_occupancy += n_keys / max(padded, 1)
            self.batch_latency.record(t_end - t_start)
            self.queue_latency.record(t_start - t_oldest_submit)
            if per_request:
                for t_submit, nk, *rest in per_request:
                    self.request_latency.record(t_end - t_submit)
                    self.windows.record(t_end - t_submit, units=nk, t=t_end)
                    if rest:
                        st = self._class_stats.setdefault(
                            str(rest[0]),
                            {"requests": 0, "keys": 0,
                             "latency": LatencyHistogram()})
                        st["requests"] += 1
                        st["keys"] += nk
                        st["latency"].record(t_end - t_submit)
            else:
                self.request_latency.record(t_end - t_oldest_submit)
                self.windows.record(t_end - t_oldest_submit, units=n_keys,
                                    t=t_end)
            if self.t_first is None:
                self.t_first = t_start
            self.t_last = t_end

    def note_cache(self, *, hit: bool, warm: bool = False) -> None:
        """One executable-cache access (from `ExecutableCache.get`).
        Warm-up accesses only count their compiles — hit-rate reflects
        serving traffic alone."""
        with self._lock:
            if warm:
                if not hit:
                    self.warm_compiles += 1
            elif hit:
                self.cache_hits += 1
            else:
                self.cache_misses += 1

    def note_slot_depth(self, depth: int) -> None:
        """In-flight slot count observed at one launch."""
        with self._lock:
            self.sum_inflight += depth
            self.n_inflight_obs += 1
            if depth > self.max_inflight:
                self.max_inflight = depth

    def observe_insert_batch(self, *, n_keys: int, admitted: int,
                             t_start: float, t_end: float) -> None:
        with self._lock:
            self.n_insert_batches += 1
            self.n_insert_keys += n_keys
            self.n_admitted += admitted
            self.insert_latency.record(t_end - t_start)
            if self.t_first is None:
                self.t_first = t_start
            self.t_last = t_end

    def observe_compaction(self, *, duration_s: float) -> None:
        # counts + latency only: the delta gauge has a single writer
        # (`set_delta_gauge`, fed the real post-compaction count)
        with self._lock:
            self.n_compactions += 1
            self.compaction_latency.record(duration_s)

    def observe_compaction_failure(self) -> None:
        with self._lock:
            self.n_compaction_failures += 1

    def set_delta_gauge(self, *, delta_keys: int, threshold: int) -> None:
        with self._lock:
            self.delta_keys = int(delta_keys)
            self.delta_threshold = int(threshold)

    def windowed(self, window_s: float = 10.0) -> Dict[str, float]:
        """Rolling-window request-latency snapshot (§14.2): quantiles,
        key rate, and SLO budget burn over the trailing ``window_s`` —
        the read surface a live p99 regression cannot hide from."""
        snap = self.windows.snapshot(window_s)
        snap["lookups_per_s"] = snap.pop("units_per_s")
        snap["lookups"] = snap.pop("units")
        return snap

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            # the serving window spans ANY observation — insert-only
            # traffic sets t_first/t_last through observe_insert_batch
            # and must not read as a zero-length window
            window = ((self.t_last - self.t_first)
                      if self.t_first is not None
                      and self.t_last is not None
                      and self.t_last > self.t_first else 0.0)
            out = {
                "batches": self.n_batches,
                "requests": self.n_requests,
                "lookups": self.n_keys,
                "lookups_per_s": (self.n_keys / window) if window else 0.0,
                "mean_occupancy": (self.sum_occupancy / self.n_batches
                                   if self.n_batches else 0.0),
                "mean_batch_ms": self.batch_latency.mean * 1e3,
                "p50_batch_ms": self.batch_latency.quantile(0.50) * 1e3,
                "p99_batch_ms": self.batch_latency.quantile(0.99) * 1e3,
                "mean_queue_ms": self.queue_latency.mean * 1e3,
                "p99_queue_ms": self.queue_latency.quantile(0.99) * 1e3,
                "mean_request_ms": self.request_latency.mean * 1e3,
                "p50_request_ms": self.request_latency.quantile(0.50) * 1e3,
                "p99_request_ms": self.request_latency.quantile(0.99) * 1e3,
                "slo_p99_target_ms": (self.windows.slo_p99_ms
                                      if self.windows.slo_p99_ms is not None
                                      else 0.0),
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "cache_accesses": self.cache_hits + self.cache_misses,
                "cache_hit_rate": (
                    self.cache_hits / (self.cache_hits + self.cache_misses)
                    if self.cache_hits + self.cache_misses else 0.0),
                "warm_compiles": self.warm_compiles,
                "mean_inflight_slots": (self.sum_inflight
                                        / self.n_inflight_obs
                                        if self.n_inflight_obs else 0.0),
                "max_inflight_slots": self.max_inflight,
                "insert_batches": self.n_insert_batches,
                "insert_keys": self.n_insert_keys,
                "inserts_per_s": (self.n_insert_keys / window
                                  if window else 0.0),
                "admitted": self.n_admitted,
                "mean_insert_ms": self.insert_latency.mean * 1e3,
                "compactions": self.n_compactions,
                "compaction_failures": self.n_compaction_failures,
                "mean_compaction_ms": self.compaction_latency.mean * 1e3,
                "p99_compaction_ms": self.compaction_latency.quantile(0.99) * 1e3,
                "delta_keys": self.delta_keys,
                "delta_occupancy": (self.delta_keys / self.delta_threshold
                                    if self.delta_threshold else 0.0),
                "routed_batches": self.n_routed_batches,
                "route_skew": (self.sum_route_skew / self.n_routed_batches
                               if self.n_routed_batches else 0.0),
                "route_max_skew": self.max_route_skew,
                "route_shards": len(self._shard_stats),
            }
            # flat per-class keys ride the same namespace the alert
            # rules and exporters already consume
            for name, st in self._class_stats.items():
                out[f"class_{name}_requests"] = st["requests"]
                out[f"class_{name}_keys"] = st["keys"]
                out[f"class_{name}_p99_request_ms"] = (
                    st["latency"].quantile(0.99) * 1e3)
            return out
