"""Index-health telemetry + alert engine (DESIGN.md §15).

Acceptance contracts pinned here:

  * instrumented bit-identity — `compile_instrumented` returns the SAME
    positions as the plain lookup for every index family on both
    backends, and its device-reduced stats vector is backend-invariant
    and matches a plain numpy scatter reference exactly;
  * `GenerationHealth` host accumulation (packed vector == named dict),
    interpolated displacement quantiles, windowed drift scoring, and
    the `HealthMonitor` version routing / retention bound;
  * the `AlertEngine` state machine — flapping, cooldown suppression
    with late emit / silent cancel, multi-rule keys, per-(event, sink)
    failure isolation, cold-start sample gates;
  * export surfaces — non-finite Prometheus values, 400 on malformed
    ``window_s``, `/healthz` liveness+alert semantics, `/health.json`
    and `/alerts.json`, JSONL sink-outage survival;
  * end-to-end on BOTH executors: a mid-run hot-spot shift raises
    `workload_drift` while stationary traffic stays silent, and the
    mutable service's compaction lifecycle shows up in the per-
    generation health records.
"""
import jax

jax.config.update("jax_enable_x64", True)

import functools
import json
import time
import types
import urllib.error
import urllib.request

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data import sosd
from repro.core import base, plan
from repro.obs import alerts as alerts_mod
from repro.obs import health as health_mod
from repro.obs.alerts import AlertEngine, AlertRule, default_rules
from repro.obs.export import (JsonlMetricsLogger, MetricsServer,
                              prometheus_text)
from repro.obs.health import (GenerationHealth, HEALTH_DISP_BUCKETS,
                              HEALTH_STATS_SIZE, HEALTH_TRAFFIC_BUCKETS,
                              HealthMonitor, build_rank_hist, unpack_stats)
from repro.serve.lookup import (LookupService, LookupServiceConfig,
                                MutableLookupService,
                                MutableLookupServiceConfig)

N_KEYS, N_Q = 8_000, 512

INDEXES = [
    ("rmi", dict(branching=512)),
    ("pgm", dict(eps=32)),
    ("radix_spline", dict(eps=16, radix_bits=12)),
    ("rbs", dict(radix_bits=12)),
    ("btree", dict(sample=8)),
    ("binary_search", {}),
]


@functools.lru_cache(maxsize=None)
def _cell(ds: str):
    keys = sosd.generate(ds, N_KEYS, seed=3)
    q = sosd.make_queries(keys, N_Q, seed=5, present_frac=0.7)
    return keys, q, np.searchsorted(keys, q)


def _ref_stats(pos, lo, hi, n, n_valid):
    """Plain numpy scatter reference for `plan.health_stats_expr` —
    the O(batch) host computation the device reduction replaces."""
    pos, lo, hi = (np.asarray(a)[:n_valid].astype(np.int64)
                   for a in (pos, lo, hi))
    mid = lo + (hi - lo) // 2
    disp = np.abs(pos - mid)
    bucket = np.where(disp == 0, 0, np.minimum(
        np.frexp(disp.astype(np.float64))[1], HEALTH_DISP_BUCKETS - 1))
    disp_hist = np.bincount(bucket, minlength=HEALTH_DISP_BUCKETS)
    rank = np.clip(pos, 0, n - 1)
    traffic = np.bincount(rank * HEALTH_TRAFFIC_BUCKETS // n,
                          minlength=HEALTH_TRAFFIC_BUCKETS)
    return {"n": n_valid, "disp_sum": int(disp.sum()),
            "disp_max": int(disp.max()), "disp_hist": disp_hist,
            "traffic_hist": traffic,
            "width_sum": int((hi - lo + 1).sum())}


# ---------------------------------------------------------------------------
# device side: instrumented executables
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,hyper", INDEXES,
                         ids=[n for n, _ in INDEXES])
def test_instrumented_parity_and_backend_invariance(name, hyper):
    """Positions from the instrumented executable are bit-identical to
    the plain lookup on BOTH backends, and the packed stats vector is
    backend-invariant (stats derive from the plan's jnp bounds)."""
    keys, q, lb = _cell("osm")
    b = base.REGISTRY[name](keys, **hyper)
    p = plan.lower(b, jnp.asarray(keys))
    qj, nv = jnp.asarray(q), np.int32(N_Q)
    pos_j, st_j = p.compile_instrumented(backend="jnp")(qj, nv)
    pos_p, st_p = p.compile_instrumented(backend="pallas",
                                         interpret=True)(qj, nv)
    np.testing.assert_array_equal(np.asarray(pos_j), lb)
    np.testing.assert_array_equal(np.asarray(pos_p), lb)
    np.testing.assert_array_equal(np.asarray(st_j), np.asarray(st_p))
    st = unpack_stats(st_j)
    assert st["n"] == N_Q
    assert st["disp_hist"].sum() == N_Q == st["traffic_hist"].sum()


def test_instrumented_stats_match_numpy_scatter_reference():
    """The scatter-free device histograms equal a plain `.at[idx].add`
    style numpy reference — same buckets, same counts, exactly."""
    keys, q, lb = _cell("amzn")
    b = base.REGISTRY["rmi"](keys, branching=512)
    p = plan.lower(b, jnp.asarray(keys))
    _, packed = p.compile_instrumented()(jnp.asarray(q), np.int32(N_Q))
    got = unpack_stats(packed)
    lo, hi = p.bounds.predict(p.bounds.state, jnp.asarray(q))
    ref = _ref_stats(lb, lo, hi, N_KEYS, N_Q)
    for k in ("n", "disp_sum", "disp_max", "width_sum"):
        assert got[k] == ref[k], k
    np.testing.assert_array_equal(got["disp_hist"], ref["disp_hist"])
    np.testing.assert_array_equal(got["traffic_hist"], ref["traffic_hist"])


def test_instrumented_pad_lanes_do_not_pollute_stats():
    """Pad lanes beyond ``n_valid`` are masked out on device: a padded
    batch reports exactly the stats of its real prefix."""
    keys, q, _ = _cell("face")
    p = plan.lower(base.REGISTRY["pgm"](keys, eps=32), jnp.asarray(keys))
    fn = p.compile_instrumented()
    _, st_exact = fn(jnp.asarray(q), np.int32(N_Q))
    q_pad = np.concatenate([q, np.full(N_Q, keys[0], np.uint64)])
    _, st_padded = fn(jnp.asarray(q_pad), np.int32(N_Q))
    np.testing.assert_array_equal(np.asarray(st_exact),
                                  np.asarray(st_padded))


def test_point_only_instrumented_counts_found_lanes():
    """robin_hash has no prediction window: stats count only the FOUND
    lanes of the real batch (traffic from their positions), zero
    displacement, and the merged path refuses to exist."""
    keys, q, lb = _cell("wiki")
    p = plan.lower(base.REGISTRY["robin_hash"](keys), jnp.asarray(keys))
    pos, packed = p.compile_instrumented()(jnp.asarray(q), np.int32(N_Q))
    pos = np.asarray(pos)
    present = np.isin(q, keys)
    np.testing.assert_array_equal(pos >= 0, present)
    st = unpack_stats(packed)
    assert st["n"] == int(present.sum())
    assert st["disp_sum"] == 0 and st["disp_max"] == 0
    assert st["traffic_hist"].sum() == st["n"]
    with pytest.raises(ValueError):
        p.instrumented_merged_expr()


def test_instrumented_merged_parity():
    """Merged instrumented ranks equal `compile_merged`'s; the stats
    describe the BASE plan (same vector as the unmerged path)."""
    keys, q, _ = _cell("amzn")
    delta = sosd.generate("osm", 256, seed=7)
    delta = delta[~np.isin(delta, keys)]
    p = plan.lower(base.REGISTRY["radix_spline"](keys, eps=16,
                                                 radix_bits=12),
                   jnp.asarray(keys))
    qj, dj = jnp.asarray(q), jnp.asarray(np.sort(delta))
    want = np.asarray(p.compile_merged()(qj, dj))
    pos, st_m = p.compile_instrumented_merged()(qj, np.int32(N_Q), dj)
    np.testing.assert_array_equal(np.asarray(pos), want)
    _, st_plain = p.compile_instrumented()(qj, np.int32(N_Q))
    np.testing.assert_array_equal(np.asarray(st_m), np.asarray(st_plain))


def test_unpack_stats_shape_contract():
    vec = np.arange(HEALTH_STATS_SIZE, dtype=np.int64)
    st = unpack_stats(vec)
    assert st["n"] == 0 and st["steps_sum"] == 4
    assert st["disp_hist"].shape == (HEALTH_DISP_BUCKETS,)
    assert st["traffic_hist"].shape == (HEALTH_TRAFFIC_BUCKETS,)
    with pytest.raises(ValueError):
        unpack_stats(np.zeros(HEALTH_STATS_SIZE - 1))


def test_build_displacement_quantile_caches_and_degenerates():
    keys, _, _ = _cell("osm")
    p = plan.lower(base.REGISTRY["rmi"](keys, branching=512),
                   jnp.asarray(keys))
    v = p.build_displacement_quantile(0.99)
    assert v > 0.0 and p.build_displacement_quantile(0.99) == v
    ph = plan.lower(base.REGISTRY["robin_hash"](keys), jnp.asarray(keys))
    assert ph.build_displacement_quantile(0.99) == 0.0


# ---------------------------------------------------------------------------
# host side: GenerationHealth / HealthMonitor
# ---------------------------------------------------------------------------
def _mk_stats(disp_hist=None, traffic_hist=None, n=0, **kw):
    st = {"n": n, "disp_sum": 0, "disp_max": 0, "width_sum": 0,
          "steps_sum": 0,
          "disp_hist": np.zeros(HEALTH_DISP_BUCKETS, np.int64),
          "traffic_hist": np.zeros(HEALTH_TRAFFIC_BUCKETS, np.int64)}
    if disp_hist is not None:
        st["disp_hist"] = np.asarray(disp_hist, np.int64)
    if traffic_hist is not None:
        st["traffic_hist"] = np.asarray(traffic_hist, np.int64)
    st.update(kw)
    return st


def test_accumulate_packed_vector_equals_dict():
    """The packed int64 vector an executable returns and the named dict
    fold to the same record."""
    keys, q, _ = _cell("face")
    p = plan.lower(base.REGISTRY["pgm"](keys, eps=32), jnp.asarray(keys))
    _, packed = p.compile_instrumented()(jnp.asarray(q), np.int32(N_Q))
    a = GenerationHealth(1, "pgm", N_KEYS, p.bounds.max_err,
                         clock=lambda: 0.0)
    b = GenerationHealth(1, "pgm", N_KEYS, p.bounds.max_err,
                         clock=lambda: 0.0)
    a.accumulate(np.asarray(packed))
    b.accumulate(unpack_stats(packed))
    assert a.snapshot() == b.snapshot()


def test_disp_quantile_interpolates_within_bucket():
    g = GenerationHealth(1, "rmi", 1000, 1024, clock=lambda: 0.0)
    # 100 observations, all landing in bucket 10 = [512, 1023]
    h = np.zeros(HEALTH_DISP_BUCKETS, np.int64)
    h[10] = 100
    g.accumulate(_mk_stats(disp_hist=h, n=100, disp_max=1000))
    # median interpolates to mid-bucket, NOT the 1023 upper edge
    assert 512 < g.disp_quantile(0.5) < 1023
    assert abs(g.disp_quantile(0.5) - (512 + 0.5 * 511)) < 1e-9
    # all mass at zero displacement
    g0 = GenerationHealth(1, "rmi", 1000, 1024, clock=lambda: 0.0)
    z = np.zeros(HEALTH_DISP_BUCKETS, np.int64)
    z[0] = 7
    g0.accumulate(_mk_stats(disp_hist=z, n=7))
    assert g0.disp_quantile(0.99) == 0.0
    # overflow bucket reports the observed max
    go = GenerationHealth(1, "rmi", 1000, 1024, clock=lambda: 0.0)
    o = np.zeros(HEALTH_DISP_BUCKETS, np.int64)
    o[-1] = 5
    go.accumulate(_mk_stats(disp_hist=o, n=5, disp_max=9_999_999))
    assert go.disp_quantile(0.99) == 9_999_999.0


def test_drift_is_windowed_not_lifetime():
    """A traffic shift must not be diluted by the stationary history:
    the drift read over a trailing window sees ONLY the shift."""
    t = [0.0]
    g = GenerationHealth(1, "rmi", 64_000, 64, slot_s=0.5, n_slots=240,
                         clock=lambda: t[0])
    uniform = np.full(HEALTH_TRAFFIC_BUCKETS, 100, np.int64)
    hot = np.zeros(HEALTH_TRAFFIC_BUCKETS, np.int64)
    hot[0] = HEALTH_TRAFFIC_BUCKETS * 100
    for _ in range(20):           # stationary history at t in [0, 10)
        g.accumulate(_mk_stats(traffic_hist=uniform,
                               n=int(uniform.sum())))
        t[0] += 0.5
    tv_before, n_before = g.drift(window_s=5.0)
    assert n_before > 0 and tv_before < 0.05
    t[0] += 60.0                  # jump past the window, then shift
    g.accumulate(_mk_stats(traffic_hist=hot, n=int(hot.sum())))
    tv_hot, _ = g.drift(window_s=5.0)
    assert tv_hot > 0.9           # 1 - 1/K of the mass moved
    tv_life = 0.5 * float(np.abs(
        g.traffic_total / g.traffic_total.sum()
        - g.build_hist / g.build_hist.sum()).sum())
    assert tv_life < 0.1          # lifetime view would have hidden it


@pytest.mark.parametrize("n", [64, 1_000, 8_001, 200_000])
def test_build_rank_hist_matches_device_partition(n):
    """Host build-time histogram and the device traffic partition use
    the SAME bucket map r -> r*K//n (awkward n included)."""
    h = build_rank_hist(n)
    assert int(h.sum()) == n
    ranks = np.arange(n, dtype=np.int64)
    ref = np.bincount(ranks * HEALTH_TRAFFIC_BUCKETS // n,
                      minlength=HEALTH_TRAFFIC_BUCKETS)
    np.testing.assert_array_equal(h, ref)


def _fake_gen(version, n_keys=1000, max_err=64, name="rmi"):
    plan_obj = types.SimpleNamespace(name=name,
                                     bounds=types.SimpleNamespace(
                                         max_err=max_err))
    return types.SimpleNamespace(version=version, n_keys=n_keys,
                                 plan=plan_obj)


def test_monitor_routes_by_version_and_bounds_retention():
    mon = HealthMonitor(keep=3, clock=lambda: 0.0)
    for v in range(5):
        mon.on_publish(_fake_gen(v))
    assert mon.get(0) is None and mon.get(1) is None  # evicted
    assert mon.current().version == 4
    # a batch completing against a retired-but-retained generation
    # lands in ITS record, never the successor's
    mon.accumulate(3, _mk_stats(n=7, disp_sum=21))
    assert mon.get(3).n == 7 and mon.get(4).n == 0
    mon.accumulate(999, _mk_stats(n=5))       # unknown version: dropped
    assert [r["generation_version"] for r in mon.records()] == \
        [2.0, 3.0, 4.0]


def test_note_delta_compaction_debt_gauge():
    mon = HealthMonitor(clock=lambda: 0.0)
    assert mon.snapshot()["compaction_debt"] == 0.0   # pre-publish zeros
    mon.on_publish(_fake_gen(1))
    mon.note_delta(48, 64)
    assert mon.snapshot()["compaction_debt"] == pytest.approx(0.75)
    mon.on_publish(_fake_gen(2))                      # compaction: resets
    assert mon.snapshot()["compaction_debt"] == 0.0


# ---------------------------------------------------------------------------
# alert engine state machine (satellite: flapping / cooldown / sinks)
# ---------------------------------------------------------------------------
RULE = AlertRule("hot", key="x", op=">", threshold=1.0, cooldown_s=10.0)


def _engine(rules=(RULE,), sinks=()):
    t = [0.0]
    eng = AlertEngine(rules=rules, sinks=sinks, clock=lambda: t[0])
    return eng, t


def test_fire_resolve_refire_cycle():
    eng, t = _engine()
    assert eng.evaluate({"x": 0.5}) == []            # ok
    ev = eng.evaluate({"x": 2.0})                    # fire
    assert [e["state"] for e in ev] == ["firing"]
    assert eng.firing() == ["hot"]
    assert eng.evaluate({"x": 2.0}) == []            # steady: no re-emit
    t[0] = 20.0
    ev = eng.evaluate({"x": 0.5})                    # resolve
    assert [e["state"] for e in ev] == ["resolved"]
    assert eng.firing() == []
    t[0] = 40.0
    ev = eng.evaluate({"x": 3.0})                    # cooled: re-fire emits
    assert [e["state"] for e in ev] == ["firing"]
    st = eng.state()["hot"]
    assert st["n_fired"] == 2 and st["n_resolved"] == 1


def test_flap_inside_cooldown_suppresses_then_late_emits():
    eng, t = _engine()
    eng.evaluate({"x": 2.0})                         # fire @ t=0, emitted
    t[0] = 1.0
    eng.evaluate({"x": 0.5})                         # resolve (emitted)
    t[0] = 2.0
    assert eng.evaluate({"x": 2.0}) == []            # re-fire SUPPRESSED
    assert eng.firing() == ["hot"]                   # ...but state is true
    assert eng.state()["hot"]["n_suppressed"] == 1
    t[0] = 11.0                                      # cooldown expired,
    ev = eng.evaluate({"x": 2.0})                    # still firing: late emit
    assert [e["state"] for e in ev] == ["firing"]
    assert eng.evaluate({"x": 2.0}) == []            # delivered exactly once


def test_flap_that_resolves_first_is_cancelled_silently():
    eng, t = _engine()
    eng.evaluate({"x": 2.0})
    t[0] = 1.0
    eng.evaluate({"x": 0.5})
    t[0] = 2.0
    eng.evaluate({"x": 2.0})                         # suppressed fire
    t[0] = 3.0
    ev = eng.evaluate({"x": 0.5})                    # resolved before expiry
    assert ev == []                                  # the whole flap: silent
    assert eng.firing() == []
    t[0] = 30.0
    assert eng.evaluate({"x": 0.5}) == []            # nothing pending


def test_multiple_rules_on_one_key_fire_independently():
    r_warn = AlertRule("warn_x", key="x", op=">", threshold=1.0)
    r_crit = AlertRule("crit_x", key="x", op=">", threshold=5.0,
                       severity="critical")
    eng, _ = _engine(rules=(r_warn, r_crit))
    eng.evaluate({"x": 2.0})
    assert eng.firing() == ["warn_x"]
    assert not eng.has_critical_firing()
    eng.evaluate({"x": 9.0})
    assert set(eng.firing()) == {"warn_x", "crit_x"}
    assert eng.has_critical_firing()
    assert eng.firing(severity="critical") == ["crit_x"]


def test_sink_failure_is_isolated_per_event_and_counted():
    good = []

    def bad_sink(event):
        raise RuntimeError("pager down")

    eng, _ = _engine(rules=(RULE, AlertRule("hot2", key="y", op=">",
                                            threshold=1.0)),
                     sinks=(bad_sink, good.append))
    ev = eng.evaluate({"x": 2.0, "y": 2.0})
    assert len(ev) == 2                      # evaluation unharmed
    assert [e["rule"] for e in good] == ["hot", "hot2"]   # good sink: all
    assert eng.n_sink_errors == 2            # bad sink: counted per event
    assert eng.firing() == ["hot", "hot2"]


def test_min_samples_gate_and_absent_key_abstain():
    r = AlertRule("gated", key="x", op=">", threshold=1.0,
                  min_samples_key="n", min_samples=100)
    eng, _ = _engine(rules=(r,))
    assert eng.evaluate({"x": 99.0, "n": 5}) == []   # cold: abstains
    assert eng.firing() == []
    assert eng.evaluate({"n": 500}) == []            # key absent: abstains
    ev = eng.evaluate({"x": 99.0, "n": 500})         # warm: fires
    assert [e["rule"] for e in ev] == ["gated"]
    assert eng.evaluate({"x": 99.0, "n": 5}) == [] and \
        eng.firing() == ["gated"]                    # re-gated: state sticks


def test_rule_validation_rejects_bad_op_and_severity():
    with pytest.raises(ValueError):
        AlertRule("bad", key="x", op="~")
    with pytest.raises(ValueError):
        AlertRule("bad", key="x", severity="page-everyone")


def test_default_rules_quiet_on_cold_snapshot():
    """The shipped ruleset never fires on an idle just-built service
    snapshot (every rule is sample-gated or keyed on zero defaults)."""
    eng = AlertEngine(rules=default_rules())
    snap = dict(health_mod._zero_snapshot())
    snap.update(window_slo_budget_burn=0.0, window_n=0.0,
                cache_hit_rate=0.0, cache_accesses=0.0,
                inflight_saturation=0.0, batches=0.0, trace_dropped=0.0)
    assert eng.evaluate(snap) == [] and eng.firing() == []


# ---------------------------------------------------------------------------
# export surfaces
# ---------------------------------------------------------------------------
def test_prometheus_nonfinite_values_render_per_exposition_format():
    text = prometheus_text({"a": float("inf"), "b": float("-inf"),
                            "c": float("nan"), "d": 1.0})
    assert "repro_lookup_a +Inf" in text
    assert "repro_lookup_b -Inf" in text
    assert "repro_lookup_c NaN" in text
    assert "inf\n" not in text and "nan\n" not in text


def _get(base_url, path):
    with urllib.request.urlopen(base_url + path, timeout=10) as r:
        return r.status, r.read().decode()


@functools.lru_cache(maxsize=None)
def _small_keys():
    return sosd.generate("amzn", N_KEYS, seed=3)


def test_http_health_endpoints_and_healthz_semantics():
    keys = _small_keys()
    svc = LookupService(keys, LookupServiceConfig(
        index="rmi", hyper=dict(branching=256), max_batch=256))
    with MetricsServer(svc, port=0) as srv:
        url = f"http://127.0.0.1:{srv.port}"
        # not started: the flusher is down -> 503, honest about why
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(url, "/healthz")
        assert ei.value.code == 503
        doc = json.loads(ei.value.read().decode())
        assert doc["serving"] is False and doc["critical"] == []

        with svc:
            got = svc.lookup(sosd.make_queries(keys, 600, seed=5))
            assert got.shape == (600,)
            status, body = _get(url, "/healthz")
            assert status == 200
            assert json.loads(body)["status"] == "ok"

            status, body = _get(url, "/health.json")
            doc = json.loads(body)
            assert status == 200
            assert doc["snapshot"]["health_n"] >= 600
            assert doc["snapshot"]["disp_p99_ratio"] > 0.0
            assert len(doc["generations"]) == 1
            assert doc["alerts"]["firing"] == []

            status, body = _get(url, "/alerts.json")
            doc = json.loads(body)
            assert status == 200
            assert {r["name"] for r in doc["rules"]} >= \
                {"workload_drift", "error_inflation", "slo_burn"}
            assert doc["firing"] == []

            # malformed window_s is the client's error: 400, not 500
            for path in ("/metrics?window_s=potato",
                         "/health.json?window_s=potato"):
                with pytest.raises(urllib.error.HTTPError) as ei:
                    _get(url, path)
                assert ei.value.code == 400

            # a firing CRITICAL rule flips liveness to 503 while serving
            svc.alerts.add_rule(AlertRule(
                "always", key="serving", op=">=", threshold=0.0,
                severity="critical"))
            with pytest.raises(urllib.error.HTTPError) as ei:
                _get(url, "/healthz")
            assert ei.value.code == 503
            doc = json.loads(ei.value.read().decode())
            assert doc["serving"] is True and "always" in doc["critical"]


def test_jsonl_logger_survives_sink_outage(tmp_path):
    keys = _small_keys()
    svc = LookupService(keys, LookupServiceConfig(max_batch=256))
    bad = JsonlMetricsLogger(svc, str(tmp_path), interval_s=60.0)
    assert bad.write_once() is False       # path is a directory: fails
    assert bad.write_once() is False       # ...and keeps failing quietly
    assert bad.n_errors == 2 and bad.n_written == 0
    good = JsonlMetricsLogger(svc, str(tmp_path / "m.jsonl"),
                              interval_s=60.0)
    assert good.write_once() is True
    with open(tmp_path / "m.jsonl") as f:
        doc = json.loads(f.readline())
    assert "health" in doc and doc["alerts_firing"] == []


# ---------------------------------------------------------------------------
# end-to-end: drift alert on both executors; mutable lifecycle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("executor", ["sync", "async"])
def test_drift_alert_fires_on_skew_silent_on_stationary(executor):
    """The §15 e2e acceptance cell: stationary traffic keeps every
    alert quiet; a mid-run hot-spot shift raises `workload_drift` (and
    positions stay correct throughout — the instrumented path serves
    the answers)."""
    keys = _small_keys()
    svc = LookupService(keys, LookupServiceConfig(
        index="rmi", hyper=dict(branching=256), max_batch=512,
        executor=executor, warm_buckets=(512,)))
    with svc:
        q = sosd.make_queries(keys, 1_024, seed=5, present_frac=0.7)
        np.testing.assert_array_equal(svc.lookup(q),
                                      np.searchsorted(keys, q))
        svc.check_alerts(window_s=3600.0)
        assert "workload_drift" not in svc.alerts.firing()
        snap = svc.health_snapshot(window_s=3600.0)
        assert snap["drift_n"] >= 1_024 and snap["drift_tv"] <= 0.6

        # hot-spot shift: every query from the bottom 1/64 of key space.
        # Age the stationary slots out of the drift window first — the
        # 1 s read window must hold the shifted traffic ONLY.
        time.sleep(1.2)
        hot = np.random.default_rng(0).choice(
            keys[: max(1, len(keys) // 64)], size=1_024)
        np.testing.assert_array_equal(svc.lookup(hot),
                                      np.searchsorted(keys, hot))
        svc.check_alerts(window_s=1.0)      # tight window: shift only
        assert "workload_drift" in svc.alerts.firing()
        assert svc.health_snapshot(window_s=1.0)["drift_tv"] > 0.6
    assert svc.alerts.state()["workload_drift"]["n_fired"] >= 1


def test_health_off_is_bit_identical_and_reports_zeros():
    keys = _small_keys()
    q = sosd.make_queries(keys, 700, seed=9, present_frac=0.5)
    on = LookupService(keys, LookupServiceConfig(max_batch=256))
    off = LookupService(keys, LookupServiceConfig(max_batch=256,
                                                  health=False))
    with on, off:
        np.testing.assert_array_equal(on.lookup(q), off.lookup(q))
    assert on.health_snapshot()["health_n"] >= 700
    snap = off.health_snapshot()
    assert "health_n" not in snap            # no health keys published
    assert off.check_alerts() == []          # rules abstain, not crash


def test_mutable_compaction_lifecycle_in_health_records():
    """Inserts grow `compaction_debt`; the post-compaction generation
    gets its OWN record (debt reset, version advanced) while the
    retired generation's record survives for post-mortems."""
    keys = _small_keys()[:4_000]
    extra = sosd.generate("osm", 600, seed=11)
    extra = extra[~np.isin(extra, keys)][:512]
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        index="pgm", hyper=dict(eps=32), max_batch=256,
        compact_threshold=1 << 30))          # manual compaction only
    with svc:
        svc.insert(extra).result(30.0)
        v0 = svc.generation.version
        debt = svc.health_snapshot()["compaction_debt"]
        assert debt == pytest.approx(len(extra) / (1 << 30))
        assert svc.health.current().delta_keys == len(extra)
        q = sosd.make_queries(keys, 600, seed=5)
        merged = np.sort(np.concatenate([keys, extra]))
        np.testing.assert_array_equal(svc.lookup(q),
                                      np.searchsorted(merged, q))
        gen = svc.force_compact()
        assert gen is not None and gen.version > v0
        snap = svc.health_snapshot()
        assert snap["compaction_debt"] == 0.0
        assert snap["generation_version"] == float(gen.version)
        recs = svc.registry.health_records()
        assert [int(r["generation_version"]) for r in recs] == \
            [v0, gen.version]
        assert recs[0]["health_n"] >= 600    # retired gen kept its stats
        np.testing.assert_array_equal(svc.lookup(q),
                                      np.searchsorted(merged, q))
