"""Lookup-service tests: admission/batcher policy, FIFO completion,
sharded dispatch bit-exactness, hot-swap atomicity, real-SOSD loader."""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import base, search, spec
from repro.data import sosd
from repro.serve.common import MonotonicCounter
from repro.serve.lookup import (ClientBacklogFull, IndexRegistry,
                                LookupService, LookupServiceConfig,
                                MicroBatcher, ShardedDispatcher)
from repro.serve.lookup.metrics import LatencyHistogram, ServiceMetrics


# ---------------------------------------------------------------------------
# shared id counter
# ---------------------------------------------------------------------------
def test_monotonic_counter_unique_across_threads():
    c = MonotonicCounter()
    seen = []

    def worker():
        seen.extend(c.next() for _ in range(500))

    ts = [threading.Thread(target=worker) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(set(seen)) == 2000


# ---------------------------------------------------------------------------
# micro-batcher flush policy (no jax involved)
# ---------------------------------------------------------------------------
def test_batcher_flushes_on_size():
    b = MicroBatcher(max_batch=100, deadline_s=60.0)
    for _ in range(3):
        b.submit(np.arange(40, dtype=np.uint64) + 1)
    assert b.ready()                       # 120 >= 100, no deadline needed
    batch = b.take()
    # whole requests in FIFO order, stop before exceeding max_batch
    assert [r.keys.size for r in batch] == [40, 40]
    assert [r.rid for r in batch] == sorted(r.rid for r in batch)
    assert b.pending_keys == 40            # third request left queued


def test_batcher_flushes_on_deadline():
    b = MicroBatcher(max_batch=10_000, deadline_s=0.05)
    b.submit(np.arange(5, dtype=np.uint64) + 1)
    assert not b.ready()                   # far below size trigger
    assert b.take() == []
    # generous timeout: the 50ms deadline firing is the assertion, the
    # timeout only bounds a BROKEN wait — never a tight wall-clock race
    assert b.wait_ready(timeout=30.0)      # deadline fires
    batch = b.take()
    assert len(batch) == 1 and batch[0].keys.size == 5
    assert b.pending_keys == 0


def test_batcher_oversize_request_not_split():
    b = MicroBatcher(max_batch=8, deadline_s=60.0)
    b.submit(np.arange(50, dtype=np.uint64) + 1)
    batch = b.take()                       # size trigger: 50 >= 8
    assert len(batch) == 1 and batch[0].keys.size == 50


def test_batcher_wait_ready_wakes_on_submit():
    # a submit() while wait_ready blocks must wake it via the size
    # trigger: with a 60s deadline and a 30s timeout, returning True AT
    # ALL proves the wake-up — no wall-clock elapsed assertion needed
    b = MicroBatcher(max_batch=4, deadline_s=60.0)
    waiting = threading.Event()

    def feed():
        waiting.wait(5.0)
        b.submit(np.arange(4, dtype=np.uint64) + 1)

    t = threading.Thread(target=feed)
    t.start()
    waiting.set()
    assert b.wait_ready(timeout=30.0)      # size trigger, not the deadline
    t.join()


def test_batcher_rejects_empty():
    b = MicroBatcher(max_batch=4, deadline_s=1.0)
    with pytest.raises(ValueError):
        b.submit(np.array([], np.uint64))


def test_batcher_per_client_pending_cap():
    b = MicroBatcher(max_batch=10_000, deadline_s=60.0, max_client_keys=100)
    b.submit(np.arange(60, dtype=np.uint64) + 1, client="a")
    b.submit(np.arange(60, dtype=np.uint64) + 1, client="b")   # independent
    with pytest.raises(ClientBacklogFull):
        b.submit(np.arange(50, dtype=np.uint64) + 1, client="a")
    assert b.pending_keys_of("a") == 60
    # anonymous submits are never capped (strict-FIFO default unchanged)
    b.submit(np.arange(500, dtype=np.uint64) + 1)
    assert b.pending_requests == 3
    # a flush returns the budget
    assert len(b.take(force=True)) == 3
    assert b.pending_keys_of("a") == 0
    b.submit(np.arange(100, dtype=np.uint64) + 1, client="a")  # fits again


def test_batcher_cap_disabled_by_default():
    b = MicroBatcher(max_batch=16, deadline_s=60.0)
    for _ in range(5):
        b.submit(np.arange(64, dtype=np.uint64) + 1, client="hog")
    assert b.pending_requests == 5


def test_batcher_token_bucket_rejects_over_burst():
    b = MicroBatcher(max_batch=10_000, deadline_s=60.0,
                     client_rate=(1.0, 100))   # ~no refill within the test
    b.submit(np.arange(90, dtype=np.uint64) + 1, client="a")
    with pytest.raises(ClientBacklogFull):
        b.submit(np.arange(50, dtype=np.uint64) + 1, client="a")
    # other clients and anonymous submits are unaffected
    b.submit(np.arange(90, dtype=np.uint64) + 1, client="b")
    b.submit(np.arange(500, dtype=np.uint64) + 1)
    assert b.pending_requests == 3
    # a flush does NOT return tokens (rate limits sustained keys/s, not
    # backlog); the client stays limited until the bucket refills
    b.take(force=True)
    with pytest.raises(ClientBacklogFull):
        b.submit(np.arange(50, dtype=np.uint64) + 1, client="a")


def test_batcher_token_bucket_refills_at_rate():
    b = MicroBatcher(max_batch=10_000, deadline_s=60.0,
                     client_rate=(10_000.0, 64))
    b.submit(np.arange(64, dtype=np.uint64) + 1, client="a")  # bucket empty
    # retry until the refill admits the burst (at 10k tokens/s this is
    # ~6.4ms away); the deadline only bounds a bucket that never refills
    deadline = time.perf_counter() + 30.0
    while True:
        try:
            b.submit(np.arange(64, dtype=np.uint64) + 1, client="a")
            break
        except ClientBacklogFull:
            assert time.perf_counter() < deadline, "bucket never refilled"
            time.sleep(0.001)
    assert b.pending_requests == 2


def test_batcher_token_bucket_validates_config():
    with pytest.raises(ValueError):
        MicroBatcher(max_batch=4, deadline_s=1.0, client_rate=(0.0, 10))
    with pytest.raises(ValueError):
        MicroBatcher(max_batch=4, deadline_s=1.0, client_rate=(5.0, 0))


def test_batcher_cap_rejection_burns_no_tokens():
    """A backlog-cap rejection must not consume rate-limit tokens."""
    b = MicroBatcher(max_batch=10_000, deadline_s=60.0,
                     max_client_keys=50, client_rate=(1.0, 1000))
    with pytest.raises(ClientBacklogFull):
        b.submit(np.arange(60, dtype=np.uint64) + 1, client="a")  # over cap
    # the full burst is still available for an in-cap submit
    b.submit(np.arange(50, dtype=np.uint64) + 1, client="a")
    assert b.pending_requests == 1


# ---------------------------------------------------------------------------
# service: FIFO completion, deadline flush, verification vs core
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def amzn_service():
    keys = sosd.generate("amzn", 50_000, seed=3)
    svc = LookupService(keys, LookupServiceConfig(
        index="rmi", hyper=dict(branching=1024),
        max_batch=512, deadline_ms=5.0))
    yield keys, svc
    svc.stop()


def test_service_fifo_completion_per_client(amzn_service):
    keys, svc = amzn_service
    q = sosd.make_queries(keys, 6_400, seed=5)
    per_client = {}
    lock = threading.Lock()

    def client(cid):
        rng = np.random.default_rng(cid)
        futs = []
        for i in range(20):
            m = int(rng.integers(8, 120))
            futs.append(svc.submit(q[(cid * 20 + i) * 8:][:m]))
        with lock:
            per_client[cid] = futs

    with svc:
        ts = [threading.Thread(target=client, args=(c,)) for c in range(3)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        for futs in per_client.values():
            for i, f in enumerate(futs):
                f.result(timeout=30.0)
                # when future i is done, every earlier future of the same
                # client is done: single flusher, admission-order take()
                assert all(g.done() for g in futs[:i])


def test_service_deadline_flush_completes_small_request(amzn_service):
    # 7 keys << max_batch=512: ONLY the deadline trigger can flush this,
    # so `result` returning (inside any generous timeout) is the whole
    # assertion — a wall-clock elapsed bound would just re-measure
    # scheduler noise
    keys, svc = amzn_service
    with svc:
        pos = svc.submit(keys[:7]).result(timeout=30.0)   # 7 keys << 512
    np.testing.assert_array_equal(pos, np.arange(7))


def test_service_results_bit_identical_vs_core_all_datasets(datasets, queries):
    import jax.numpy as jnp

    for name, keys in datasets.items():
        q = queries[name]
        svc = LookupService(keys, LookupServiceConfig(
            index="rmi", hyper=dict(branching=512),
            max_batch=2048, deadline_ms=1.0))
        futs = [svc.submit(q[i:i + 977]) for i in range(0, len(q), 977)]
        svc.drain()
        got = np.concatenate([f.result(timeout=30.0) for f in futs])
        direct = np.asarray(search.fused_lookup_fn(
            svc.generation.build, jnp.asarray(keys))(jnp.asarray(q)),
            dtype=np.int64)
        np.testing.assert_array_equal(got, direct, err_msg=name)
        # and the fused pipeline itself is exact vs the host oracle
        np.testing.assert_array_equal(
            direct, base.lower_bound_oracle(keys, q), err_msg=name)


def test_sharded_dispatch_multi_device_bit_identical(tmp_path):
    """Force 4 host devices in a subprocess (XLA locks the device count at
    first init): the 4-way sharded dispatch must equal the 1-device fused
    lookup bit-for-bit."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.core import search
from repro.data import sosd
from repro.serve.lookup import LookupService, LookupServiceConfig

assert len(jax.devices()) == 4
keys = sosd.generate("osm", 20_000, seed=3)
q = sosd.make_queries(keys, 4_000, seed=4)
svc = LookupService(keys, LookupServiceConfig(
    index="pgm", hyper=dict(eps=64), max_batch=1024, deadline_ms=1.0))
assert svc.dispatcher.n_shards == 4
futs = [svc.submit(q[i:i+333]) for i in range(0, len(q), 333)]
svc.drain()
got = np.concatenate([f.result(10.0) for f in futs])
direct = np.asarray(search.fused_lookup_fn(
    svc.generation.build, jnp.asarray(keys))(jnp.asarray(q)), np.int64)
assert np.array_equal(got, direct), "sharded != single-device"
print("SHARDED_OK")
"""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "SHARDED_OK" in out.stdout, out.stderr


def test_dispatcher_padded_size_buckets():
    d = ShardedDispatcher()            # 1 device on the test container
    assert d.padded_size(1) == d.pad_quantum
    assert d.padded_size(128) == 128
    assert d.padded_size(129) == 256
    for m in (1, 7, 511, 513, 4096):
        p = d.padded_size(m)
        assert p >= m and p % d.n_shards == 0


# ---------------------------------------------------------------------------
# hot swap
# ---------------------------------------------------------------------------
def test_registry_swap_is_atomic_never_half_built():
    keys_old = sosd.generate("amzn", 10_000, seed=1)
    keys_new = sosd.generate("wiki", 10_000, seed=2)
    reg = IndexRegistry()
    g0 = reg.build_and_publish("rmi", keys_old, hyper=dict(branching=256))

    in_build = threading.Event()
    release = threading.Event()

    @base.register("_test_slow_rmi")
    def slow_build(keys, **hyper):           # noqa: ANN001
        in_build.set()
        assert release.wait(10.0)            # hold the build "half done"
        return base.REGISTRY["rmi"](keys, **hyper)

    # builds go through the spec entry point now: the injected index
    # needs a schema too (rmi's fields fit — the slow build delegates)
    spec.register_schema("_test_slow_rmi",
                         fields=spec.SCHEMAS["rmi"].fields, ladder=[dict()])
    try:
        t = threading.Thread(target=reg.build_and_publish, args=(
            "_test_slow_rmi", keys_new), kwargs=dict(hyper=dict(branching=256)))
        t.start()
        assert in_build.wait(10.0)
        # mid-build: readers still get the OLD complete generation
        cur = reg.current()
        assert cur.version == g0.version
        q = sosd.make_queries(keys_old, 200, seed=3)
        np.testing.assert_array_equal(
            np.asarray(cur.fn(np.asarray(q)), np.int64),
            base.lower_bound_oracle(keys_old, q))
        release.set()
        t.join(timeout=30.0)
        assert reg.current().version > g0.version
        assert reg.current().n_keys == len(keys_new)
    finally:
        release.set()
        base.REGISTRY.pop("_test_slow_rmi", None)
        spec.SCHEMAS.pop("_test_slow_rmi", None)


def test_service_hot_swap_under_load():
    keys_old = sosd.generate("face", 30_000, seed=1)
    keys_new = sosd.generate("osm", 30_000, seed=2)
    svc = LookupService(keys_old, LookupServiceConfig(
        index="radix_spline", hyper=dict(eps=32, radix_bits=12),
        max_batch=256, deadline_ms=1.0))
    oracles = {0: (keys_old, base.lower_bound_oracle),
               1: (keys_new, base.lower_bound_oracle)}
    bad = []

    midstream = threading.Event()   # client is provably mid-stream here

    def client():
        rng = np.random.default_rng(0)
        for i in range(60):
            q = rng.integers(1, 1 << 62, size=32, dtype=np.uint64)
            v_before = svc.generation.version
            pos = svc.submit(q).result(timeout=30.0)
            v_after = svc.generation.version
            ok = any(np.array_equal(pos, fn(k, q))
                     for v, (k, fn) in oracles.items()
                     if v_before <= v <= v_after)
            if not ok:
                bad.append(i)
            if i == 20:
                midstream.set()

    with svc:
        t = threading.Thread(target=client)
        t.start()
        # event handshake, not a sleep: the swap lands after request 20
        # completed and before request 60 — mid-stream BY CONSTRUCTION
        assert midstream.wait(timeout=60.0)
        svc.swap_keys(keys_new)        # no drain, mid-stream
        t.join(timeout=60.0)
    assert not t.is_alive()
    assert not bad
    assert svc.generation.version == 1


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_latency_histogram_quantiles_bracket():
    h = LatencyHistogram()
    for ms in (1, 1, 1, 1, 1, 1, 1, 1, 1, 100):
        h.record(ms / 1e3)
    assert h.n == 10
    assert 0.8e-3 < h.quantile(0.5) < 1.3e-3
    assert 80e-3 < h.quantile(0.99) < 130e-3
    assert abs(h.mean - (9 * 1e-3 + 100e-3) / 10) < 2e-3


def test_service_metrics_occupancy_and_counts():
    m = ServiceMetrics()
    m.observe_batch(n_keys=100, padded=128, n_requests=4,
                    t_oldest_submit=0.0, t_start=0.001, t_end=0.002)
    m.observe_batch(n_keys=128, padded=128, n_requests=2,
                    t_oldest_submit=0.002, t_start=0.003, t_end=0.004)
    s = m.snapshot()
    assert s["batches"] == 2 and s["requests"] == 6 and s["lookups"] == 228
    assert abs(s["mean_occupancy"] - (100 / 128 + 1.0) / 2) < 1e-9
    assert s["lookups_per_s"] == pytest.approx(228 / 0.003)


def test_latency_histogram_boundary_buckets():
    h = LatencyHistogram()
    h.record(0.0)                       # below the lowest bound -> bucket 0
    assert h.counts[0] == 1
    h.record(1e9)                       # beyond the top -> overflow bucket
    assert h.counts[-1] == 1
    assert h.quantile(1.0) == float("inf")
    assert h.n == 2
    # empty histogram is all zeros
    empty = LatencyHistogram()
    assert empty.quantile(0.5) == 0.0 and empty.mean == 0.0


def test_latency_histogram_bucket_resolution_and_monotonicity():
    h = LatencyHistogram()
    rng = np.random.default_rng(0)
    vals = 10 ** rng.uniform(-5, 0, size=2_000)    # 10us..1s, log-uniform
    for v in vals:
        h.record(v)
    assert h.n == 2_000
    assert h.mean == pytest.approx(vals.mean(), rel=1e-9)
    qs = [h.quantile(q) for q in (0.1, 0.5, 0.9, 0.99)]
    assert qs == sorted(qs)                        # quantiles are monotone
    # each bucketed quantile brackets the exact one within the 5% growth
    for q, got in zip((0.1, 0.5, 0.9, 0.99), qs):
        exact = np.quantile(vals, q)
        assert exact * 0.9 <= got <= exact * 1.2


def test_service_metrics_write_side_snapshot():
    m = ServiceMetrics()
    m.observe_insert_batch(n_keys=64, admitted=50, t_start=0.0, t_end=0.004)
    m.observe_insert_batch(n_keys=16, admitted=0, t_start=0.005, t_end=0.006)
    m.set_delta_gauge(delta_keys=50, threshold=200)
    s = m.snapshot()
    assert s["insert_batches"] == 2 and s["insert_keys"] == 80
    assert s["admitted"] == 50
    assert s["mean_insert_ms"] == pytest.approx(2.5, rel=0.1)
    assert s["delta_keys"] == 50
    assert s["delta_occupancy"] == pytest.approx(0.25)
    m.observe_compaction(duration_s=0.5)
    m.set_delta_gauge(delta_keys=0, threshold=200)  # the single gauge writer
    s = m.snapshot()
    assert s["compactions"] == 1
    assert s["compaction_failures"] == 0
    assert s["delta_keys"] == 0
    assert 400 < s["mean_compaction_ms"] < 700
    assert 400 < s["p99_compaction_ms"]
    m.observe_compaction_failure()
    assert m.snapshot()["compaction_failures"] == 1


def test_registry_swap_racing_concurrent_publishes():
    """N writers hammer build_and_publish while readers continuously
    verify whatever generation they observe against its own key set —
    a torn or half-built publish would return wrong positions."""
    key_sets = {s: sosd.generate("amzn", 3_000, seed=s) for s in range(3)}
    reg = IndexRegistry()
    g0 = reg.build_and_publish("rmi", key_sets[0], hyper=dict(branching=128))
    stop = threading.Event()
    errors = []
    published = []                      # (GIL-atomic appends)

    def reader():
        while not stop.is_set():
            gen = reg.current()
            keys = np.asarray(gen.data, dtype=np.uint64)
            q = keys[:: max(1, len(keys) // 64)]
            pos = np.asarray(gen.fn(np.asarray(q)), np.int64)
            if not np.array_equal(pos, base.lower_bound_oracle(keys, q)):
                errors.append(gen.version)

    def writer(seed):
        for _ in range(4):
            published.append(reg.build_and_publish(
                "rmi", key_sets[seed], hyper=dict(branching=128)).version)

    readers = [threading.Thread(target=reader) for _ in range(2)]
    writers = [threading.Thread(target=writer, args=(s,)) for s in key_sets]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join(timeout=120.0)
    stop.set()
    for t in readers:
        t.join(timeout=30.0)
    assert not errors                   # every observed generation consistent
    assert len(published) == 12
    assert len(set(published)) == 12    # version ids never reused
    assert reg.current().version in set(published)   # last writer won
    assert reg.current().version != g0.version
    assert reg.current().n_keys == 3_000


# ---------------------------------------------------------------------------
# real-SOSD loader (env-gated, checksum-verified)
# ---------------------------------------------------------------------------
def _write_sosd_binary(path, keys):
    with open(path, "wb") as f:
        np.asarray([len(keys)], dtype="<u8").tofile(f)
        np.asarray(keys, dtype="<u8").tofile(f)


def test_load_real_subsamples_and_sorts(tmp_path):
    rng = np.random.default_rng(0)
    raw = np.unique(rng.integers(1, 1 << 60, size=5_000, dtype=np.uint64))
    _write_sosd_binary(tmp_path / sosd.SOSD_SOURCES["wiki"], raw)
    got = sosd.load_real("wiki", 1_000, str(tmp_path))
    assert len(got) == 1_000 and got.dtype == np.uint64
    assert (np.diff(got.astype(np.float64)) > 0).all()
    assert np.isin(got, raw).all()
    # endpoints-ish preserved: rank-based subsample starts at the minimum
    assert got[0] == raw[0]


def test_generate_uses_real_when_env_set(tmp_path, monkeypatch):
    raw = np.arange(1, 4_001, dtype=np.uint64) * 7
    _write_sosd_binary(tmp_path / sosd.SOSD_SOURCES["amzn"], raw)
    monkeypatch.setenv("REPRO_SOSD_DIR", str(tmp_path))
    got = sosd.generate("amzn", 2_000, seed=9)
    assert np.isin(got, raw).all()       # real keys, not the surrogate


def test_generate_falls_back_when_file_missing(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SOSD_DIR", str(tmp_path))   # empty dir
    with pytest.warns(UserWarning, match="surrogate"):
        got = sosd.generate("face", 5_000, seed=5)
    np.testing.assert_array_equal(got, sosd.gen_face(5_000, seed=5))


def test_load_real_checksum_sidecar(tmp_path):
    import hashlib

    raw = np.arange(1, 3_001, dtype=np.uint64) * 3
    path = tmp_path / sosd.SOSD_SOURCES["osm"]
    _write_sosd_binary(path, raw)
    digest = hashlib.sha256(path.read_bytes()).hexdigest()
    sidecar = tmp_path / (sosd.SOSD_SOURCES["osm"] + ".sha256")

    sidecar.write_text(f"{digest}  {sosd.SOSD_SOURCES['osm']}\n")
    got = sosd.load_real("osm", 500, str(tmp_path))       # verifies, loads
    assert len(got) == 500

    sidecar.write_text("0" * 64 + f"  {sosd.SOSD_SOURCES['osm']}\n")
    with pytest.raises(ValueError, match="checksum mismatch"):
        sosd.load_real("osm", 500, str(tmp_path))

    sidecar.write_text("")                 # truncated sidecar: diagnosable
    with pytest.raises(ValueError, match="malformed sha256 sidecar"):
        sosd.load_real("osm", 500, str(tmp_path))


def test_load_real_truncated_file_raises(tmp_path):
    path = tmp_path / sosd.SOSD_SOURCES["face"]
    with open(path, "wb") as f:
        np.asarray([1000], dtype="<u8").tofile(f)         # promises 1000
        np.arange(10, dtype="<u8").tofile(f)              # holds 10
    with pytest.raises(ValueError, match="header promises"):
        sosd.load_real("face", 5, str(tmp_path))


# ---------------------------------------------------------------------------
# online fetch (downloader is env-gated; these tests never touch the net)
# ---------------------------------------------------------------------------
def _fake_urlopen_for(payload: bytes, seen_urls):
    import io

    class _Resp(io.BytesIO):
        def __enter__(self):
            return self

        def __exit__(self, *exc):
            return False

    def fake(url, *a, **k):
        seen_urls.append(url)
        return _Resp(payload)

    return fake


def test_fetch_real_downloads_decompresses_and_writes_sidecar(
        tmp_path, monkeypatch):
    import shutil

    raw = np.arange(1, 2_001, dtype=np.uint64) * 5
    payload = (np.asarray([len(raw)], dtype="<u8").tobytes()
               + raw.astype("<u8").tobytes())
    urls = []
    monkeypatch.setattr("urllib.request.urlopen",
                        _fake_urlopen_for(payload, urls))
    # stand-in decompressor: the "zst" payload is already the raw binary
    monkeypatch.setattr(sosd, "_decompress_zstd",
                        lambda src, dst: shutil.copyfile(src, dst))

    path = sosd.fetch_real("wiki", str(tmp_path))
    assert urls == [sosd.SOSD_URL_BASE + sosd.SOSD_SOURCES["wiki"] + ".zst"]
    assert os.path.exists(path + ".sha256")       # sidecar written
    assert not os.path.exists(path + ".zst.part") # temp files cleaned
    got = sosd.load_real("wiki", 500, str(tmp_path))  # checksum-verified load
    assert np.isin(got, raw).all()

    # a present file short-circuits: no second download
    monkeypatch.setattr("urllib.request.urlopen",
                        lambda *a, **k: pytest.fail("re-downloaded"))
    assert sosd.fetch_real("wiki", str(tmp_path)) == path


def test_fetch_real_honors_url_override(tmp_path, monkeypatch):
    import shutil

    raw = np.arange(1, 1_001, dtype=np.uint64) * 3
    payload = (np.asarray([len(raw)], dtype="<u8").tobytes()
               + raw.astype("<u8").tobytes())
    urls = []
    monkeypatch.setattr("urllib.request.urlopen",
                        _fake_urlopen_for(payload, urls))
    monkeypatch.setattr(sosd, "_decompress_zstd",
                        lambda src, dst: shutil.copyfile(src, dst))
    monkeypatch.setenv("REPRO_SOSD_URL", "https://mirror.example/sosd/")
    sosd.fetch_real("osm", str(tmp_path))
    assert urls[0].startswith("https://mirror.example/sosd/")


def test_generate_fetch_is_env_gated(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SOSD_DIR", str(tmp_path))   # empty dir
    monkeypatch.delenv("REPRO_SOSD_FETCH", raising=False)
    monkeypatch.setattr(sosd, "fetch_real",
                        lambda *a, **k: pytest.fail("fetched without opt-in"))
    with pytest.warns(UserWarning, match="surrogate"):
        got = sosd.generate("face", 4_000, seed=2)        # CI path: no net
    np.testing.assert_array_equal(got, sosd.gen_face(4_000, seed=2))


def test_generate_fetches_when_opted_in(tmp_path, monkeypatch):
    raw = np.arange(1, 5_001, dtype=np.uint64) * 7

    def fake_fetch(name, dest_dir, **k):
        path = os.path.join(dest_dir, sosd.SOSD_SOURCES[name])
        _write_sosd_binary(path, raw)
        return path

    monkeypatch.setenv("REPRO_SOSD_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_SOSD_FETCH", "1")
    monkeypatch.setattr(sosd, "fetch_real", fake_fetch)
    got = sosd.generate("amzn", 2_000, seed=3)
    assert np.isin(got, raw).all()                        # real keys served


def test_generate_fetch_failure_falls_back(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SOSD_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_SOSD_FETCH", "1")

    def failing_fetch(*a, **k):
        raise OSError("network unreachable")

    monkeypatch.setattr(sosd, "fetch_real", failing_fetch)
    with pytest.warns(UserWarning, match="fetch .* failed"):
        got = sosd.generate("wiki", 3_000, seed=4)
    np.testing.assert_array_equal(got, sosd.gen_wiki(3_000, seed=4))


def test_decompress_zstd_without_backend_raises(tmp_path, monkeypatch):
    monkeypatch.setitem(sys.modules, "zstandard", None)   # import -> ImportError
    monkeypatch.setattr(sosd.shutil, "which", lambda _: None)
    src = tmp_path / "x.zst"
    src.write_bytes(b"\x28\xb5\x2f\xfd")
    with pytest.raises(RuntimeError, match="no zstd decompressor"):
        sosd._decompress_zstd(str(src), str(tmp_path / "x"))
