"""Range-routed shard mesh tests (DESIGN.md §16): topology routing
algebra, routed-vs-broadcast bit-parity across the index x executor
matrix, split-point/absent-key edge cases, single-shard degeneration,
boundary-crossing scans, replica rebalance, per-shard observability,
and the pinned host staging contract."""
import numpy as np
import pytest

from repro.core import base
from repro.core.spec import IndexSpec, Tuner
from repro.serve.lookup import (LookupService, LookupServiceConfig,
                                MutableLookupService,
                                MutableLookupServiceConfig, ShardTopology)


def _oracle(keys, q):
    return base.lower_bound_oracle(keys, q)


# ---------------------------------------------------------------------------
# topology value object: routing algebra (no service, no jit)
# ---------------------------------------------------------------------------
def test_route_split_points_side_left():
    # split_points[s] IS shard s's last key: a query equal to it must
    # route to shard s (side='left'), the next key up to shard s+1
    keys = np.arange(0, 1000, 2, dtype=np.uint64)  # evens, gaps of 1
    topo = ShardTopology.from_keys(keys, 4)
    for s, split in enumerate(topo.split_points):
        assert topo.route(np.array([split], dtype=np.uint64))[0] == s
        assert topo.route(np.array([split + 1], dtype=np.uint64))[0] == s + 1
        # the split key itself lives at the end of shard s's slice
        lo, hi = topo.offsets[s], topo.offsets[s + 1]
        assert keys[hi - 1] == split


def test_route_extremes():
    keys = (np.arange(100, dtype=np.uint64) + 50) * 10
    topo = ShardTopology.from_keys(keys, 5)
    q = np.array([0, keys[0] - 1, keys[-1] + 1, 2**64 - 1], dtype=np.uint64)
    sid = topo.route(q)
    assert sid[0] == 0 and sid[1] == 0            # below global min
    assert sid[2] == topo.n_shards - 1            # above global max
    assert sid[3] == topo.n_shards - 1


def test_duplicates_never_straddle_a_split():
    # 50 distinct values x 40 duplicates each: every boundary must sit
    # at the FIRST occurrence of its key, so no duplicate run straddles
    rng = np.random.default_rng(3)
    vals = np.sort(rng.choice(10_000, size=50, replace=False))
    keys = np.sort(np.repeat(vals, 40).astype(np.uint64))
    topo = ShardTopology.from_keys(keys, 8)
    for s in range(1, topo.n_shards):
        o = topo.offsets[s]
        assert keys[o - 1] != keys[o]
    # and routed ranks stay globally exact on the duplicated values
    q = keys[rng.integers(0, keys.size, 500)]
    sid = topo.route(q)
    pos = np.empty(q.size, dtype=np.int64)
    for s in range(topo.n_shards):
        m = sid == s
        lo, hi = topo.offsets[s], topo.offsets[s + 1]
        pos[m] = lo + np.searchsorted(keys[lo:hi], q[m], side="left")
    assert np.array_equal(pos, _oracle(keys, q))


def test_route_device_matches_host_on_boundaries():
    keys = np.sort(np.random.default_rng(5).choice(
        2**40, size=4096, replace=False).astype(np.uint64))
    topo = ShardTopology.from_keys(keys, 6)
    q = np.concatenate([topo.split_points,
                        topo.split_points - 1,
                        topo.split_points + 1,
                        np.array([0, 2**63], dtype=np.uint64)])
    import jax.numpy as jnp

    dev = np.asarray(topo.route_device(jnp.asarray(q)), dtype=np.int64)
    assert np.array_equal(dev, topo.route(q))


def test_single_topology_routes_everything_to_shard_zero():
    topo = ShardTopology.single(1000)
    assert topo.n_shards == 1
    q = np.array([0, 7, 2**63], dtype=np.uint64)
    assert np.array_equal(topo.route(q), np.zeros(3, dtype=np.int64))


def test_from_keys_collapses_on_constant_array():
    keys = np.full(5000, 42, dtype=np.uint64)
    topo = ShardTopology.from_keys(keys, 8)
    assert topo.n_shards == 1                     # every split collapsed
    assert topo.offsets == (0, 5000)


def test_replica_apportionment_largest_remainder():
    keys = np.arange(4000, dtype=np.uint64)
    topo = ShardTopology.from_keys(keys, 4)
    hot = topo.rebalanced_from_masses([97.0, 1.0, 1.0, 1.0],
                                      total_replicas=8)
    assert sum(hot.replicas) == 8
    assert min(hot.replicas) >= 1                 # floor of one seat
    assert hot.replicas[0] == max(hot.replicas)   # hottest shard wins
    # split points and offsets are untouched: routes stay valid
    assert np.array_equal(hot.split_points, topo.split_points)
    assert hot.offsets == topo.offsets


def test_rebalanced_from_traffic_histogram():
    keys = np.arange(8000, dtype=np.uint64)
    topo = ShardTopology.from_keys(keys, 4)
    flat = topo.rebalanced(np.ones(32), total_replicas=8)
    assert flat.replicas == (2, 2, 2, 2)          # uniform -> even seats
    hist = np.zeros(32)
    hist[:8] = 100.0                              # all mass on shard 0
    skew = topo.rebalanced(hist, total_replicas=8)
    assert skew.replicas[0] == max(skew.replicas) >= 4


# ---------------------------------------------------------------------------
# service parity matrix: routed == broadcast == oracle, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("index", ["rmi", "pgm", "radix_spline"])
@pytest.mark.parametrize("executor", ["sync", "async"])
def test_routed_parity_matrix(datasets, queries, index, executor):
    keys = datasets["amzn"]
    q = queries["amzn"][:2000]
    sp = IndexSpec(index, {})
    bcast = LookupService(keys, LookupServiceConfig(
        spec=sp, max_batch=1024, deadline_ms=0.0, executor=executor))
    routed = LookupService(keys, LookupServiceConfig(
        spec=sp, max_batch=1024, deadline_ms=0.0, executor=executor,
        shards=4))
    try:
        got_b = bcast.lookup(q)
        got_r = routed.lookup(q)
        assert routed.dispatcher.n_shards == 4
        assert np.array_equal(got_r, got_b)
        assert np.array_equal(got_r, _oracle(keys, q))
    finally:
        bcast.stop()
        routed.stop()


def test_routed_parity_pallas_backend(datasets, queries):
    keys = datasets["amzn"]
    q = queries["amzn"][:1000]
    svc = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("rmi", {}, backend="pallas"),
        max_batch=1024, deadline_ms=0.0, shards=2))
    try:
        assert np.array_equal(svc.lookup(q), _oracle(keys, q))
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# shared routed service for the edge-case / observability block
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def routed_svc(datasets):
    svc = LookupService(datasets["amzn"], LookupServiceConfig(
        spec=IndexSpec("rmi", {}), max_batch=2048, deadline_ms=0.0,
        executor="sync", shards=4))
    yield svc
    svc.stop()


def test_queries_exactly_on_split_points(datasets, routed_svc):
    keys = datasets["amzn"]
    splits = routed_svc.generation.topology.split_points
    q = np.concatenate([splits, splits - 1, splits + 1]).astype(np.uint64)
    assert np.array_equal(routed_svc.lookup(q), _oracle(keys, q))


def test_absent_keys_outside_global_range(datasets, routed_svc):
    keys = datasets["amzn"]
    below = np.array([0, keys[0] - 1], dtype=np.uint64)
    above = np.array([keys[-1] + 1, 2**64 - 1], dtype=np.uint64)
    assert np.array_equal(routed_svc.lookup(below),
                          np.zeros(2, dtype=np.int64))
    assert np.array_equal(routed_svc.lookup(above),
                          np.full(2, keys.size, dtype=np.int64))


def test_batch_entirely_in_one_shard(datasets, routed_svc):
    keys = datasets["amzn"]
    topo = routed_svc.generation.topology
    lo, hi = topo.offsets[2], topo.offsets[3]
    rng = np.random.default_rng(9)
    q = keys[rng.integers(lo, hi, 512)]           # all owned by shard 2
    assert np.array_equal(topo.route(q), np.full(512, 2, dtype=np.int64))
    before = {r["shard"]: r["keys"] for r in routed_svc.metrics.per_shard()}
    assert np.array_equal(routed_svc.lookup(q), _oracle(keys, q))
    after = {r["shard"]: r["keys"] for r in routed_svc.metrics.per_shard()}
    for s in range(4):
        grew = after.get(s, 0) - before.get(s, 0)
        assert grew >= 512 if s == 2 else grew == 0


def test_single_shard_topology_degenerates_bit_exactly(datasets, queries):
    # an EXPLICIT one-shard topology forces the routed machinery
    # (scatter/gather, per-shard health) yet must be bit-identical to
    # plain broadcast dispatch
    keys = datasets["amzn"]
    q = queries["amzn"][:1500]
    bcast = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("rmi", {}), max_batch=1024, deadline_ms=0.0))
    one = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("rmi", {}), max_batch=1024, deadline_ms=0.0,
        topology=ShardTopology.single(keys.size)))
    try:
        got_b, got_1 = bcast.lookup(q), one.lookup(q)
        assert np.array_equal(got_1, got_b)
        assert one.metrics.snapshot()["routed_batches"] >= 1   # routed path
        assert bcast.metrics.snapshot()["routed_batches"] == 0
    finally:
        bcast.stop()
        one.stop()


def test_scan_windows_cross_shard_boundaries(datasets, routed_svc):
    # scan windows anchored just below each split must borrow the head
    # of the NEXT shard's range — routed windows == broadcast windows
    keys = datasets["amzn"]
    topo = routed_svc.generation.topology
    anchors = np.array([keys[o - 3] for o in topo.offsets[1:-1]]
                       + [keys[10], keys[-2]], dtype=np.uint64)
    bcast = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("rmi", {}), max_batch=1024, deadline_ms=0.0))
    try:
        fr = routed_svc.scan(anchors, 64)
        routed_svc.drain()
        fb = bcast.scan(anchors, 64)
        bcast.drain()
        pos_r, win_r = fr.result(timeout=30.0)
        pos_b, win_b = fb.result(timeout=30.0)
        assert np.array_equal(pos_r, pos_b)
        assert np.array_equal(win_r, win_b)
    finally:
        bcast.stop()


def test_hot_swap_routed_generation(datasets):
    keys = datasets["amzn"]
    svc = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("pgm", {}), max_batch=1024, deadline_ms=0.0,
        shards=3))
    try:
        fresh = np.sort(np.random.default_rng(21).choice(
            2**48, size=30_000, replace=False).astype(np.uint64))
        old_ver = svc.generation.version
        svc.swap_keys(fresh)
        assert svc.generation.version > old_ver
        assert svc.generation.topology.n_keys == fresh.size
        q = np.concatenate([fresh[::100], fresh[:5] + 1]).astype(np.uint64)
        assert np.array_equal(svc.lookup(q), _oracle(fresh, q))
    finally:
        svc.stop()


def test_replica_fanout_and_rebalance(datasets, queries):
    keys = datasets["amzn"]
    q = queries["amzn"][:1500]
    svc = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("rmi", {}), max_batch=1024, deadline_ms=0.0,
        shards=2, replicas=2))
    try:
        assert svc.generation.topology.replicas == (2, 2)
        assert np.array_equal(svc.lookup(q), _oracle(keys, q))
        reps = svc.rebalance_replicas(total_replicas=6, window_s=60.0)
        assert sum(reps) == 6 and min(reps) >= 1
        # routes and results survive the fan-out change
        assert np.array_equal(svc.lookup(q), _oracle(keys, q))
    finally:
        svc.stop()


def test_per_shard_tuned_specs(datasets, queries):
    keys = datasets["amzn"]
    q = queries["amzn"][:1000]
    svc = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("rmi", {}), shards=2, max_batch=1024,
        deadline_ms=0.0,
        shard_tuner=Tuner(names=("rmi", "pgm"), max_configs=4)))
    try:
        specs = [g.spec for g in svc.generation.shards]
        assert all(sp is not None for sp in specs)
        assert np.array_equal(svc.lookup(q), _oracle(keys, q))
    finally:
        svc.stop()


def test_mutable_service_rejects_routed_topology(datasets):
    with pytest.raises(ValueError, match="routed"):
        MutableLookupService(datasets["amzn"],
                             MutableLookupServiceConfig(shards=4))


# ---------------------------------------------------------------------------
# per-shard observability + staging contract
# ---------------------------------------------------------------------------
def test_per_shard_metrics_health_and_prometheus(datasets, queries,
                                                 routed_svc):
    from repro.obs.export import MetricsServer, metrics_payload

    keys = datasets["amzn"]
    routed_svc.lookup(queries["amzn"][:2000])     # ensure traffic
    snap = routed_svc.metrics.snapshot()
    assert snap["routed_batches"] >= 1
    assert snap["route_shards"] == 4
    assert snap["route_skew"] >= 1.0
    rows = routed_svc.metrics.per_shard()
    assert {r["shard"] for r in rows} == set(range(4))
    assert all(r["keys"] > 0 for r in rows)
    # merged health snapshot spans the shard group
    h = routed_svc.health_snapshot(window_s=60.0)
    assert h["health_shards"] == 4.0
    # one health record per shard in the registry-facing view, and the
    # shard slices partition the key space exactly
    recs = routed_svc.registry.health_records(60.0)
    by_shard = {r["shard"]: r for r in recs if "shard" in r}
    assert set(by_shard) == set(range(4))
    assert sum(r["n_keys"] for r in by_shard.values()) == keys.size
    # exporter surfaces: /metrics.json per_shard + shard-labelled text
    payload = metrics_payload(routed_svc)
    assert {r["shard"] for r in payload["per_shard"]} == set(range(4))
    server = MetricsServer(routed_svc)
    try:
        text = server.render_prometheus()
        for s in range(4):
            assert f'repro_lookup_shard_keys{{shard="{s}"}}' in text
    finally:
        server._httpd.server_close()


def test_pinned_staging_reuse_steady_state(datasets, routed_svc):
    keys = datasets["amzn"]
    rng = np.random.default_rng(13)
    q = keys[rng.integers(0, keys.size, 300)]     # fixed odd size: padded
    routed_svc.lookup(q)                          # allocate the buckets
    allocs = routed_svc.dispatcher.staging_allocs
    hits = routed_svc.dispatcher.staging_hits
    for _ in range(5):
        routed_svc.lookup(q)
    assert routed_svc.dispatcher.staging_allocs == allocs   # no growth
    assert routed_svc.dispatcher.staging_hits > hits        # reuse


def test_staging_placement_never_aliases_the_buffer(datasets):
    # Regression for a live routed async parity failure: a placed batch
    # must be INDEPENDENT of the pinned staging buffer the moment
    # pad_and_place returns, because the very next batch of the same
    # bucket rewrites that buffer.  Two mechanisms break independence —
    # XLA's CPU zero-copy fast path aliases an owning 64-byte-aligned
    # numpy array outright (so the dispatcher keeps the buffer
    # deliberately misaligned), and the host->device copy is
    # asynchronous (so pad_and_place blocks on the placement).  Without
    # either guard, a whole sub-batch silently answers for the
    # FOLLOWING batch.
    from repro.serve.lookup.dispatch import ShardedDispatcher

    keys = datasets["amzn"]
    d = ShardedDispatcher()
    rng = np.random.default_rng(29)
    q = keys[rng.integers(0, keys.size, 300)]     # odd size: staging path
    qj, p = d.pad_and_place(q)
    assert p > q.size                             # staging buffer used
    assert d._staging[p].ctypes.data % 64 != 0    # zero-copy-proof
    assert qj.is_ready()                          # copy done at return
    # the overwrite-after-return contract: clobbering the staging buffer
    # must not be observable through the already-placed batch
    d._staging[p][:] = 0
    assert np.array_equal(np.asarray(qj)[:q.size], q)


def test_donated_query_buffer_parity(datasets, queries):
    # donation is a no-op on CPU (jax warns) but must never change bits
    keys = datasets["amzn"]
    q = queries["amzn"][:1000]
    svc = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("rmi", {}), max_batch=1024, deadline_ms=0.0,
        shards=2, donate_queries=True))
    try:
        assert np.array_equal(svc.lookup(q), _oracle(keys, q))
        assert np.array_equal(svc.lookup(q), _oracle(keys, q))  # reuse
    finally:
        svc.stop()
