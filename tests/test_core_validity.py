"""Paper-§2 validity: every index must bound LB(x) for EVERY integer query.

Property-based (hypothesis) over adversarial key distributions + the four
SOSD surrogates; end-to-end exactness through each last-mile search.
"""
import numpy as np
import pytest

try:  # optional dep: only the property-based test below needs it
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

from repro.core import base, validate
from repro.data import sosd

INDEX_CONFIGS = [
    ("rmi", dict(branching=64)),
    ("rmi", dict(branching=4096)),
    ("rmi", dict(branching=512, stage1="cubic")),
    ("pgm", dict(eps=16)),
    ("pgm", dict(eps=128)),
    ("radix_spline", dict(eps=16, radix_bits=12)),
    ("btree", dict(sample=8)),
    ("ibtree", dict(sample=8)),
    ("rbs", dict(radix_bits=10)),
    ("binary_search", dict()),
]


@pytest.mark.parametrize("name,hyper", INDEX_CONFIGS,
                         ids=[f"{n}-{i}" for i, (n, _) in enumerate(INDEX_CONFIGS)])
@pytest.mark.parametrize("ds", ["amzn", "face", "osm", "wiki"])
def test_bounds_valid_on_sosd(datasets, queries, ds, name, hyper):
    keys = datasets[ds]
    q = queries[ds]
    b = base.REGISTRY[name](keys, **hyper)
    r = validate.check_bounds(b, keys, q)
    assert r["valid"], (ds, name, hyper, r)


@pytest.mark.parametrize("name,hyper", INDEX_CONFIGS[:7],
                         ids=[f"{n}-{i}" for i, (n, _) in enumerate(INDEX_CONFIGS[:7])])
def test_end_to_end_exact(datasets, queries, name, hyper):
    keys = datasets["wiki"]
    q = queries["wiki"]
    b = base.REGISTRY[name](keys, **hyper)
    for lm in ("binary", "interpolation"):
        r = validate.check_end_to_end(b, keys, q, last_mile=lm)
        assert r["exact"], (name, lm, r)


if st is not None:
    @st.composite
    def key_arrays(draw):
        """Adversarial key sets: clusters, gaps, near-duplicates, outliers."""
        n = draw(st.integers(64, 512))
        style = draw(st.sampled_from(["uniform", "clustered", "outliers",
                                      "dense"]))
        rng = np.random.default_rng(draw(st.integers(0, 2**31)))
        if style == "uniform":
            raw = rng.integers(0, 2**62, n, dtype=np.uint64)
        elif style == "clustered":
            centers = rng.integers(0, 2**50, 5, dtype=np.uint64)
            raw = (centers[rng.integers(0, 5, n)]
                   + rng.integers(0, 1000, n).astype(np.uint64))
        elif style == "outliers":
            raw = rng.integers(0, 2**30, n, dtype=np.uint64)
            raw[: max(1, n // 100)] = rng.integers(
                2**60, 2**63, max(1, n // 100), dtype=np.uint64)
        else:
            raw = np.arange(n, dtype=np.uint64) * 2 + 10
        keys = np.unique(raw)
        return keys if len(keys) >= 16 else np.unique(
            np.arange(32, dtype=np.uint64) * 7)

    @pytest.mark.parametrize("name,hyper", [
        ("rmi", dict(branching=32)),
        ("pgm", dict(eps=8, top_cutoff=8)),
        ("radix_spline", dict(eps=8, radix_bits=8)),
        ("btree", dict(sample=4)),
        ("rbs", dict(radix_bits=6)),
    ])
    @settings(max_examples=25, deadline=None)
    @given(keys=key_arrays(), seed=st.integers(0, 2**31))
    def test_property_validity(name, hyper, keys, seed):
        rng = np.random.default_rng(seed)
        present = keys[rng.integers(0, len(keys), 64)]
        absent = rng.integers(0, 2**63, 64, dtype=np.uint64)
        edge = np.array([0, 1, keys[0], keys[-1],
                         np.uint64(2**64 - 1)], np.uint64)
        q = np.concatenate([present, absent, edge])
        b = base.REGISTRY[name](keys, **hyper)
        r = validate.check_bounds(b, keys, q)
        assert r["valid"], (name, r["n_bad"], r["bad_idx"])
        e = validate.check_end_to_end(b, keys, q)
        assert e["exact"], (name, e)
else:
    @pytest.mark.skip(reason="optional dep `hypothesis` not installed")
    def test_property_validity():
        pass


def test_binary_search_is_reference(datasets, queries):
    keys = datasets["amzn"]
    q = queries["amzn"]
    b = base.REGISTRY["binary_search"](keys)
    assert b.size_bytes == 0
    r = validate.check_end_to_end(b, keys, q)
    assert r["exact"]
