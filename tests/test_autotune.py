"""Self-driving autotune subsystem (DESIGN.md §17).

Covers the three layers plus the satellites that ride with them:

- `autotune.store` — dataset fingerprint, quantized workload signature,
  versioned spec-artifact persistence, lookup_or_tune short-circuit.
- `autotune.objective` — traffic-weighted probe streams, SLO-burn tail
  weighting, calibrated scoring; the §17 satellite pin that a measured
  ``cost_model_ratio`` corrects a 2x-miscalibrated proxy before it can
  flip the tuner's family choice.
- `autotune.retuner` — the trigger → tune → verify → margin → swap
  state machine end-to-end on real services (both executors), the
  budget-violation margin waiver, truthful rejections, and the mutable
  republish path.
- latency-class admission — per-class deadline budgets in
  `MicroBatcher` and the per-class latency rows in `ServiceMetrics`.
- surfaces — `/autotune.json`, `health_snapshot` autotune keys.
"""
import json
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.autotune import (AutotuneConfig, ShadowRetuner, SpecArtifactStore,
                            WorkloadObjective, dataset_fingerprint,
                            tail_weight_from_burn, workload_queries,
                            workload_signature)
from repro.core import analysis
from repro.core.spec import IndexSpec, Tuner
from repro.data import sosd
from repro.serve.lookup import (LookupService, LookupServiceConfig,
                                MicroBatcher, MutableLookupService,
                                MutableLookupServiceConfig, ServiceMetrics)


def _keys(n=60_000, seed=7):
    return sosd.generate("amzn", n, seed=seed)


# ---------------------------------------------------------------------------
# store: fingerprint, signature, versioned artifacts
# ---------------------------------------------------------------------------
def test_dataset_fingerprint_stable_and_content_sensitive():
    keys = _keys()
    assert dataset_fingerprint(keys) == dataset_fingerprint(keys.copy())
    bumped = keys.copy()
    bumped[-1] += 1
    assert dataset_fingerprint(bumped) != dataset_fingerprint(keys)
    assert dataset_fingerprint(keys[:-1]) != dataset_fingerprint(keys)


def test_workload_signature_quantizes_noise_splits_hot_spots():
    flat = np.full(64, 100.0)
    assert workload_signature(None) == "uniform"
    assert workload_signature(np.zeros(64)) == "uniform"
    assert workload_signature(flat) == "uniform"
    hot = flat.copy()
    hot[3] = 5_000.0
    assert workload_signature(hot) != "uniform"
    # the signature is deterministic and scale-invariant (normalized)
    assert workload_signature(hot) == workload_signature(hot * 7.0)


def test_store_round_trip_versions_and_stats(tmp_path):
    store = SpecArtifactStore(str(tmp_path))
    sp = IndexSpec("rmi", {"branching": 256}).validated()
    assert store.get("fp", 1024, "uniform") is None
    a1 = store.put("fp", 1024, "uniform", [sp], score=12.5,
                   meta={"trigger": "workload_drift"})
    assert a1.version == 1
    got = store.get("fp", 1024, "uniform")
    assert got is not None and got.version == 1
    assert got.specs[0].canonical() == sp.canonical()
    assert got.score == 12.5 and got.meta["trigger"] == "workload_drift"
    # versions append, never overwrite; get returns the newest
    sp2 = IndexSpec("rmi", {"branching": 1024}).validated()
    a2 = store.put("fp", 1024, "uniform", [sp2], score=9.0)
    assert a2.version == 2
    assert store.get("fp", 1024, "uniform").specs[0].canonical() == \
        sp2.canonical()
    # distinct budget or signature = distinct key
    assert store.get("fp", 2048, "uniform") is None
    assert store.get("fp", 1024, "h0123") is None
    assert store.stats() == {"hits": 2, "misses": 3}
    entry, = [e for e in store.entries()
              if e["key"] == store.key("fp", 1024, "uniform")]
    assert entry["n_versions"] == 2


def test_store_lookup_or_tune_runs_fn_once(tmp_path):
    store = SpecArtifactStore(str(tmp_path))
    sp = IndexSpec("btree", {"sample": 8}).validated()
    calls = []

    def tune_fn():
        calls.append(1)
        return [sp], 3.0, {"trigger": "t"}

    art, hit = store.lookup_or_tune("fp", None, "uniform", tune_fn)
    assert not hit and art.version == 1 and len(calls) == 1
    art2, hit2 = store.lookup_or_tune("fp", None, "uniform", tune_fn)
    assert hit2 and len(calls) == 1
    assert art2.specs[0].canonical() == sp.canonical()


# ---------------------------------------------------------------------------
# objective: workload-drawn probes, tail weighting, calibration
# ---------------------------------------------------------------------------
def test_workload_queries_follow_traffic_histogram():
    keys = _keys()
    hist = np.zeros(64)
    hist[0] = 1_000.0          # all live traffic in the bottom 1/64
    q = workload_queries(keys, hist, 4_096, seed=3, absent_frac=0.25)
    assert q.dtype == np.uint64 and len(q) == 4_096
    # the present-key draw (75%) must land in the hot bucket's rank range
    edge_key = keys[(len(keys) + 63) // 64]
    frac_hot = float(np.mean(q < edge_key))
    assert frac_hot > 0.6
    # uniform fallback spreads across the space
    q_flat = workload_queries(keys, None, 4_096, seed=3)
    assert float(np.mean(q_flat < edge_key)) < 0.1


def test_tail_weight_from_burn_clamps():
    assert tail_weight_from_burn(0.0) == 1.0
    assert tail_weight_from_burn(2.0) == 3.0
    assert tail_weight_from_burn(1e9) == 5.0
    assert tail_weight_from_burn(-3.0) == 1.0


def test_objective_tail_weight_penalizes_wide_tails():
    keys = _keys()
    from repro.core.spec import build
    sp = IndexSpec("rmi", {"branching": 64}).validated()
    b = build(sp, keys)
    # synthetic widths: tight mean, pathological tail past the p99 cut
    widths = np.ones(2_048)
    widths[-64:] = 4_096
    metrics = analysis.describe(b, widths)
    lo = WorkloadObjective(tail_weight=1.0).score(sp, metrics, widths)
    hi = WorkloadObjective(tail_weight=5.0).score(sp, metrics, widths)
    assert hi > lo
    # no tail (widths all equal) → tail weight is inert
    flat = np.full(2_048, 8.0)
    m2 = analysis.describe(b, flat)
    assert WorkloadObjective(tail_weight=5.0).score(sp, m2, flat) == \
        pytest.approx(WorkloadObjective(tail_weight=1.0).score(sp, m2, flat))


def test_cost_ns_calibration_rescales():
    m = {"probes": 4, "bytes_touched": 100, "flops": 10}
    base = analysis.cost_ns(m)
    assert analysis.cost_ns(m, calibration=2.0) == pytest.approx(2 * base)
    assert analysis.cost_ns(m, calibration=1.0) == pytest.approx(base)


def test_calibration_pin_miscalibrated_proxy_no_longer_flips_choice():
    """§17 satellite pin: the tuner's cross-family choice must follow a
    measured ``cost_model_ratio``.  We derive, from the tuner's own
    evaluated costs, a ratio that makes the uncalibrated winner's proxy
    2x-style optimistic relative to the runner-up family — uncalibrated
    ranking keeps the (now wrong) winner, calibrated ranking flips to
    the other family.  Symmetrically, a no-op ratio of 1.0 changes
    nothing: the knob, not noise, drives the flip."""
    keys = _keys(30_000)
    tuner = Tuner(names=("rmi", "btree"), max_configs=4)
    res = tuner.tune(keys)
    win_family = res.spec.index
    other_family = "btree" if win_family == "rmi" else "rmi"
    best = {}
    for c in res.evaluated:
        fam = c.spec.index
        best[fam] = min(best.get(fam, float("inf")), c.cost_ns)
    assert best[win_family] <= best[other_family]
    # the winner's proxy was optimistic by this much (a 2x-miscalibrated
    # proxy is the motivating case; the exact ratio comes from the data)
    ratio = 1.01 * best[other_family] / best[win_family]
    flipped = Tuner(names=("rmi", "btree"), max_configs=4,
                    calibration={win_family: ratio}).tune(keys)
    assert flipped.spec.index == other_family
    control = Tuner(names=("rmi", "btree"), max_configs=4,
                    calibration={win_family: 1.0}).tune(keys)
    assert control.spec.index == win_family


# ---------------------------------------------------------------------------
# latency-class admission (satellite): MicroBatcher + ServiceMetrics
# ---------------------------------------------------------------------------
def test_microbatcher_class_deadline_budgets():
    mb = MicroBatcher(max_batch=1_000_000, deadline_s=10.0,
                      class_deadlines={"interactive": 0.01, "batch": 5.0})
    assert mb.deadline_for("interactive") == 0.01
    assert mb.deadline_for("batch") == 5.0
    assert mb.deadline_for("unknown") == 10.0      # fallback to default
    # batch-only traffic does not force an eager flush...
    mb.submit(np.arange(4, dtype=np.uint64), priority="batch")
    time.sleep(0.05)
    assert not mb.ready()
    # ...but one interactive request bounds its own wait
    mb.submit(np.arange(4, dtype=np.uint64), priority="interactive")
    assert mb.wait_ready(timeout=1.0)
    group = mb.take()
    # admission order is untouched: classes shape WHEN, never reorder
    assert [r.priority for r in group] == ["batch", "interactive"]


def test_microbatcher_class_deadline_recomputed_on_take():
    mb = MicroBatcher(max_batch=8, deadline_s=10.0,
                      class_deadlines={"interactive": 0.01, "batch": 5.0})
    mb.submit(np.arange(8, dtype=np.uint64), priority="interactive")
    mb.submit(np.arange(4, dtype=np.uint64), priority="batch")
    assert mb.ready()                      # size trigger from the first
    took = mb.take()
    assert len(took) == 1
    # the remaining batch-class request reverts to its lazy budget
    assert not mb.ready()


def test_microbatcher_class_deadlines_validated():
    with pytest.raises(ValueError):
        MicroBatcher(max_batch=8, deadline_s=1.0,
                     class_deadlines={"interactive": 0.0})


def test_service_metrics_per_class_rows():
    m = ServiceMetrics()
    t0 = time.perf_counter()
    m.observe_batch(
        n_keys=48, padded=64, n_requests=3, t_oldest_submit=t0,
        t_start=t0 + 0.001, t_end=t0 + 0.002,
        per_request=[(t0, 16, "interactive"), (t0, 16, "interactive"),
                     (t0, 16, "batch")])
    rows = {r["priority"]: r for r in m.per_class()}
    assert rows["interactive"]["requests"] == 2
    assert rows["interactive"]["keys"] == 32
    assert rows["batch"]["requests"] == 1
    assert rows["interactive"]["p99_request_ms"] > 0
    snap = m.snapshot()
    assert snap["class_interactive_requests"] == 2
    assert snap["class_batch_requests"] == 1
    # 2-tuple observations (no class) keep the classic shape: no rows
    m2 = ServiceMetrics()
    m2.observe_batch(n_keys=8, padded=8, n_requests=1, t_oldest_submit=t0,
                     t_start=t0, t_end=t0 + 0.001,
                     per_request=[(t0, 8)])
    assert m2.per_class() == []


def test_service_routes_priority_class_end_to_end():
    keys = _keys(20_000)
    svc = LookupService(keys, LookupServiceConfig(
        max_batch=256, deadline_ms=1.0,
        class_deadline_ms={"interactive": 1.0, "batch": 50.0}))
    with svc:
        q = sosd.make_queries(keys, 300, seed=2, present_frac=0.5)
        f_int = svc.submit(q[:150], priority="interactive")
        f_bat = svc.submit(q[150:], priority="batch")
        want = np.searchsorted(keys, q)
        np.testing.assert_array_equal(f_int.result(timeout=30.0), want[:150])
        np.testing.assert_array_equal(f_bat.result(timeout=30.0), want[150:])
    rows = {r["priority"]: r for r in svc.metrics.per_class()}
    assert rows["interactive"]["requests"] >= 1
    assert rows["batch"]["requests"] >= 1


# ---------------------------------------------------------------------------
# retuner: the state machine on a live service
# ---------------------------------------------------------------------------
def _mis_service(keys, executor="sync", **at_kw):
    """Service stranded on a deliberately mis-tuned btree (huge fanout:
    every descent level scans 2049 node keys) with a manual-poll
    retuner attached."""
    at = AutotuneConfig(
        hysteresis_s=0.0, cooldown_s=0.0, window_s=1.0,
        verify_queries=512, calibrate=False,
        tuner=Tuner(names=("btree",), max_configs=4, backends=("jnp",)),
        **at_kw)
    return LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("btree", {"sample": 1, "fanout": 2048}).validated(),
        max_batch=512, executor=executor, warm_buckets=(512,),
        autotune=at))


def _drift_traffic(svc, keys, n=1_024):
    """Hot-spot traffic (bottom 1/64 of key space), aged past the
    stationary warm-up so the drift window holds the shift only."""
    time.sleep(1.2)
    hot = np.random.default_rng(0).choice(
        keys[: max(1, len(keys) // 64)], size=n)
    np.testing.assert_array_equal(svc.lookup(hot),
                                  np.searchsorted(keys, hot))
    # evaluate the rules now: `poll_once` only acts on alerts that were
    # already firing when the poll began (that is the hysteresis
    # contract), so the flip must predate the poll
    svc.check_alerts(window_s=1.0)
    return hot


@pytest.mark.parametrize("executor", ["sync", "async"])
def test_e2e_drift_triggers_verified_swap_bit_identical(executor):
    """§17 acceptance: hot-spot skew fires `workload_drift` through the
    real alert path, one poll lands a VERIFIED hot-swap, and served
    positions are bit-identical to the oracle before and after."""
    keys = _keys()
    svc = _mis_service(keys, executor=executor)
    with svc:
        v0 = svc.registry.current().version
        _drift_traffic(svc, keys)
        assert "workload_drift" in svc.alerts.firing()
        d = svc.autotune.poll_once()       # REAL trigger: no force
        assert d is not None and d["action"] == "swapped", d
        assert d["trigger"] == "workload_drift"
        assert d["verify"]["divergent"] == 0
        assert d["candidate"]["specs"][0] != d["incumbent"]["specs"][0]
        gen = svc.registry.current()
        assert gen.version > v0
        assert gen.spec.canonical() == tuple(
            d["candidate"]["specs"][0]) or gen.spec.canonical() == \
            d["candidate"]["specs"][0]
        # post-swap serving is still bit-exact on a fresh mixed stream
        q = sosd.make_queries(keys, 2_000, seed=13, present_frac=0.5)
        np.testing.assert_array_equal(svc.lookup(q),
                                      np.searchsorted(keys, q))
        assert svc.autotune.n_swapped == 1
        # surfaces follow: health snapshot exposes the retuner counters
        snap = svc.health_snapshot(window_s=60.0)
        assert snap["autotune_swapped"] == 1
        assert snap["autotune_triggered"] == 1


def test_rejection_cost_is_truthful_and_does_not_swap():
    """A candidate that cannot beat a good incumbent by the margin is
    rejected with reason "cost" and the serving generation stays."""
    keys = _keys()
    # fanout 64 descends on 65-key node scans — cheaper than any ladder
    # rung (all fanout 128), so the swept candidate loses the margin
    svc = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("btree", {"sample": 1, "fanout": 64}).validated(),
        max_batch=512, warm_buckets=(512,),
        autotune=AutotuneConfig(
            hysteresis_s=0.0, cooldown_s=0.0, window_s=1.0,
            verify_queries=512, calibrate=False, min_win=0.05,
            tuner=Tuner(names=("btree",), max_configs=4,
                        backends=("jnp",)))))
    with svc:
        v0 = svc.registry.current().version
        d = svc.autotune.poll_once(force_trigger="workload_drift")
        assert d["action"] == "rejected" and d["reason"] == "cost"
        assert d["candidate"]["score"] > d["incumbent"]["score"] * 0.95
        assert svc.registry.current().version == v0
        assert svc.autotune.n_rejected == 1 and svc.autotune.n_swapped == 0


def test_rejection_no_better_spec_when_incumbent_is_the_ladder_winner():
    keys = _keys()
    probe = Tuner(names=("btree",), max_configs=4,
                  backends=("jnp",)).tune(keys)
    svc = LookupService(keys, LookupServiceConfig(
        spec=probe.spec, max_batch=512, warm_buckets=(512,),
        autotune=AutotuneConfig(
            hysteresis_s=0.0, cooldown_s=0.0, verify_queries=512,
            calibrate=False,
            tuner=Tuner(names=("btree",), max_configs=4,
                        backends=("jnp",)))))
    with svc:
        d = svc.autotune.poll_once(force_trigger="workload_drift")
        assert d["action"] == "rejected"
        assert d["reason"] == "no_better_spec"


def test_budget_violation_waives_cost_margin():
    """§17 margin rule: an incumbent OVER the tuner's byte cap must be
    swapped out even when its modeled cost beats every budgeted
    candidate — basis "budget" on the decision records why."""
    keys = _keys()
    # a 65536-leaf RMI's model table is ~1.3MB — far over a 128KB cap —
    # but its near-width-1 windows make its modeled cost BETTER than any
    # budgeted rung (rmi inference bytes are constant in branching), so
    # only the budget rule can carry the swap
    cap = 128 * 1024
    mk = lambda max_bytes: LookupService(keys, LookupServiceConfig(  # noqa: E731
        spec=IndexSpec("rmi", {"branching": 65536}).validated(),
        max_batch=512, warm_buckets=(512,),
        autotune=AutotuneConfig(
            hysteresis_s=0.0, cooldown_s=0.0, verify_queries=512,
            calibrate=False, min_win=0.05,
            tuner=Tuner(names=("rmi",), max_configs=6,
                        backends=("jnp",), max_bytes=max_bytes))))
    svc = mk(cap)
    with svc:
        assert svc.registry.current().build.size_bytes > cap
        d = svc.autotune.poll_once(force_trigger="slo_burn")
        assert d["action"] == "swapped", d
        assert d["basis"] == "budget"
        # the modeled cost genuinely preferred the incumbent — that is
        # exactly what the waiver exists for
        assert d["candidate"]["score"] > d["incumbent"]["score"]
        assert svc.registry.current().build.size_bytes <= cap
        q = sosd.make_queries(keys, 1_500, seed=3, present_frac=0.5)
        np.testing.assert_array_equal(svc.lookup(q),
                                      np.searchsorted(keys, q))
    # control: an incumbent WITHIN the cap keeps the margin gate — the
    # same budgeted search has nothing that beats it, nothing swaps
    svc2 = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("rmi", {"branching": 4096}).validated(),
        max_batch=512, warm_buckets=(512,),
        autotune=AutotuneConfig(
            hysteresis_s=0.0, cooldown_s=0.0, verify_queries=512,
            calibrate=False, min_win=0.05,
            tuner=Tuner(names=("rmi",), max_configs=6,
                        backends=("jnp",), max_bytes=cap))))
    with svc2:
        assert svc2.registry.current().build.size_bytes <= cap
        d2 = svc2.autotune.poll_once(force_trigger="slo_burn")
        assert d2["action"] == "rejected"
        assert d2["reason"] in ("cost", "no_better_spec")


def test_verify_failure_rejects_and_never_publishes(monkeypatch):
    keys = _keys()
    svc = _mis_service(keys)
    with svc:
        v0 = svc.registry.current().version
        monkeypatch.setattr(ShadowRetuner, "_verify_fn",
                            lambda self, fn, k, q: (False, 7))
        d = svc.autotune.poll_once(force_trigger="workload_drift")
        assert d["action"] == "rejected" and d["reason"] == "verify"
        assert d["verify"]["divergent"] == 7
        assert svc.registry.current().version == v0
        assert svc.autotune.n_verify_failures == 1


def test_retune_error_is_recorded_not_raised():
    keys = _keys()
    svc = LookupService(keys, LookupServiceConfig(
        max_batch=512, warm_buckets=(512,),
        autotune=AutotuneConfig(
            hysteresis_s=0.0, cooldown_s=0.0, verify_queries=256,
            calibrate=False,
            tuner=Tuner(names=("no_such_index",), backends=("jnp",)))))
    with svc:
        d = svc.autotune.poll_once(force_trigger="workload_drift")
        assert d["action"] == "error" and d["reason"]
        assert svc.autotune.n_errors == 1
        assert svc.autotune.last_error


def test_store_short_circuits_second_attempt(tmp_path):
    """The artifact store ends the retune loop cheaply: after a swap,
    the next attempt under the same (data, budget, workload) key skips
    the ladder sweep and lands on no_better_spec from cache."""
    keys = _keys()
    svc = _mis_service(keys, store_dir=str(tmp_path))
    with svc:
        _drift_traffic(svc, keys)
        d = svc.autotune.poll_once()
        assert d["action"] == "swapped" and not d["cache_hit"]
        assert svc.autotune.n_sweeps == 1
        # keep the drifted traffic shape alive so the signature matches
        _drift_traffic(svc, keys)
        d2 = svc.autotune.poll_once()
        assert d2 is not None and d2["cache_hit"], d2
        assert d2["action"] == "rejected"
        assert d2["reason"] == "no_better_spec"
        assert svc.autotune.n_sweeps == 1      # no second sweep
        assert svc.autotune.store.stats()["hits"] >= 1


def test_hysteresis_and_cooldown_gate_attempts():
    keys = _keys()
    at = AutotuneConfig(hysteresis_s=3600.0, cooldown_s=3600.0,
                        window_s=1.0, verify_queries=256, calibrate=False,
                        tuner=Tuner(names=("btree",), max_configs=2,
                                    backends=("jnp",)))
    svc = LookupService(keys, LookupServiceConfig(
        spec=IndexSpec("btree", {"sample": 1, "fanout": 2048}).validated(),
        max_batch=512, warm_buckets=(512,), autotune=at))
    with svc:
        _drift_traffic(svc, keys)
        assert "workload_drift" in svc.alerts.firing()
        # firing, but not CONTINUOUSLY for an hour: nothing is due
        assert svc.autotune.poll_once() is None
        assert svc.autotune.n_triggered == 0
        # a forced attempt arms the cooldown; the next poll stays idle
        d = svc.autotune.poll_once(force_trigger="workload_drift")
        assert d is not None
        assert svc.autotune.poll_once() is None


def test_mutable_service_retunes_through_republish():
    """Mutable path: the swap goes through `MutableIndex.republish`, so
    delta inserts made before the retune stay served after it."""
    keys = _keys(30_000)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        spec=IndexSpec("btree", {"sample": 1, "fanout": 2048}).validated(),
        max_batch=512, warm_buckets=(512,), auto_compact=False,
        autotune=AutotuneConfig(
            hysteresis_s=0.0, cooldown_s=0.0, verify_queries=512,
            calibrate=False,
            tuner=Tuner(names=("btree",), max_configs=4,
                        backends=("jnp",)))))
    with svc:
        gaps = keys[:-1][np.diff(keys) > 1] + 1
        ins = gaps[:64].astype(np.uint64)
        svc.insert(ins).result(timeout=60.0)
        d = svc.autotune.poll_once(force_trigger="workload_drift")
        assert d["action"] == "swapped", d
        merged = np.sort(np.concatenate([keys, ins]))
        q = sosd.make_queries(merged, 1_500, seed=4, present_frac=0.6)
        got = svc.lookup(q)
        np.testing.assert_array_equal(got, np.searchsorted(merged, q))


def test_daemon_thread_lifecycle_and_status():
    keys = _keys(20_000)
    svc = LookupService(keys, LookupServiceConfig(
        max_batch=512, warm_buckets=(512,),
        autotune=AutotuneConfig(
            daemon=True, poll_s=0.05, hysteresis_s=3600.0,
            calibrate=False)))
    with svc:
        deadline = time.perf_counter() + 10.0
        while svc.autotune.n_polls == 0 and time.perf_counter() < deadline:
            time.sleep(0.02)
        assert svc.autotune.alive
        assert svc.autotune.n_polls >= 1
        st = svc.autotune.status()
        assert st["alive"] and st["daemon"]
        snap = svc.health_snapshot(window_s=60.0)
        assert snap["autotune_alive"] == 1.0
    # service stop tears the retuner down with it
    assert not svc.autotune.alive


def test_autotune_json_surface(tmp_path):
    from repro.obs.export import MetricsServer

    keys = _keys(20_000)
    svc = _mis_service(keys, store_dir=str(tmp_path))
    with svc:
        svc.autotune.poll_once(force_trigger="workload_drift")
        with MetricsServer(svc, port=0) as ms:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{ms.port}/autotune.json",
                    timeout=10) as r:
                doc = json.loads(r.read().decode())
        assert doc["counters"]["triggered"] == 1
        assert doc["counters"]["swapped"] + doc["counters"]["rejected"] \
            + doc["counters"]["errors"] == 1
        assert doc["decisions"][-1]["trigger"] == "workload_drift"
        assert doc["config"]["triggers"]
        assert "store" in doc
    # a service without a retuner answers 404
    plain = LookupService(keys, LookupServiceConfig(max_batch=512))
    with plain:
        with MetricsServer(plain, port=0) as ms:
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(
                    f"http://127.0.0.1:{ms.port}/autotune.json", timeout=10)
            assert ei.value.code == 404


def test_warm_wait_is_a_noop_when_idle():
    keys = _keys(20_000)
    svc = LookupService(keys, LookupServiceConfig(max_batch=512))
    with svc:
        svc.warm_wait()            # nothing in flight: returns instantly
        q = sosd.make_queries(keys, 200, seed=1, present_frac=0.5)
        np.testing.assert_array_equal(svc.lookup(q),
                                      np.searchsorted(keys, q))
