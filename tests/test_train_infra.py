"""Optimizer, train step, checkpoint, fault tolerance, compression, sharding."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import model as M
from repro.train.optimizer import AdamW, cosine_schedule, opt_state_specs
from repro.train import train_step as TS
from repro.train import checkpoint as CK
from repro.train import fault_tolerance as FT
from repro.dist import compression as GC
from repro.dist.sharding import resolve_spec, ACT_RULES, PARAM_RULES
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# optimizer / train step
# ---------------------------------------------------------------------------
def test_adamw_converges_quadratic():
    opt = AdamW(lr=lambda s: 0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < float(lr(jnp.int32(50)))
    assert float(lr(jnp.int32(100))) >= 1e-4 - 1e-9  # floor


def test_train_step_reduces_loss():
    cfg = get_smoke("granite-3-2b")
    opt = AdamW(lr=lambda s: 3e-3, weight_decay=0.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = TS.TrainState(params, opt.init(params))
    step = jax.jit(TS.make_train_step(cfg, opt))
    rng = np.random.default_rng(0)
    toks = rng.integers(2, cfg.vocab, (4, 64)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_equivalence():
    cfg = get_smoke("granite-3-2b")
    opt = AdamW(lr=lambda s: 1e-3)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((4, 32), jnp.int32),
             "labels": jnp.ones((4, 32), jnp.int32)}
    s1 = TS.TrainState(params, opt.init(params))
    s2 = TS.TrainState(params, opt.init(params))
    st1, m1 = TS.make_train_step(cfg, opt, microbatches=1)(s1, batch)
    st2, m2 = TS.make_train_step(cfg, opt, microbatches=2)(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        st1.params, st2.params)
    assert max(jax.tree.leaves(d)) < 1e-2


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke("starcoder2-3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=cosine_schedule(1e-3, 5, 50))
    state = TS.TrainState(params, opt.init(params))
    t = CK.save(str(tmp_path), 7, state, extra={"mesh": [1]}, async_=True)
    t.join()
    assert CK.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), state)
    restored = CK.restore(str(tmp_path), 7, like)
    same = jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
        state, restored)
    assert all(jax.tree.leaves(same))


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory must never be picked up as a valid step."""
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert CK.latest_step(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_heartbeat_classification():
    led = FT.HeartbeatLedger(4, straggler_factor=2.0, dead_after=3)
    for step in range(5):
        for h in range(3):  # host 3 never beats
            led.beat(h, step, now=float(step))
    stragglers, dead = led.classify(5, now=5.0)
    assert 3 in dead
    # host 2 slows down
    led.beat(0, 5, now=5.0)
    led.beat(1, 5, now=5.0)
    stragglers, dead = led.classify(5, now=9.0)
    assert 2 in stragglers or 2 in dead


def test_shrink_mesh_drops_pod_first():
    shape, axes = FT.shrink_mesh_shape((2, 16, 16), ("pod", "data", "model"),
                                       lost_hosts=4, hosts_per_pod=64)
    assert shape == (1, 16, 16) and axes == ("pod", "data", "model")
    shape, axes = FT.shrink_mesh_shape((16, 16), ("data", "model"),
                                       lost_hosts=1, hosts_per_pod=64)
    assert shape == (8, 16)


def test_recovery_plan_scales_batch():
    led = FT.HeartbeatLedger(4, dead_after=1)
    for h in range(3):
        led.beat(h, 10, now=1.0)
    led.hosts[3].last_step = 5
    plan = FT.plan_recovery(led, 10, (2, 16, 16), ("pod", "data", "model"),
                            hosts_per_pod=2, ckpt_latest=100)
    assert plan is not None
    assert plan.restore_step == 100
    assert plan.global_batch_scale == 2.0  # lost one of two pods


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_quantize_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, 512).astype(np.float32))
    err = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    for _ in range(64):
        c, err = GC.quantize(x, err)
        acc = acc + GC.dequantize(c)
    # error feedback: accumulated dequantized sum tracks 64*x closely
    rel = float(jnp.linalg.norm(acc - 64 * x) / jnp.linalg.norm(64 * x))
    assert rel < 0.01, rel


def test_quantize_max_error_one_step():
    x = jnp.asarray(np.linspace(-3, 3, 101, dtype=np.float32))
    c, res = GC.quantize(x)
    assert float(jnp.max(jnp.abs(res))) <= float(c.scale) / 2 + 1e-7
    np.testing.assert_allclose(np.asarray(GC.dequantize(c) + res),
                               np.asarray(x), rtol=1e-6, atol=1e-6)


def test_compressed_psum_single_axis():
    from repro.dist import shard_map

    mesh = jax.make_mesh((1,), ("pod",))
    f = shard_map(
        lambda x: GC.compressed_psum(x, "pod")[0],
        mesh=mesh, in_specs=P(), out_specs=P())
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, 64).astype(np.float32))
    got = f(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-2,
                               atol=1e-2)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_resolve_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # trivial mesh: axis size 1 -> everything replicated
    spec = resolve_spec((24, 128), ("heads", "head_dim"), mesh, ACT_RULES)
    assert spec == P(None, None)


def test_resolve_spec_axis_reuse():
    import jax as _j
    if len(_j.devices()) < 1:
        pytest.skip("no devices")
    # simulated 16x16 resolution logic without building a 256-device mesh:
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = resolve_spec((256, 4096, 2048), ("batch", "seq", "embed"),
                        FakeMesh(), ACT_RULES)
    assert spec == P("data", None, None)
    # starcoder2: 24 heads don't divide 16 -> head_dim picks up an axis
    # (TP rules store FSDP on non-contraction dims: head_dim -> data first)
    spec = resolve_spec((3072, 24, 128), ("embed", "heads", "head_dim"),
                        FakeMesh(), PARAM_RULES)
    assert spec == P(None, None, "data")
    # mlp hidden: FSDP over (model, data) jointly
    spec = resolve_spec((6144, 16384), ("embed", "mlp"), FakeMesh(),
                        PARAM_RULES)
    assert spec == P(None, ("model", "data"))
    # deepseek experts divide; expert_fsdp falls through to data
    spec = resolve_spec((64, 2048, 1408), ("experts", None, "expert_fsdp"),
                        FakeMesh(), PARAM_RULES)
    assert spec == P("model", None, "data")
    # mixtral: experts don't divide; capacity TP takes model
    spec = resolve_spec((16, 8, 20480, 6144),
                        ("batch", "experts", "moe_cap_tp", None),
                        FakeMesh(), ACT_RULES)
    assert spec == P("data", None, "model", None)


def test_fsdp_rules_seq_pickup():
    """FSDP rule set: seq takes whatever the batch couldn't use."""
    from repro.dist.sharding import FSDP_ACT_RULES

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # train_4k: batch uses everything, seq unsharded
    spec = resolve_spec((256, 4096), ("batch", "seq"), FakeMesh(),
                        FSDP_ACT_RULES)
    assert spec == P(("data", "model"), None)
    # prefill_32k: batch 32 only fits data; seq picks up model (SP)
    spec = resolve_spec((32, 32768), ("batch", "seq"), FakeMesh(),
                        FSDP_ACT_RULES)
    assert spec == P("data", "model")

    class PodMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    # multi-pod train: batch 256 = data*model; seq takes the pod axis
    spec = resolve_spec((256, 4096), ("batch", "seq"), PodMesh(),
                        FSDP_ACT_RULES)
    assert spec == P(("data", "model"), "pod")
