"""The trip-count-aware HLO analyzer must match ground truth exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import hlo_cost


def _body(x, w):
    return jnp.tanh(x @ w), None


def _xla_cost(c):
    """compiled.cost_analysis() returns a one-element list on jax 0.4.x."""
    cost = c.cost_analysis()
    return cost[0] if isinstance(cost, list) else cost


def test_scan_trip_counts_recovered():
    def scanned(x, ws):
        x, _ = jax.lax.scan(_body, x, ws)
        return x

    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 256, 256), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    r = hlo_cost.analyze(c.as_text())
    expected = 6 * 2 * 64 * 256 * 256
    assert r["flops"] == pytest.approx(expected, rel=1e-6)
    # and the naive xla counter under-reports by exactly the trip count
    assert _xla_cost(c)["flops"] == pytest.approx(expected / 6, rel=1e-6)


def test_unrolled_matches_xla():
    def unrolled(x, ws):
        for i in range(4):
            x, _ = _body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    c = jax.jit(unrolled).lower(x, ws).compile()
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == pytest.approx(_xla_cost(c)["flops"], rel=1e-6)


def test_nested_scan_multiplies():
    def inner(x, w):
        x, _ = jax.lax.scan(_body, x, w)
        return x, None

    def outer(x, ws):
        x, _ = jax.lax.scan(inner, x, ws)
        return x

    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)
    c = jax.jit(outer).lower(x, ws).compile()
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == pytest.approx(15 * 2 * 16 * 64 * 64, rel=1e-6)


def test_shape_bytes_parser():
    assert hlo_cost._shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert hlo_cost._shape_bytes("(bf16[4,4], s32[2])") == 32 + 8
    assert hlo_cost._shape_bytes("pred[100]") == 100
