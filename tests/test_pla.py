"""Error guarantees of the PLA builders (PGM cone / RS spline)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import _pla


def _eval_cone(ax, ay, sl, x):
    seg = np.clip(np.searchsorted(ax, x, side="right") - 1, 0, len(ax) - 1)
    return ay[seg] + sl[seg] * (x - ax[seg])


@settings(max_examples=30, deadline=None)
@given(n=st.integers(32, 800), eps=st.sampled_from([2, 8, 32]),
       seed=st.integers(0, 2**31))
def test_shrinking_cone_error_bound(n, eps, seed):
    rng = np.random.default_rng(seed)
    x = np.unique(rng.integers(0, 2**52, n, dtype=np.uint64)).astype(np.float64)
    y = np.arange(len(x), dtype=np.float64)
    ax, ay, sl = _pla.shrinking_cone(x, y, float(eps))
    pred = _eval_cone(ax, ay, sl, x)
    assert np.abs(pred - y).max() <= eps + 1e-6
    assert (sl >= 0).all()
    assert (np.diff(ax) > 0).all()


@settings(max_examples=30, deadline=None)
@given(n=st.integers(32, 800), eps=st.sampled_from([2, 8, 32]),
       seed=st.integers(0, 2**31))
def test_greedy_spline_error_bound(n, eps, seed):
    rng = np.random.default_rng(seed)
    x = np.unique(rng.integers(0, 2**52, n, dtype=np.uint64)).astype(np.float64)
    y = np.arange(len(x), dtype=np.float64)
    kx, ky = _pla.greedy_spline(x, y, float(eps))
    # knots are data points, endpoints included
    assert kx[0] == x[0] and kx[-1] == x[-1]
    assert np.isin(kx, x).all()
    # interpolation error <= eps at every data point
    seg = np.clip(np.searchsorted(kx, x, side="right") - 1, 0, len(kx) - 2)
    t = (x - kx[seg]) / np.maximum(kx[seg + 1] - kx[seg], 1e-30)
    pred = ky[seg] + np.clip(t, 0, 1) * (ky[seg + 1] - ky[seg])
    assert np.abs(pred - y).max() <= eps + 1e-6


def test_group_rounded_spans():
    x = np.array([1.0, 1.0, 1.0, 2.0, 3.0, 3.0])
    y = np.arange(6.0)
    xu, yf, span = _pla.group_rounded(x, y)
    assert list(xu) == [1.0, 2.0, 3.0]
    assert list(yf) == [0.0, 3.0, 4.0]
    assert span == 2  # the three 1.0s span positions 0..2


def test_cone_fewer_segments_with_larger_eps():
    rng = np.random.default_rng(0)
    x = np.sort(rng.uniform(0, 1e12, 5000))
    y = np.arange(5000.0)
    n_segs = [len(_pla.shrinking_cone(x, y, e)[0]) for e in (4, 32, 256)]
    assert n_segs[0] >= n_segs[1] >= n_segs[2]
    assert n_segs[2] >= 1
