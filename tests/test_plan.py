"""LookupPlan IR: backend parity, scan materialization, plan transforms.

The acceptance contract of the plan engine (DESIGN.md §11): the "jnp"
and "pallas" backends return BIT-IDENTICAL LB ranks for every index on
every dataset and last-mile choice — including through the mutable
layer's hot-swap and the sharded dispatcher — and the range-scan
materialization matches a plain numpy oracle.
"""
import jax

jax.config.update("jax_enable_x64", True)

import functools

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data import sosd
from repro.core import base, plan

DATASETS = ("amzn", "face", "osm", "wiki")
INDEXES = [
    ("rmi", dict(branching=512)),
    ("pgm", dict(eps=32)),
    ("radix_spline", dict(eps=16, radix_bits=12)),
    ("rbs", dict(radix_bits=12)),
    ("btree", dict(sample=8)),
    ("binary_search", {}),
]
LAST_MILES = ("binary", "linear", "interpolation")

N_KEYS, N_Q = 8_000, 512


@functools.lru_cache(maxsize=None)
def _cell(ds: str):
    keys = sosd.generate(ds, N_KEYS, seed=3)
    q = sosd.make_queries(keys, N_Q, seed=5, present_frac=0.7)
    return keys, q, np.searchsorted(keys, q)


# ---------------------------------------------------------------------------
# The parity matrix: index x dataset x last-mile, jnp vs pallas
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds", DATASETS)
@pytest.mark.parametrize("name,hyper", INDEXES,
                         ids=[n for n, _ in INDEXES])
def test_backend_parity_matrix(name, hyper, ds):
    keys, q, lb = _cell(ds)
    data, qj = jnp.asarray(keys), jnp.asarray(q)
    b = base.REGISTRY[name](keys, **hyper)
    for lm in LAST_MILES:
        p = plan.lower(b, data, last_mile=lm)
        got_jnp = np.asarray(p.compile(backend="jnp")(qj))
        got_pal = np.asarray(p.compile(backend="pallas",
                                       interpret=True)(qj))
        np.testing.assert_array_equal(got_jnp, lb)
        np.testing.assert_array_equal(got_pal, got_jnp)


def test_rmi_unfused_pallas_parity():
    """The generic bounds->bounded_search kernel path (fused=False) must
    agree with both the fused whole-plan kernel and the jnp backend."""
    keys, q, lb = _cell("osm")
    b = base.REGISTRY["rmi"](keys, branching=512)
    p = plan.lower(b, jnp.asarray(keys))
    qj = jnp.asarray(q)
    fused = np.asarray(p.compile(backend="pallas", interpret=True)(qj))
    unfused = np.asarray(
        p.compile(backend="pallas", interpret=True, fused=False)(qj))
    np.testing.assert_array_equal(fused, lb)
    np.testing.assert_array_equal(unfused, lb)


def test_point_only_plan_parity():
    """robin_hash lowers to a degenerate (point-only) plan; both backends
    share the probe-window path and must agree: position for present
    keys, -1 for absent."""
    keys, q, _ = _cell("wiki")
    b = base.REGISTRY["robin_hash"](keys, load_factor=0.5)
    p = plan.lower(b, jnp.asarray(keys))
    qj = jnp.asarray(q)
    got_jnp = np.asarray(p.compile(backend="jnp")(qj))
    got_pal = np.asarray(p.compile(backend="pallas")(qj))
    np.testing.assert_array_equal(got_jnp, got_pal)
    present = np.isin(q, keys)
    assert (keys[got_jnp[present]] == q[present]).all()
    assert (got_jnp[~present] == -1).all()
    with pytest.raises(ValueError):
        p.scan_expr(4)


def test_unknown_backend_rejected():
    keys, _, _ = _cell("amzn")
    b = base.REGISTRY["rbs"](keys, radix_bits=12)
    p = plan.lower(b, jnp.asarray(keys))
    with pytest.raises(ValueError):
        p.compile(backend="tpu_v9")


def test_compile_cache_reuses_fn():
    keys, _, _ = _cell("amzn")
    b = base.REGISTRY["rbs"](keys, radix_bits=12)
    p = plan.lower(b, jnp.asarray(keys))
    assert p.compile() is p.compile()
    assert p.compile(backend="pallas") is not p.compile()


# ---------------------------------------------------------------------------
# Range-scan materialization vs numpy oracle
# ---------------------------------------------------------------------------
def _scan_oracle(keys, lb, m):
    out = np.full((len(lb), m), np.uint64(0xFFFFFFFFFFFFFFFF))
    for i, p in enumerate(lb):
        seg = keys[p:p + m]
        out[i, :seg.size] = seg
    return out


@pytest.mark.parametrize("name,hyper", [("rmi", dict(branching=512)),
                                        ("btree", dict(sample=8))],
                         ids=["rmi", "btree"])
@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_scan_matches_numpy_oracle(name, hyper, backend):
    keys, q, lb = _cell("face")
    b = base.REGISTRY[name](keys, **hyper)
    p = plan.lower(b, jnp.asarray(keys))
    m = 24
    pos, win = p.scan(jnp.asarray(q), m, backend=backend, interpret=True)
    np.testing.assert_array_equal(np.asarray(pos), lb)
    np.testing.assert_array_equal(np.asarray(win), _scan_oracle(keys, lb, m))


def test_scan_window_past_the_end():
    """Queries beyond the last key materialize all-sentinel windows."""
    keys, _, _ = _cell("amzn")
    b = base.REGISTRY["rbs"](keys, radix_bits=12)
    p = plan.lower(b, jnp.asarray(keys))
    q = np.full(4, max(int(keys[-1]) + 1, 0), dtype=np.uint64)
    pos, win = p.scan(jnp.asarray(q), 8)
    assert (np.asarray(pos) == len(keys)).all()
    assert (np.asarray(win) == np.uint64(0xFFFFFFFFFFFFFFFF)).all()


# ---------------------------------------------------------------------------
# Parity through the mutable layer (delta + hot-swap) and the dispatcher
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("index", ("rmi", "pgm", "btree"))
def test_mutable_hot_swap_backend_parity(index):
    from repro.mutable.index import MutableIndex

    keys, q, _ = _cell("osm")
    rng = np.random.default_rng(11)
    inserts = rng.integers(int(keys[0]), int(keys[-1]), 300,
                           dtype=np.uint64)

    results = {}
    for backend in ("jnp", "pallas"):
        mi = MutableIndex(keys, index=index, backend=backend,
                          compact_threshold=1 << 30)
        mi.insert(inserts)
        mid = mi.lookup(q)                      # merged: base + delta
        gen = mi.compact()                      # hot-swap to a new base
        assert gen is not None
        post = mi.lookup(q)
        np.testing.assert_array_equal(mid, post)  # swap changes nothing
        results[backend] = post

    merged_keys = np.unique(np.concatenate([keys, inserts]))
    expected = np.searchsorted(merged_keys, q)
    np.testing.assert_array_equal(results["jnp"], expected)
    np.testing.assert_array_equal(results["jnp"], results["pallas"])


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_sharded_dispatcher_accepts_plans(backend):
    from repro.serve.lookup.dispatch import ShardedDispatcher

    keys, q, lb = _cell("wiki")
    b = base.REGISTRY["radix_spline"](keys, eps=16, radix_bits=12)
    p = plan.lower(b, jnp.asarray(keys))
    disp = ShardedDispatcher()
    out = disp(p, q, backend=backend)     # plan, not a closure
    np.testing.assert_array_equal(out, lb)


def test_service_runs_on_pallas_backend():
    """One LookupService path end-to-end on the plan engine's kernel
    backend, including a scan op kind through the micro-batcher."""
    from repro.serve.lookup import LookupService, LookupServiceConfig

    keys, q, lb = _cell("amzn")
    svc = LookupService(keys, LookupServiceConfig(
        index="rmi", hyper=dict(branching=512), backend="pallas",
        max_batch=256))
    assert svc.generation.backend == "pallas"
    np.testing.assert_array_equal(svc.lookup(q), lb)

    fut = svc.scan(q[:100], 16)
    svc.drain()
    pos, win = fut.result(30.0)
    np.testing.assert_array_equal(pos, lb[:100])
    np.testing.assert_array_equal(win, _scan_oracle(keys, lb[:100], 16))


def test_scan_on_point_only_index_fails_future_not_flusher():
    """A scan against a point-only index is rejected at admission; if
    one slips past (hot-swap race), the compile error fails the FUTURE,
    and the flusher keeps serving later requests."""
    from repro.serve.lookup import LookupService, LookupServiceConfig

    keys, q, _ = _cell("amzn")
    svc = LookupService(keys, LookupServiceConfig(
        index="robin_hash", max_batch=64))
    with pytest.raises(ValueError):
        svc.scan(q[:8], 8)
    # race path: admit the scan directly through the batcher
    _, fut = svc.batcher.submit(q[:8], kind="scan", aux=8)
    svc.drain()
    with pytest.raises(ValueError):
        fut.result(10.0)
    # the service still completes point lookups afterwards
    present = keys[:50]
    out = svc.lookup(present)
    assert (keys[out] == present).all()


def test_mutable_service_ycsb_e_scans_end_to_end():
    """A YCSB-E trace (ranges + inserts) executes end-to-end: every range
    op materializes its window, verified against the numpy scan oracle
    at every step across delta growth."""
    from repro import workloads
    from repro.serve.lookup import (MutableLookupService,
                                    MutableLookupServiceConfig)

    keys, _, _ = _cell("face")
    wl = workloads.make_workload(keys, 400, mix="ycsb_e", dist="zipfian",
                                 seed=9, range_len=16)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        index="radix_spline", hyper=dict(eps=16), max_batch=256,
        compact_threshold=1 << 30, auto_compact=False))
    got, windows = workloads.replay_on_service(wl, svc, chunk=64,
                                               scan_ranges=True)
    exp, exp_windows = workloads.oracle_scan_replay(keys, wl)
    np.testing.assert_array_equal(got, exp)
    assert set(windows) == set(exp_windows) != set()
    for i in exp_windows:
        np.testing.assert_array_equal(windows[i], exp_windows[i])
