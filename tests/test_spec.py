"""The declarative build API + budget tuner (DESIGN.md §12).

Pins the acceptance contract of the spec layer: specs validate before
building and build BIT-IDENTICAL to the equivalent direct call, the
schema registry and `base.REGISTRY` can never drift apart, capped
sweeps always see both size extremes, `Generation.spec` survives the
service layer (hot-swap + sharded dispatch) with its backend intact,
the tuner's byte budget is hard, and compaction retunes against the
delta-merged key set.
"""
import jax

jax.config.update("jax_enable_x64", True)

import functools
import inspect
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data import sosd
from repro.core import base, spec, tuning

N_KEYS, N_Q = 8_000, 512

#: One mid-ladder rung per index for the bit-identity matrix.
DIRECT_CELLS = [
    ("rmi", dict(branching=512, stage1="linear")),
    ("pgm", dict(eps=32)),
    ("radix_spline", dict(eps=16, radix_bits=12)),
    ("btree", dict(sample=8)),
    ("ibtree", dict(sample=16)),
    ("rbs", dict(radix_bits=12)),
    ("binary_search", {}),
    ("robin_hash", dict(load_factor=0.5)),
]


@functools.lru_cache(maxsize=None)
def _cell(ds: str = "amzn"):
    keys = sosd.generate(ds, N_KEYS, seed=3)
    q = sosd.make_queries(keys, N_Q, seed=5, present_frac=0.7)
    return keys, q, np.searchsorted(keys, q)


# ---------------------------------------------------------------------------
# IndexSpec: serialization + validation
# ---------------------------------------------------------------------------
def test_json_roundtrip():
    specs = [
        spec.IndexSpec("rmi", dict(branching=512)),
        spec.IndexSpec("pgm", dict(eps=32), backend="pallas"),
        spec.IndexSpec("btree", dict(sample=4), last_mile="interpolation"),
        spec.IndexSpec("binary_search"),
    ]
    for s in specs:
        assert spec.IndexSpec.from_json(s.to_json()) == s
        v = s.validated()
        assert v.validated() == v                  # idempotent
        assert spec.IndexSpec.from_json(v.to_json()) == v
        assert v.backend == s.backend and v.last_mile == s.last_mile
    # JSON is plain data: no surprises for an external caller
    d = json.loads(specs[1].to_json())
    assert d == {"index": "pgm", "hyper": {"eps": 32}, "backend": "pallas"}


def test_from_dict_rejects_unknown_keys():
    with pytest.raises(spec.SpecError):
        spec.IndexSpec.from_dict({"index": "rmi", "hyperr": {}})
    with pytest.raises(spec.SpecError):
        spec.IndexSpec.from_dict({"hyper": {}})


@pytest.mark.parametrize("bad", [
    spec.IndexSpec("no_such_index"),
    spec.IndexSpec("rmi", dict(branchingg=512)),          # unknown field
    spec.IndexSpec("rmi", dict(branching="big")),         # wrong type
    spec.IndexSpec("rmi", dict(branching=True)),          # bool is not int
    spec.IndexSpec("rmi", dict(branching=1)),             # below min
    spec.IndexSpec("rmi", dict(stage1="quartic")),        # not a choice
    spec.IndexSpec("rbs", dict(radix_bits=64)),           # above max
    spec.IndexSpec("robin_hash", dict(load_factor=2.0)),  # above max
    spec.IndexSpec("rmi", backend="tpu_v9"),
    spec.IndexSpec("rmi", last_mile="quantum"),
], ids=["index", "field", "type", "bool", "min", "choice", "max",
        "float-max", "backend", "last-mile"])
def test_validation_rejects(bad):
    with pytest.raises(spec.SpecError):
        bad.validated()
    with pytest.raises(spec.SpecError):
        spec.build(bad, _cell()[0])   # build validates BEFORE building


def test_coerce_folds_legacy_and_rejects_mixed():
    sp = spec.coerce("rmi", dict(branching=256), backend="pallas")
    assert sp == spec.IndexSpec("rmi", dict(branching=256),
                                backend="pallas").validated()
    assert spec.coerce(sp) == sp
    with pytest.raises(TypeError):
        spec.coerce(spec.IndexSpec("rmi"), dict(branching=256))


def test_validated_fills_defaults():
    v = spec.IndexSpec("rmi", dict(branching=256)).validated()
    assert v.hyper == dict(branching=256, stage1="linear")
    assert spec.IndexSpec("binary_search").validated().hyper == {}


# ---------------------------------------------------------------------------
# The build entry point: bit-identical to direct builds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,hyper", DIRECT_CELLS,
                         ids=[n for n, _ in DIRECT_CELLS])
def test_spec_build_bit_identical_to_direct(name, hyper):
    keys, q, _ = _cell()
    via_spec = spec.build(spec.IndexSpec(name, hyper), keys)
    direct = base.REGISTRY[name](keys, **hyper)
    assert via_spec.name == direct.name
    assert via_spec.size_bytes == direct.size_bytes
    ls, ld = (jax.tree_util.tree_leaves(via_spec.state),
              jax.tree_util.tree_leaves(direct.state))
    assert len(ls) == len(ld)
    for a, b in zip(ls, ld):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    qj = jnp.asarray(q)
    outs = via_spec.lookup(via_spec.state, qj)
    outd = direct.lookup(direct.state, qj)
    for a, b in zip(outs, outd):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the spec rides along on the build
    assert via_spec.meta["spec"].index == name


# ---------------------------------------------------------------------------
# Registry <-> schema consistency (satellite: nothing can drift)
# ---------------------------------------------------------------------------
def test_registry_schema_consistency():
    assert set(spec.SCHEMAS) == set(base.REGISTRY), (
        "every base.REGISTRY index needs a spec schema and vice versa")
    swept = set(spec.sweep_names())
    for name, schema in spec.SCHEMAS.items():
        assert len(schema.ladder) >= 1, f"{name}: empty ladder"
        # every rung must be a valid (partial) spec
        for rung in schema.ladder:
            spec.IndexSpec(name, dict(rung)).validated()
        if name in swept:
            assert schema.sweep and not schema.sweep_exclude_reason
        else:
            assert schema.sweep_exclude_reason, (
                f"{name} is excluded from the default sweep without a "
                "stated reason")
    # the historically-missing names are now resolved explicitly:
    assert "ibtree" in swept
    assert "robin_hash" not in swept
    assert "point-only" in spec.SCHEMAS["robin_hash"].sweep_exclude_reason
    # the derived LADDERS view matches the schemas
    assert set(tuning.LADDERS) == set(spec.SCHEMAS)
    for name in spec.SCHEMAS:
        assert tuning.LADDERS[name] == [dict(r) for r in
                                        spec.SCHEMAS[name].ladder]
    # the spec layer's backend axis must track the plan IR's
    from repro.core import plan
    assert spec.BACKENDS == plan.BACKENDS


def test_schema_defaults_match_builder_signatures():
    """A schema default drifting from the builder's signature default
    would make `validated()` change build results — forbid it."""
    for name, schema in spec.SCHEMAS.items():
        sig = inspect.signature(base.REGISTRY[name])
        for f in schema.fields:
            p = sig.parameters.get(f.name)
            assert p is not None, f"{name}.{f.name}: not a builder kwarg"
            assert p.default == f.default, (
                f"{name}.{f.name}: schema default {f.default!r} != "
                f"builder default {p.default!r}")


# ---------------------------------------------------------------------------
# Capped sweeps: stride sampling keeps both size extremes (satellite)
# ---------------------------------------------------------------------------
def test_stride_sample_includes_both_ends():
    seq = list(range(9))
    assert spec.stride_sample(seq, 3) == [0, 4, 8]
    assert spec.stride_sample(seq, 2) == [0, 8]
    assert spec.stride_sample(seq, 9) == seq
    assert spec.stride_sample(seq, None) == seq
    out = spec.stride_sample(seq, 5)
    assert out[0] == 0 and out[-1] == 8 and len(out) == 5


@pytest.mark.parametrize("name", ("pgm", "btree", "rmi"))
def test_capped_sweep_sees_min_and_max_sizes(name):
    keys, _, _ = _cell()
    full = [b.size_bytes for b in tuning.sweep(keys, names=(name,))]
    capped = [b.size_bytes
              for b in tuning.sweep(keys, names=(name,), max_configs=3)]
    assert len(capped) == 3
    # the ladder-ordering contract: rungs run smallest -> largest size
    assert full[0] == min(full) and full[-1] == max(full)
    # the fix: a capped sweep still spans the whole size range
    assert min(capped) == min(full) and max(capped) == max(full)


# ---------------------------------------------------------------------------
# Tuner: hard byte budget, target_ns, backend measurement
# ---------------------------------------------------------------------------
def test_tuner_respects_hard_byte_budget():
    keys, q, lb = _cell()
    budget = 20_000
    res = spec.Tuner(names=("rmi", "pgm"), max_bytes=budget,
                     max_configs=4).tune(keys)
    assert res.build.size_bytes <= budget
    assert any(c.size_bytes > budget for c in res.evaluated), (
        "search space should include over-budget rungs it then discards")
    # the tuned build is bit-identical to a direct build of the spec
    direct = spec.build(res.spec, keys)
    assert direct.size_bytes == res.build.size_bytes
    qj = jnp.asarray(q)
    lo1, hi1 = res.build.lookup(res.build.state, qj)
    lo2, hi2 = direct.lookup(direct.state, qj)
    np.testing.assert_array_equal(np.asarray(lo1), np.asarray(lo2))
    np.testing.assert_array_equal(np.asarray(hi1), np.asarray(hi2))


def test_tuner_budget_impossible_raises():
    keys, _, _ = _cell()
    with pytest.raises(spec.BudgetError):
        spec.Tuner(names=("rmi",), max_bytes=8, max_configs=2).tune(keys)


def test_tuner_target_ns_picks_smallest_fast_enough():
    keys, _, _ = _cell()
    t = spec.Tuner(names=("rmi", "pgm"), target_ns=1e12, max_configs=4)
    res = t.tune(keys)
    # with an unreachable-high target, EVERY candidate qualifies, so the
    # smallest index must win
    assert res.build.size_bytes == min(c.size_bytes for c in res.evaluated)
    # monotonicity: loosening the byte budget can only speed up the pick
    tight = spec.Tuner(names=("rmi", "pgm"), max_bytes=20_000,
                       max_configs=4).tune(keys)
    loose = spec.Tuner(names=("rmi", "pgm"), max_bytes=1 << 24,
                       max_configs=4).tune(keys)
    tight_c = min(c.cost_ns for c in tight.evaluated
                  if c.size_bytes <= 20_000)
    loose_c = min(c.cost_ns for c in loose.evaluated)
    assert loose_c <= tight_c


def test_tuner_measures_and_selects_backend():
    keys, q, lb = _cell()
    res = spec.Tuner(names=("rmi",), backends=("jnp", "pallas"),
                     max_configs=2, n_queries=256).tune(keys)
    assert set(res.backend_ns) == {"jnp", "pallas"}
    assert res.spec.backend == min(res.backend_ns, key=res.backend_ns.get)
    # whichever backend won, the tuned spec still serves exact LB ranks
    from repro.core import plan
    fn = plan.lower(res.build, jnp.asarray(keys)).compile(
        backend=res.spec.backend)
    np.testing.assert_array_equal(np.asarray(fn(jnp.asarray(q))), lb)


def test_tuner_rejects_point_only_names():
    keys, _, _ = _cell()
    with pytest.raises(spec.SpecError):
        spec.Tuner(names=("robin_hash",)).tune(keys)


# ---------------------------------------------------------------------------
# Spec round-trip through the service layer (satellite)
# ---------------------------------------------------------------------------
def test_generation_spec_survives_publish_and_hot_swap():
    from repro.serve.lookup import IndexRegistry
    from repro.serve.lookup.dispatch import ShardedDispatcher

    keys, q, lb = _cell()
    reg = IndexRegistry()
    sp = spec.IndexSpec("rmi", dict(branching=512), backend="pallas")
    gen = reg.build_and_publish(sp, keys)
    assert gen.backend == "pallas"
    assert gen.spec == sp.validated()
    # JSON round-trip of the published spec rebuilds bit-identically
    re_sp = spec.IndexSpec.from_json(gen.spec.to_json())
    re_gen = reg.build_and_publish(re_sp, keys, name="rebuilt")
    np.testing.assert_array_equal(
        np.asarray(gen.fn(jnp.asarray(q))),
        np.asarray(re_gen.fn(jnp.asarray(q))))
    # hot-swap: a new spec published under the same name replaces it
    sp2 = spec.IndexSpec("pgm", dict(eps=32))
    gen2 = reg.build_and_publish(sp2, keys)
    assert reg.current().spec == sp2.validated()
    assert reg.current().spec.backend == "jnp"
    # the sharded dispatcher serves the generation's plan on its backend
    disp = ShardedDispatcher()
    np.testing.assert_array_equal(disp(gen.plan, q, backend=gen.backend), lb)
    np.testing.assert_array_equal(disp(gen2.plan, q, backend=gen2.backend),
                                  lb)


def test_legacy_string_publish_still_carries_spec():
    from repro.serve.lookup import IndexRegistry

    keys, q, lb = _cell()
    gen = IndexRegistry().build_and_publish(
        "radix_spline", keys, hyper=dict(eps=16, radix_bits=12))
    assert gen.spec is not None
    assert gen.spec.index == "radix_spline"
    assert gen.spec.hyper["eps"] == 16
    np.testing.assert_array_equal(np.asarray(gen.fn(jnp.asarray(q))), lb)


def test_service_config_spec_roundtrip():
    from repro.serve.lookup import (LookupService, LookupServiceConfig,
                                    MutableLookupService,
                                    MutableLookupServiceConfig)

    keys, q, lb = _cell()
    sp = spec.IndexSpec("rmi", dict(branching=512), backend="pallas")
    svc = LookupService(keys, LookupServiceConfig(spec=sp, max_batch=256))
    assert svc.generation.spec == sp.validated()
    assert svc.generation.backend == "pallas"
    np.testing.assert_array_equal(svc.lookup(q), lb)
    # swap_keys preserves the spec on the fresh generation
    svc.swap_keys(keys[: len(keys) // 2])
    assert svc.generation.spec == sp.validated()

    msvc = MutableLookupService(keys, MutableLookupServiceConfig(
        spec=spec.IndexSpec("pgm", dict(eps=32)), max_batch=256,
        auto_compact=False))
    assert msvc.generation.spec == \
        spec.IndexSpec("pgm", dict(eps=32)).validated()
    np.testing.assert_array_equal(msvc.lookup(q), lb)


# ---------------------------------------------------------------------------
# Compaction retunes against the delta-merged key set (acceptance)
# ---------------------------------------------------------------------------
def test_compaction_retunes_with_tuner():
    from repro.mutable.index import MutableIndex

    keys, q, _ = _cell()
    rng = np.random.default_rng(17)
    inserts = rng.integers(int(keys[0]), int(keys[-1]), 400,
                           dtype=np.uint64)
    budget = 25_000
    tuner = spec.Tuner(names=("rmi", "pgm"), max_bytes=budget,
                       max_configs=3, seed=1)
    mi = MutableIndex(keys, spec=spec.IndexSpec("rmi", dict(branching=512)),
                      tuner=tuner, compact_threshold=1 << 30)
    start_spec = mi.spec
    mi.insert(inserts)
    merged = np.unique(np.concatenate([keys, inserts]))
    pre = mi.lookup(q)
    np.testing.assert_array_equal(pre, np.searchsorted(merged, q))

    gen = mi.compact()
    assert gen is not None
    # the new spec is EXACTLY what the tuner picks on the merged keys
    expected = tuner.tune(merged).spec
    assert mi.spec == expected
    assert gen.spec == expected
    assert gen.build.size_bytes <= budget
    # retuning may change the structure but never the answers
    np.testing.assert_array_equal(mi.lookup(q), pre)
    # without a tuner the spec stays pinned
    mi2 = MutableIndex(keys, spec=start_spec, compact_threshold=1 << 30)
    mi2.insert(inserts)
    assert mi2.compact() is not None
    assert mi2.spec == start_spec.validated()


def test_compaction_retune_preserves_backend_and_last_mile():
    """A single-backend tuner performed no backend selection, so the
    index's configured serving backend (and last-mile) must survive the
    retune — only a multi-backend tuner may flip the backend."""
    from repro.mutable.index import MutableIndex

    keys, q, _ = _cell()
    ins = np.random.default_rng(5).integers(
        int(keys[0]), int(keys[-1]), 200, dtype=np.uint64)
    tuner = spec.Tuner(names=("rmi", "pgm"), max_bytes=25_000,
                       max_configs=3)
    mi = MutableIndex(
        keys,
        spec=spec.IndexSpec("rmi", dict(branching=512), backend="pallas",
                            last_mile="interpolation"),
        tuner=tuner, compact_threshold=1 << 30)
    mi.insert(ins)
    gen = mi.compact()
    assert gen is not None
    assert mi.spec.backend == "pallas"
    assert mi.spec.last_mile == "interpolation"
    assert gen.backend == "pallas" and gen.spec == mi.spec
    merged = np.unique(np.concatenate([keys, ins]))
    np.testing.assert_array_equal(mi.lookup(q), np.searchsorted(merged, q))


def test_mutable_service_compaction_retune_end_to_end():
    from repro.serve.lookup import (MutableLookupService,
                                    MutableLookupServiceConfig)

    keys, q, _ = _cell()
    tuner = spec.Tuner(names=("rmi", "pgm"), max_bytes=25_000,
                       max_configs=3)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        spec=spec.IndexSpec("rmi", dict(branching=512)),
        compact_threshold=64, auto_compact=False, tuner=tuner,
        max_batch=512))
    rng = np.random.default_rng(23)
    ins = rng.integers(int(keys[0]), int(keys[-1]), 300, dtype=np.uint64)
    fut = svc.insert(ins)
    svc.drain()
    fut.result(30.0)
    gen = svc.force_compact()
    assert gen is not None and gen.build.size_bytes <= 25_000
    assert gen.spec == svc.mindex.spec
    merged = np.unique(np.concatenate([keys, ins]))
    np.testing.assert_array_equal(svc.lookup(q),
                                  np.searchsorted(merged, q))
