"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions, decode-step consistency, spec-tree sync."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.configs import ARCHS, SHAPES, SKIPS, get_smoke
from repro.models import model as M

ALL = list(ARCHS)


def _batch(cfg, b=2, s=64):
    out = {"tokens": jnp.ones((b, s), jnp.int32),
           "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = jnp.zeros((b, cfg.encoder_seq, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    return out


@pytest.mark.parametrize("arch", ALL)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (2, 64, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = M.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ALL)
def test_smoke_grad_step(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch))(params)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in leaves)
    assert total > 0.0, "gradients must be non-trivial"


@pytest.mark.parametrize("arch", ALL)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache_sh = M.cache_shapes(cfg, batch=2, s_max=96)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sh)
    logits, cache2 = M.decode_step(cfg, params, cache,
                                   jnp.ones((2, 1), jnp.int32))
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache2["len"][0]) == 1
    # step again with the updated cache
    logits3, cache3 = M.decode_step(cfg, params, cache2,
                                    jnp.ones((2, 1), jnp.int32))
    assert int(cache3["len"][0]) == 2


@pytest.mark.parametrize("arch", ALL)
def test_param_spec_tree_matches(arch):
    """param_specs must mirror init_params structurally (sharding relies
    on it); same for cache specs."""
    cfg = get_smoke(arch)
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = M.param_specs(cfg)
    is_names = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    s1 = jtu.tree_structure(jax.tree.map(lambda x: 0, shapes))
    s2 = jtu.tree_structure(jtu.tree_map(lambda x: 0, specs, is_leaf=is_names))
    assert s1 == s2
    # every spec tuple has the same rank as its array
    flat_shapes = jtu.tree_leaves_with_path(shapes)
    flat_specs = {jtu.keystr(p): v for p, v in
                  jtu.tree_leaves_with_path(specs, is_leaf=is_names)}
    for path, sds in flat_shapes:
        names = flat_specs[jtu.keystr(path)]
        assert len(names) == len(sds.shape), (jtu.keystr(path), names, sds.shape)


@pytest.mark.parametrize("arch", ALL)
def test_cache_spec_tree_matches(arch):
    cfg = get_smoke(arch)
    shapes = M.cache_shapes(cfg, batch=2, s_max=32)
    specs = M.cache_specs(cfg)
    is_names = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    s1 = jtu.tree_structure(jax.tree.map(lambda x: 0, shapes))
    s2 = jtu.tree_structure(jtu.tree_map(lambda x: 0, specs, is_leaf=is_names))
    assert s1 == s2


def test_exact_configs_match_assignment():
    """The full configs must carry the exact assigned hyperparameters."""
    expect = {
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    }
    for name, (L, d, h, kv, ff, v) in expect.items():
        cfg = ARCHS[name]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), name
    # MoE structure
    assert ARCHS["deepseek-moe-16b"].n_experts == 64
    assert ARCHS["deepseek-moe-16b"].top_k == 6
    assert ARCHS["deepseek-moe-16b"].n_shared_experts == 2
    assert ARCHS["mixtral-8x22b"].n_experts == 8
    assert ARCHS["mixtral-8x22b"].top_k == 2
    assert ARCHS["jamba-1.5-large-398b"].n_experts == 16
    assert ARCHS["jamba-1.5-large-398b"].hybrid_period == 8
    assert ARCHS["mamba2-2.7b"].ssm_state == 128


def test_shape_table_and_skips():
    assert SHAPES["train_4k"] == (4096, 256, "train")
    assert SHAPES["prefill_32k"] == (32768, 32, "prefill")
    assert SHAPES["decode_32k"] == (32768, 128, "decode")
    assert SHAPES["long_500k"] == (524288, 1, "decode")
    assert ("granite-3-2b", "long_500k") in SKIPS
    assert ("mamba2-2.7b", "long_500k") not in SKIPS
    assert ("jamba-1.5-large-398b", "long_500k") not in SKIPS


def test_param_counts_match_published():
    tol = {"granite-3-2b": (2.5e9, 0.05), "qwen1.5-32b": (32e9, 0.12),
           "command-r-plus-104b": (104e9, 0.05), "mamba2-2.7b": (2.7e9, 0.05),
           "jamba-1.5-large-398b": (398e9, 0.03),
           "deepseek-moe-16b": (16.4e9, 0.03), "mixtral-8x22b": (141e9, 0.03),
           "chameleon-34b": (34e9, 0.03)}
    for name, (n, rel) in tol.items():
        got = ARCHS[name].param_count()
        assert abs(got - n) / n < rel, (name, got, n)
