"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import jax

jax.config.update("jax_enable_x64", True)  # uint64 key planes

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data import sosd
from repro.kernels.common import split_u64, merge_u64, pad_pow2
from repro.kernels.bounded_search.ops import lower_bound_windows
from repro.kernels.bounded_search.ref import lower_bound_windows_ref
from repro.kernels.rmi_lookup import ops as rops
from repro.kernels.rmi_lookup import ref as rref


def test_split_merge_roundtrip():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**63, 1000, dtype=np.uint64)
    hi, lo = split_u64(a)
    assert (merge_u64(hi, lo) == a).all()
    b = rng.integers(0, 2**31, 1000).astype(np.int32)
    hi32, lo32 = split_u64(b)
    assert (hi32 == 0).all() and (lo32 == b.astype(np.uint32)).all()


@pytest.mark.parametrize("n,m,width", [
    (1_000, 257, 64), (10_000, 2_048, 160), (50_000, 4_001, 512),
])
@pytest.mark.parametrize("dtype", [np.uint64, np.uint32])
def test_bounded_search_shapes_dtypes(n, m, width, dtype):
    rng = np.random.default_rng(n + m)
    if dtype == np.uint64:
        keys = np.unique(rng.integers(0, 2**62, int(n * 1.2), dtype=np.uint64))[:n]
    else:
        keys = np.unique(rng.integers(0, 2**31, int(n * 1.3)).astype(np.uint32))[:n]
    q = keys[rng.integers(0, len(keys), m)]
    lb = np.searchsorted(keys, q).astype(np.int64)
    lo = np.maximum(lb - rng.integers(0, width - 1, m), 0)
    got = lower_bound_windows(jnp.asarray(keys), jnp.asarray(q),
                              jnp.asarray(lo, jnp.int32), max_width=width,
                              interpret=True)
    ref = lower_bound_windows_ref(jnp.asarray(keys), jnp.asarray(q), lo, width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_bounded_search_overflow_fallback():
    """Every query in ONE tile: capacity overflow must stay exact."""
    keys = np.arange(10_000, dtype=np.uint64) * 3 + 5
    q = keys[np.random.default_rng(0).integers(0, 100, 5_000)]  # tile 0 only
    lb = np.searchsorted(keys, q).astype(np.int64)
    lo = np.maximum(lb - 10, 0)
    got = lower_bound_windows(jnp.asarray(keys), jnp.asarray(q),
                              jnp.asarray(lo, jnp.int32), max_width=64,
                              capacity=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), lb)


def test_bounded_search_wide_window_fallback():
    """max_width > DATA_TILE falls back to the exact jnp path."""
    keys = np.unique(np.random.default_rng(1).integers(
        0, 2**40, 8_000, dtype=np.uint64))
    q = keys[::3]
    lb = np.searchsorted(keys, q).astype(np.int64)
    lo = np.zeros(len(q), np.int64)
    got = lower_bound_windows(jnp.asarray(keys), jnp.asarray(q),
                              jnp.asarray(lo, jnp.int32),
                              max_width=len(keys) + 1, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), lb)


@pytest.mark.parametrize("ds", ["wiki", "face", "osm"])
@pytest.mark.parametrize("branching", [512, 4096])
def test_rmi_kernel_end_to_end(ds, branching):
    keys = sosd.generate(ds, 40_000, seed=3)
    q = sosd.make_queries(keys, 4_096, seed=5, present_frac=0.5)
    lb = np.searchsorted(keys, q)
    st = rops.prepare_f32_state(keys, branching=branching)
    blo, bhi = rops.rmi_bounds(st, jnp.asarray(q), interpret=True)
    blo, bhi = np.asarray(blo), np.asarray(bhi)
    assert ((blo <= lb) & (lb <= bhi)).all(), "f32 bounds must stay valid"
    pos = rops.rmi_lookup(st, jnp.asarray(keys), jnp.asarray(q), interpret=True)
    np.testing.assert_array_equal(np.asarray(pos), lb)


def test_rmi_kernel_vs_ref_inference():
    keys = sosd.generate("amzn", 30_000, seed=9)
    q = sosd.make_queries(keys, 2_000, seed=10)
    st = rops.prepare_f32_state(keys, branching=1024)
    lo_k, hi_k = rops.rmi_bounds(st, jnp.asarray(q), interpret=True)
    lo_r, hi_r = rref.rmi_bounds_ref(st, jnp.asarray(q), st.n)
    np.testing.assert_array_equal(np.asarray(lo_k), np.asarray(lo_r))
    np.testing.assert_array_equal(np.asarray(hi_k), np.asarray(hi_r))


def test_pad_pow2():
    assert pad_pow2(1) == 128
    assert pad_pow2(129) == 256
    assert pad_pow2(4096) == 4096
