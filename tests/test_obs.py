"""Observability layer (`repro.obs`, DESIGN.md §14): histograms,
rolling windows, the span recorder, the exporters, and the serve-path
integration contracts (trace-vs-histogram p99 reconciliation, the
mid-run p99 shift that windows surface and lifetime aggregates hide)."""
import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.obs.export import (JsonlMetricsLogger, MetricsServer,
                              metrics_payload, prometheus_text)
from repro.obs.trace import SpanRecorder, maybe_span
from repro.obs.windows import LatencyHistogram, WindowedMetrics
from repro.serve.lookup.metrics import ServiceMetrics


# ---------------------------------------------------------------------------
# LatencyHistogram: bisect record, quantile edges, merge
# ---------------------------------------------------------------------------
def _linear_scan_bucket(hist, seconds):
    """The pre-bisect reference: first i with seconds < bounds[i]."""
    for i, b in enumerate(hist.bounds):
        if seconds < b:
            return i
    return len(hist.bounds)


def test_bucket_index_matches_linear_scan_reference():
    h = LatencyHistogram()
    probes = [0.0, 1e-9, 1e-6, 1.05e-6, 3.7e-4, 0.01, 1.0, 80.0, 1e4]
    probes += list(h.bounds[::37])          # exact bound values too
    probes += [b * (1 + 1e-12) for b in h.bounds[::53]]
    for s in probes:
        assert h.bucket_index(s) == _linear_scan_bucket(h, s), s


def test_quantile_empty_histogram_is_zero():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0
    assert h.quantile(0.99) == 0.0
    assert h.mean == 0.0


def test_quantile_overflow_bucket_is_inf():
    h = LatencyHistogram()
    h.record(1e6)                           # way past the last bound
    assert h.quantile(0.99) == float("inf")
    # mixed: the sub-bound mass keeps sub-bound quantiles finite
    for _ in range(99):
        h.record(1e-3)
    assert h.quantile(0.50) < float("inf")
    assert h.quantile(0.999) == float("inf")


def test_histogram_merge_equals_flat_recording():
    rng = np.random.default_rng(0)
    obs = rng.lognormal(mean=-6.0, sigma=1.5, size=2_000)
    flat, a, b = LatencyHistogram(), LatencyHistogram(), LatencyHistogram()
    for i, s in enumerate(obs):
        flat.record(s)
        (a if i % 2 else b).record(s)
    a.merge(b)
    assert a.counts == flat.counts
    assert a.n == flat.n
    assert a.total_s == pytest.approx(flat.total_s)
    assert a.quantile(0.99) == flat.quantile(0.99)


def test_histogram_merge_rejects_mismatched_buckets():
    with pytest.raises(ValueError):
        LatencyHistogram().merge(LatencyHistogram(n_buckets=100))


# ---------------------------------------------------------------------------
# WindowedMetrics: merge-at-read == flat, mid-run shift, SLO burn
# ---------------------------------------------------------------------------
def test_windowed_merge_matches_flat_histogram():
    """Summing per-slot sub-histograms at read time must reproduce the
    flat histogram of the same observations exactly."""
    rng = np.random.default_rng(1)
    w = WindowedMetrics(slot_s=0.5, n_slots=64, clock=lambda: 0.0)
    flat = LatencyHistogram()
    t = 1000.0
    for s in rng.lognormal(mean=-7.0, sigma=1.0, size=3_000):
        t += rng.uniform(0, 0.01)           # spread over ~30s of slots
        w.record(s, units=3, t=t)
        flat.record(s)
    hist, units, _, _ = w.merged(window_s=w.max_window_s, t=t)
    assert hist.counts == flat.counts
    assert units == 3 * flat.n
    assert hist.quantile(0.99) == flat.quantile(0.99)


def test_windowed_snapshot_surfaces_p99_shift_lifetime_hides():
    """THE pinned §14.2 acceptance property: a mid-run latency shift is
    visible in the trailing-window p99 while the lifetime aggregate —
    dominated by the long fast prefix — still reports the old p99."""
    w = WindowedMetrics(slot_s=0.5, n_slots=240)
    lifetime = LatencyHistogram()
    fast, slow = 1e-3, 50e-3
    t = 5000.0
    for i in range(10_000):                 # long healthy prefix
        w.record(fast, t=t + i * 1e-3)
        lifetime.record(fast)
    t2 = t + 60.0                           # regression: the last ~2s
    for i in range(50):
        w.record(slow, t=t2 + i * 0.04)
        lifetime.record(slow)
    # lifetime: 50/10050 slow observations < 1% — p99 still reads fast
    assert lifetime.quantile(0.99) < 2 * fast
    # trailing window: only the regressed traffic — p99 reads the shift
    recent = w.snapshot(window_s=5.0, t=t2 + 2.0)
    assert recent["n"] == 50
    assert recent["p99_ms"] >= slow * 1e3
    # ...and the full-history window agrees with the lifetime aggregate
    full = w.snapshot(window_s=w.max_window_s, t=t2 + 2.0)
    assert full["p99_ms"] == pytest.approx(lifetime.quantile(0.99) * 1e3)


def test_windowed_slot_recycling_drops_stale_slots():
    w = WindowedMetrics(slot_s=1.0, n_slots=4, clock=lambda: 0.0)
    w.record(1e-3, t=100.0)
    assert w.snapshot(window_s=4.0, t=100.0)["n"] == 1
    # 4 slots later the ring position recycles; old slot is unreachable
    w.record(2e-3, t=104.0)
    snap = w.snapshot(window_s=4.0, t=104.0)
    assert snap["n"] == 1
    assert snap["p99_ms"] >= 2.0


def test_windowed_slo_violations_and_budget_burn():
    w = WindowedMetrics(slot_s=1.0, n_slots=16, slo_p99_ms=10.0,
                        slo_budget=0.01, clock=lambda: 0.0)
    for i in range(100):
        w.record(0.05 if i < 50 else 0.001, units=1, t=500.0 + i * 0.01)
    snap = w.snapshot(window_s=4.0, t=501.0)
    assert snap["slo_violations"] == 50
    assert snap["slo_violation_rate"] == pytest.approx(0.5)
    assert snap["slo_budget_burn"] == pytest.approx(50.0)
    assert snap["slo_p99_target_ms"] == 10.0


def test_windowed_units_rate():
    w = WindowedMetrics(slot_s=1.0, n_slots=8, clock=lambda: 0.0)
    for i in range(10):
        w.record(1e-3, units=100, t=50.0 + i * 0.1)
    snap = w.snapshot(window_s=2.0, t=51.0)
    assert snap["units"] == 1000
    assert snap["units_per_s"] == pytest.approx(500.0)


def test_windowed_concurrent_recorders_lose_nothing():
    """N threads hammer one WindowedMetrics; the merged histogram must
    hold every observation (the lock contract on the hot path)."""
    w = WindowedMetrics(slot_s=60.0, n_slots=4)
    n_threads, per_thread = 8, 2_000

    def worker(seed):
        rng = np.random.default_rng(seed)
        for s in rng.uniform(1e-4, 1e-2, size=per_thread):
            w.record(float(s))

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    hist, _, _, _ = w.merged(window_s=w.max_window_s)
    assert hist.n == n_threads * per_thread


# ---------------------------------------------------------------------------
# SpanRecorder: schema round-trip, rid reconciliation, ring bound
# ---------------------------------------------------------------------------
def test_trace_schema_roundtrip_and_rid_reconciliation():
    rec = SpanRecorder(capacity=128)
    with rec.span("launch", cat="serve", kind="read", padded=512):
        pass
    rec.instant("admit", cat="admission", rid=7, kind="read", n_keys=32)
    lat = {}
    for rid in (7, 8, 9):
        t_submit = rec.t_epoch + rid * 0.010
        t_end = t_submit + 0.002 + rid * 1e-4
        rec.request(rid, kind="read", n_keys=32, t_submit=t_submit,
                    t_launch=t_submit + 0.001, t_end=t_end)
        lat[rid] = t_end - t_submit

    # full JSON round-trip — exactly what a trace viewer would parse
    trace = json.loads(json.dumps(rec.to_chrome()))
    assert trace["otherData"]["dropped_spans"] == 0
    evs = trace["traceEvents"]
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in evs)
    cats = {e.get("cat") for e in evs if e["ph"] != "M"}
    assert {"serve", "admission", "request"} <= cats
    for e in evs:
        assert e["pid"] == 0
        if e["ph"] == "X":
            assert e["dur"] >= 0 and "ts" in e
        if e["ph"] == "i":
            assert e["s"] == "t"

    # rid -> latency parsed back from the µs export matches what went in
    got = SpanRecorder.request_latencies_s(trace)
    assert set(got) == {7, 8, 9}
    for rid, s in lat.items():
        assert got[rid] == pytest.approx(s, abs=1e-8)
    # the queue/exec decomposition sums to the span duration
    for e in SpanRecorder.request_events(trace):
        a = e["args"]
        assert a["queue_us"] + a["exec_us"] == pytest.approx(e["dur"],
                                                             abs=1e-2)


def test_trace_ring_bound_reports_drops():
    rec = SpanRecorder(capacity=8)
    for i in range(20):
        rec.instant("tick", cat="serve", i=i)
    assert len(rec) == 8
    assert rec.n_dropped == 12
    trace = rec.to_chrome()
    assert trace["otherData"]["dropped_spans"] == 12
    assert trace["otherData"]["recorded_spans"] == 20
    # oldest dropped, newest kept
    kept = [e["args"]["i"] for e in trace["traceEvents"] if e["ph"] == "i"]
    assert kept == list(range(12, 20))


def test_trace_concurrent_recording_counts_every_span():
    rec = SpanRecorder(capacity=100_000)
    n_threads, per_thread = 8, 2_000

    def worker(k):
        for i in range(per_thread):
            with rec.span("w", cat="serve", k=k, i=i):
                pass

    ts = [threading.Thread(target=worker, args=(k,)) for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert rec.n_recorded == n_threads * per_thread
    assert len(rec) == n_threads * per_thread
    # every tid that recorded a span has a thread_name metadata event
    # (the OS may recycle thread idents, so distinct-count can be < N)
    trace = rec.to_chrome()
    meta_tids = {e["tid"] for e in trace["traceEvents"] if e["ph"] == "M"}
    span_tids = {e["tid"] for e in trace["traceEvents"] if e["ph"] != "M"}
    assert span_tids <= meta_tids


def test_maybe_span_none_is_noop():
    with maybe_span(None, "anything", cat="serve", x=1):
        pass
    rec = SpanRecorder()
    with maybe_span(rec, "real", cat="lifecycle"):
        pass
    assert len(rec) == 1 and rec.spans()[0].cat == "lifecycle"


# ---------------------------------------------------------------------------
# ServiceMetrics satellites: insert-only window, per-request recording
# ---------------------------------------------------------------------------
def test_insert_only_snapshot_has_live_window_and_rate():
    """Regression (satellite 2): insert-only traffic used to read as a
    zero window — lookups_per_s guarded on n_batches — so an all-write
    service reported nothing."""
    m = ServiceMetrics()
    m.observe_insert_batch(n_keys=500, admitted=480, t_start=10.0,
                           t_end=10.5)
    m.observe_insert_batch(n_keys=500, admitted=490, t_start=11.0,
                           t_end=12.0)
    snap = m.snapshot()
    assert snap["insert_keys"] == 1000
    assert snap["inserts_per_s"] == pytest.approx(1000 / 2.0)
    assert snap["lookups_per_s"] == 0.0     # no reads: rate 0, not NaN
    assert snap["mean_insert_ms"] > 0.0


def test_observe_batch_per_request_matches_trace_semantics():
    """per_request recording puts the same (t_submit, t_end) pairs into
    the histogram that `SpanRecorder.request` gets — so a trace-derived
    p99 and the snapshot p99 are the same distribution by construction."""
    m = ServiceMetrics()
    rec = SpanRecorder()
    t_end = 100.0
    per_request = []
    for rid in range(200):
        t_submit = t_end - (0.001 + rid * 1e-4)   # spread of latencies
        per_request.append((t_submit, 32))
        rec.request(rid, kind="read", n_keys=32, t_submit=t_submit,
                    t_launch=t_submit + 1e-4, t_end=t_end)
    m.observe_batch(n_keys=200 * 32, padded=8192, n_requests=200,
                    t_oldest_submit=per_request[-1][0], t_start=t_end - 1e-3,
                    t_end=t_end, per_request=per_request)
    lats = np.asarray(sorted(
        SpanRecorder.request_latencies_s(rec.to_chrome()).values()))
    trace_p99 = float(np.quantile(lats, 0.99, method="higher"))
    h = m.request_latency
    assert abs(h.bucket_index(trace_p99)
               - h.bucket_index(m.snapshot()["p99_request_ms"] / 1e3)) <= 1
    assert h.n == 200                        # one record per request
    # windowed ring saw the same per-request units (read at the same
    # synthetic completion time the observations were stamped with)
    _, units, _, _ = m.windows.merged(m.windows.max_window_s, t=t_end)
    assert units == 200 * 32


# ---------------------------------------------------------------------------
# exporters: Prometheus text, HTTP endpoints, JSONL
# ---------------------------------------------------------------------------
class _FakeProvider:
    def __init__(self, with_recorder=True):
        import time

        self.metrics = ServiceMetrics(slo_p99_ms=10.0)
        # real-clock timestamps: the windowed read uses perf_counter
        # "now", so observations must land inside the trailing window
        now = time.perf_counter()
        self.metrics.observe_batch(
            n_keys=64, padded=128, n_requests=2,
            t_oldest_submit=now - 2e-3, t_start=now - 1e-3, t_end=now,
            per_request=[(now - 2e-3, 32), (now - 1.5e-3, 32)])
        self.recorder = SpanRecorder() if with_recorder else None
        if self.recorder is not None:   # empty recorder is len()==0 falsy
            self.recorder.instant("admit", cat="admission", rid=0)


def test_prometheus_text_format():
    text = prometheus_text({"p99_ms": 1.5, "n": 3, "name": "rmi",
                            "ok": True}, labels={"ds": "amzn"})
    lines = text.strip().splitlines()
    assert "# TYPE repro_lookup_p99_ms gauge" in lines
    assert 'repro_lookup_p99_ms{ds="amzn"} 1.5' in lines
    assert 'repro_lookup_ok{ds="amzn"} 1' in lines
    assert not any("name" in ln and "rmi" in ln for ln in lines)  # non-numeric


def test_metrics_payload_contract():
    p = metrics_payload(_FakeProvider(), window_s=60.0)
    assert p["lifetime"]["requests"] == 2
    assert p["windowed"]["n"] == 2
    assert p["trace_spans"] == 1 and p["trace_dropped"] == 0


def test_metrics_server_endpoints():
    prov = _FakeProvider()
    with MetricsServer(prov, port=0) as srv:
        base = f"http://127.0.0.1:{srv.port}"

        def get(path):
            with urllib.request.urlopen(base + path, timeout=10) as r:
                return r.status, r.read().decode()

        status, text = get("/metrics")
        assert status == 200
        assert "repro_lookup_p99_request_ms" in text
        assert "repro_lookup_window_p99_ms" in text     # windowed block

        status, body = get("/metrics.json?window_s=120")
        doc = json.loads(body)
        assert status == 200 and doc["lifetime"]["lookups"] == 64

        status, body = get("/trace.json")
        assert status == 200
        assert json.loads(body)["otherData"]["dropped_spans"] == 0

        status, body = get("/healthz")
        assert status == 200 and body == "ok\n"
        with pytest.raises(urllib.error.HTTPError) as ei:
            get("/nope")
        assert ei.value.code == 404


def test_metrics_server_trace_404_when_disabled():
    with MetricsServer(_FakeProvider(with_recorder=False), port=0) as srv:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/trace.json", timeout=10)
        assert ei.value.code == 404


def test_jsonl_logger_appends_parseable_lines(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    logger = JsonlMetricsLogger(_FakeProvider(), path, interval_s=60.0)
    logger.write_once()
    logger.write_once()
    with open(path) as f:
        docs = [json.loads(ln) for ln in f]
    assert len(docs) == 2 == logger.n_written
    assert all(d["lifetime"]["requests"] == 2 for d in docs)
    # start/stop writes the final snapshot even if the interval never fired
    with JsonlMetricsLogger(_FakeProvider(), path, interval_s=60.0):
        pass
    with open(path) as f:
        assert len(f.readlines()) == 3


# ---------------------------------------------------------------------------
# end-to-end: a traced LookupService reconciles trace vs histogram
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("executor", ["sync", "async"])
def test_traced_service_reconciles_p99_and_ids(executor):
    """Serve real traffic with tracing on: every submitted request id
    appears exactly once as a request span, and the span-derived p99
    lands within one histogram bucket of the metrics-snapshot p99 (the
    §14 acceptance bound — same requests, two recording paths)."""
    from repro.data import sosd
    from repro.serve.lookup import LookupService, LookupServiceConfig

    keys = sosd.generate("amzn", 30_000, seed=3)
    q = sosd.make_queries(keys, 3_200, seed=5)
    svc = LookupService(keys, LookupServiceConfig(
        index="rmi", hyper=dict(branching=512), max_batch=256,
        deadline_ms=1.0, executor=executor, trace=True, slo_p99_ms=5000.0))
    with svc:
        futs = [svc.submit(q[i:i + 64]) for i in range(0, len(q), 64)]
        for f in futs:
            f.result(timeout=60.0)

    trace = json.loads(json.dumps(svc.recorder.to_chrome()))
    lat = SpanRecorder.request_latencies_s(trace)
    assert len(lat) == len(futs)            # one span per request, by rid
    # admission instants carry the same rids the request spans close out
    admits = {e["args"]["rid"] for e in trace["traceEvents"]
              if e.get("cat") == "admission" and e["ph"] == "i"}
    assert admits == set(lat)
    snap = svc.metrics.snapshot()
    trace_p99 = float(np.quantile(np.asarray(sorted(lat.values())), 0.99,
                                  method="higher"))
    h = svc.metrics.request_latency
    assert h.n == len(futs)
    assert abs(h.bucket_index(trace_p99)
               - h.bucket_index(snap["p99_request_ms"] / 1e3)) <= 1
    # the windowed surface saw the same traffic (full-history window)
    w = svc.metrics.windowed(window_s=svc.metrics.windows.max_window_s)
    assert w["lookups"] == len(q)
    # a target generous vs the first batch's compile (the sync path pays
    # first-touch lowering of the instrumented executable in-band, §15)
    # burns nothing
    assert w["slo_violations"] == 0
    # serve-side spans exist for the executor that ran
    cats = {e.get("cat") for e in trace["traceEvents"] if e["ph"] != "M"}
    assert "serve" in cats and "admission" in cats


def test_traced_mutable_service_records_insert_and_compaction_spans():
    from repro.data import sosd
    from repro.serve.lookup.mutable_service import (
        MutableLookupService, MutableLookupServiceConfig)

    keys = sosd.generate("wiki", 20_000, seed=9)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        index="rmi", hyper=dict(branching=256), max_batch=512,
        deadline_ms=1.0, compact_threshold=1_000, auto_compact=False,
        trace=True))
    new_keys = (np.asarray(keys[:1500], dtype=np.uint64) + 1).astype(
        np.uint64)
    with svc:
        svc.insert(new_keys).result(timeout=60.0)
        svc.submit(keys[:64]).result(timeout=60.0)
        svc.force_compact()

    spans = svc.recorder.spans()
    by_name = {}
    for s in spans:
        by_name.setdefault(s.name, []).append(s)
    req_kinds = {s.args["kind"] for s in by_name["request"]}
    assert {"insert", "read"} <= req_kinds
    assert "compaction" in by_name          # lifecycle span, cat check:
    assert by_name["compaction"][0].cat == "lifecycle"
    assert "index_build" in by_name         # the compaction's rebuild
    assert "publish" in by_name             # ...and its hot-swap
