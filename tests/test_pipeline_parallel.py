"""Pipeline parallelism: exact parity with the sequential stack.

The real-mesh test needs >1 device, so it runs in a subprocess with
placeholder devices (the same trick as the dry-run; pytest itself stays
single-device).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist.pipeline_parallel import (bubble_fraction, pipeline_apply,
                                          sequential_apply)


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 4) - 3 / 7) < 1e-9
    assert bubble_fraction(4, 28) < 0.1


def test_single_stage_parity():
    """P=1 degenerates to the sequential scan (runs on the one CPU dev)."""
    mesh = jax.make_mesh((1,), ("model",))
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(0, 0.1, (4, 16, 16)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (3, 8, 16)).astype(np.float32))

    def body(a, w):
        return jnp.tanh(a @ w)

    got = pipeline_apply(body, ws, x, mesh)
    ref = sequential_apply(body, ws, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp, sys
sys.path.insert(0, "src")
from repro.dist.pipeline_parallel import pipeline_apply, sequential_apply

mesh = jax.make_mesh((4,), ("model",))
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.normal(0, 0.1, (8, 16, 16)).astype(np.float32))
x = jnp.asarray(rng.normal(0, 1, (6, 8, 16)).astype(np.float32))

def body(a, w):
    return jnp.tanh(a @ w)

got = pipeline_apply(body, ws, x, mesh)
ref = sequential_apply(body, ws, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)
# the lowering must contain collective-permute (the PP boundary transfer)
txt = jax.jit(lambda w, xx: pipeline_apply(body, w, xx, mesh)).lower(ws, x).compile().as_text()
assert "collective-permute" in txt, "expected ppermute boundary transfers"
print("PP_OK")
"""


def test_four_stage_parity_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert "PP_OK" in r.stdout, r.stdout + "\n" + r.stderr
