"""MoE dispatch + SSD correctness against independent oracles."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import moe, mamba2
from repro.models.config import ModelConfig


def _moe_cfg(**kw):
    base = get_smoke("deepseek-moe-16b")
    return dataclasses.replace(base, **kw)


def _moe_oracle(cfg, p, x2d):
    """Straightforward per-token loop oracle (no capacity drops)."""
    logits = x2d.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = np.zeros(x2d.shape, np.float32)
    xs = np.asarray(x2d, np.float32)
    wi = np.asarray(p["wi"], np.float32)
    wg = np.asarray(p["wg"], np.float32)
    wo = np.asarray(p["wo"], np.float32)
    for t in range(x2d.shape[0]):
        for c in range(cfg.top_k):
            e = int(top_i[t, c])
            h = xs[t] @ wi[e]
            g = xs[t] @ wg[e]
            act = (g / (1 + np.exp(-g))) * h
            out[t] += float(top_p[t, c]) * (act @ wo[e])
    return out


def test_sorted_dispatch_matches_oracle():
    cfg = _moe_cfg(capacity_factor=8.0, n_shared_experts=0, dtype="float32")
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, cfg.d_model),
                          jnp.float32) * 0.3
    out, _ = moe.moe_ffn(cfg, p, x)
    ref = _moe_oracle(cfg, p, x.reshape(24, -1))
    np.testing.assert_allclose(np.asarray(out).reshape(24, -1), ref,
                               rtol=2e-3, atol=2e-3)


def test_dense_dispatch_matches_oracle():
    cfg = _moe_cfg(moe_dispatch="dense", n_shared_experts=0, dtype="float32")
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, cfg.d_model),
                          jnp.float32) * 0.3
    out, _ = moe.moe_ffn(cfg, p, x)
    ref = _moe_oracle(cfg, p, x.reshape(24, -1))
    np.testing.assert_allclose(np.asarray(out).reshape(24, -1), ref,
                               rtol=2e-3, atol=2e-3)


def test_capacity_drops_reduce_output():
    """With capacity ~0 the MoE contribution must shrink (drops).

    Needs enough tokens that the 8-slot/expert capacity floor actually
    binds: 512 tokens x top2 = 1024 assignments >> 8 experts x 8 slots.
    """
    cfg = _moe_cfg(capacity_factor=1e-9, n_shared_experts=0, dtype="float32")
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, cfg.d_model),
                          jnp.float32)
    out, _ = moe.moe_ffn(cfg, p, x)
    cfg_full = _moe_cfg(capacity_factor=8.0, n_shared_experts=0,
                        dtype="float32")
    out_full, _ = moe.moe_ffn(cfg_full, p, x)
    assert float(jnp.linalg.norm(out)) < 0.5 * float(jnp.linalg.norm(out_full))


def test_aux_losses_positive_and_balanced():
    cfg = _moe_cfg(dtype="float32")
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (64, cfg.d_model), jnp.float32)
    _, _, aux = moe._router(cfg, p, x)
    assert float(aux) > 0
    # perfectly-balanced router ~ aux_coef * 1 + z-term
    assert float(aux) < 1.0


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
def _ssd_naive(xh, Bm, Cm, dt, A_log, D):
    """Token-by-token linear recurrence oracle."""
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    a = -np.exp(np.asarray(A_log, np.float64))
    hstate = np.zeros((b, h, n, p), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    x64 = np.asarray(xh, np.float64)
    B64 = np.asarray(Bm, np.float64)
    C64 = np.asarray(Cm, np.float64)
    dt64 = np.asarray(dt, np.float64)
    for t in range(s):
        da = np.exp(dt64[:, t] * a[None])                    # [b,h]
        upd = np.einsum("bh,bn,bhp->bhnp", dt64[:, t], B64[:, t], x64[:, t])
        hstate = hstate * da[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", C64[:, t], hstate)
    return ys + x64 * np.asarray(D)[None, None, :, None]


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (48, 16)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    cfg = dataclasses.replace(get_smoke("mamba2-2.7b"), ssm_chunk=chunk)
    rng = jax.random.PRNGKey(0)
    b, h, p, n = 2, 4, 8, 16
    ks = jax.random.split(rng, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
    Bm = jax.random.normal(ks[1], (b, s, n), jnp.float32) * 0.5
    Cm = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h), jnp.float32))
    A_log = jnp.zeros((h,))
    D = jnp.ones((h,))
    got = np.asarray(mamba2.ssd_chunked(cfg, xh, Bm, Cm, dt, A_log, D))
    ref = _ssd_naive(xh, Bm, Cm, dt, A_log, D)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_prefill():
    """Running the layer token-by-token must equal the chunked scan."""
    cfg = get_smoke("mamba2-2.7b")
    p = mamba2.init_mamba(cfg, jax.random.PRNGKey(0))
    s = 24
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model),
                          jnp.float32).astype(jnp.dtype(cfg.dtype))
    full = mamba2.mamba_layer(cfg, p, x)
    ssm = jnp.zeros((2, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                    jnp.float32)
    conv = jnp.zeros((2, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state),
                     jnp.dtype(cfg.dtype))
    outs = []
    for t in range(s):
        y, ssm, conv = mamba2.mamba_decode(cfg, p, x[:, t:t + 1], ssm, conv)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)
