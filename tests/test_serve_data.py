"""Serving (paged KV + engine) and data pipeline (packing) tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import model as M
from repro.serve.kv_cache import PageAllocator, PagedKVCache, LearnedSlotIndex
from repro.serve.engine import ServeEngine
from repro.data.packing import PackedIndex, pack_documents
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.data import sosd


# ---------------------------------------------------------------------------
# paged KV cache
# ---------------------------------------------------------------------------
def test_page_allocator_alloc_release():
    a = PageAllocator(16, 8)
    p1 = a.alloc(0, 5)
    assert len(p1) == 5 and a.utilization == 5 / 16
    a.release(p1)
    assert a.utilization == 0.0
    with pytest.raises(MemoryError):
        a.alloc(1, 17)


def test_paged_kv_table_and_gather():
    kv = PagedKVCache(n_pages=32, page_size=4, max_seqs=4,
                      max_pages_per_seq=8)
    kv.add_sequence(0, 10)           # 3 pages
    kv.add_sequence(1, 4)            # 1 page
    for _ in range(5):
        kv.append_token(1)           # crosses a page boundary
    spec = kv.gather_spec(np.array([0, 1]))
    assert spec.shape[0] == 2
    # positions map to distinct physical slots
    flat = spec[spec >= 0]
    assert len(np.unique(flat)) == len(flat)
    kv.free_sequence(0)
    assert 0 not in kv.pages


def test_learned_slot_index_exact():
    rng = np.random.default_rng(0)
    lens = rng.integers(1, 100, 50)
    cum = np.concatenate([[0], np.cumsum(lens)])
    idx = LearnedSlotIndex(cum)
    slots = rng.integers(0, cum[-1], 500).astype(np.int32)
    got = np.asarray(idx.lookup(jnp.asarray(slots)))
    ref = np.searchsorted(cum, slots, side="right") - 1
    np.testing.assert_array_equal(got, ref)


def test_serve_engine_generates():
    cfg = get_smoke("granite-3-2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=64, page_size=8)
    r1 = eng.submit([5, 6, 7], max_new=4)
    r2 = eng.submit([9, 10], max_new=3)
    outs = eng.run(max_steps=16)
    assert len(outs[r1]) == 4
    assert len(outs[r2]) == 3
    assert all(0 <= t < cfg.vocab for t in outs[r1])
    assert eng.kv.alloc.utilization == 0.0  # everything released
    # rids are never reused, even after every request retired (the old
    # queue/active-size formula would hand r3 the value of r1 again)
    r3 = eng.submit([4, 5], max_new=2)
    assert len({r1, r2, r3}) == 3


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_packed_index_matches_oracle():
    rng = np.random.default_rng(3)
    lens = rng.integers(1, 2000, 5000)
    pi = PackedIndex(lens)
    offsets = rng.integers(0, pi.total, 20_000)
    d1, w1 = pi.locate(offsets)
    d2, w2 = pi.locate_oracle(offsets)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(w1, w2)


def test_pack_documents_rows():
    docs = [[2, 3, 4], [5, 6], [7, 8, 9, 10, 11]]
    rows = list(pack_documents(docs, seq_len=4, pad_id=0, eod_id=1))
    flat = np.concatenate(rows)
    # all tokens present, separators inserted, fixed-length rows
    assert all(len(r) == 4 for r in rows)
    for d in docs:
        for t in d:
            assert t in flat


def test_pipeline_deterministic_and_resumable():
    cfg = PipelineConfig(vocab=100, seq_len=16, global_batch=4, seed=42)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b5a = p1.batch(5)
    b5b = p2.batch(5)   # fresh pipeline, same step -> same batch
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert b5a["tokens"].shape == (4, 16)
    assert (b5a["tokens"] >= 2).all() and (b5a["tokens"] < 100).all()
    # next-token alignment
    np.testing.assert_array_equal(p1.batch(0)["tokens"][:, 1:],
                                  p1.batch(0)["labels"][:, :-1])


def test_pipeline_host_sharding():
    kw = dict(vocab=50, seq_len=8, global_batch=8, seed=1, n_hosts=2)
    h0 = TokenPipeline(PipelineConfig(host_id=0, **kw))
    h1 = TokenPipeline(PipelineConfig(host_id=1, **kw))
    b0, b1 = h0.batch(3), h1.batch(3)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------------------------
# SOSD surrogates
# ---------------------------------------------------------------------------
def test_sosd_generators_contract():
    for name in sosd.DATASETS:
        keys = sosd.generate(name, 20_000, seed=5)
        assert len(keys) == 20_000
        assert keys.dtype == np.uint64
        assert (np.diff(keys.astype(np.float64)) > 0).all() or (
            len(np.unique(keys)) == len(keys))
        again = sosd.generate(name, 20_000, seed=5)
        np.testing.assert_array_equal(keys, again)


def test_sosd_face_has_outliers():
    keys = sosd.generate("face", 20_000, seed=5)
    assert keys[-1] > np.uint64(1) << np.uint64(59)
    assert np.mean(keys < (np.uint64(1) << np.uint64(50))) > 0.99


def test_sosd_osm_harder_than_wiki():
    """The paper's osm pathology: more PLA segments at equal eps."""
    from repro.core import _pla
    osm = sosd.generate("osm", 30_000, seed=5)
    wiki = sosd.generate("wiki", 30_000, seed=5)
    n_osm = len(_pla.shrinking_cone(osm.astype(np.float64),
                                    np.arange(30_000.0), 32.0)[0])
    n_wiki = len(_pla.shrinking_cone(wiki.astype(np.float64),
                                     np.arange(30_000.0), 32.0)[0])
    assert n_osm > 2 * n_wiki, (n_osm, n_wiki)
