"""Workload generator + mutable-index tests (DESIGN.md §10).

The centerpiece is the mutable-index INVARIANT: for every LB-capable
index type x dataset, an interleaved insert/read/compact trace returns
positions identical to a plain sorted-array `lower_bound_oracle` replay
at every step — including across hot-swap compactions with in-flight
batches on the service path.
"""
import threading

import numpy as np
import pytest

from repro.core import base
from repro.core import spec as core_spec
from repro.data import sosd
from repro import workloads
from repro.workloads import (MIXES, OP_INSERT, OP_RANGE, OP_READ, Workload,
                             make_point_queries, make_workload, oracle_replay,
                             replay_on_service)
from repro.mutable import (LB_INDEXES, DeltaBuffer, MutableIndex, UINT64_MAX)
from repro.serve.lookup import (MutableLookupService,
                                MutableLookupServiceConfig)


# ---------------------------------------------------------------------------
# workload generator: determinism, trace format, mixes, distributions
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def wl_keys():
    return sosd.generate("amzn", 20_000, seed=1)


def test_workload_seed_determinism(wl_keys):
    a = make_workload(wl_keys, 800, mix="ycsb_b", dist="zipfian", seed=4)
    b = make_workload(wl_keys, 800, mix="ycsb_b", dist="zipfian", seed=4)
    c = make_workload(wl_keys, 800, mix="ycsb_b", dist="zipfian", seed=5)
    np.testing.assert_array_equal(a.ops, b.ops)
    np.testing.assert_array_equal(a.keys, b.keys)
    np.testing.assert_array_equal(a.aux, b.aux)
    assert not np.array_equal(a.keys, c.keys)


def test_workload_trace_roundtrip(tmp_path, wl_keys):
    wl = make_workload(wl_keys, 300, mix="ycsb_e", dist="sequential", seed=9)
    path = str(tmp_path / "trace.npz")
    wl.save(path)
    back = Workload.load(path)
    np.testing.assert_array_equal(wl.ops, back.ops)
    np.testing.assert_array_equal(wl.keys, back.keys)
    np.testing.assert_array_equal(wl.aux, back.aux)
    assert back.meta["mix"] == "ycsb_e" and back.meta["seed"] == 9


def test_workload_mix_fractions_and_aux(wl_keys):
    wl = make_workload(wl_keys, 4_000, mix="ycsb_a", dist="uniform", seed=2)
    counts = wl.counts()
    assert counts["range"] == 0
    assert abs(counts["insert"] / wl.n_ops - 0.5) < 0.05
    wle = make_workload(wl_keys, 4_000, mix="ycsb_e", dist="uniform", seed=2,
                        range_len=32)
    assert wle.counts()["read"] == 0
    assert (wle.aux[wle.ops == OP_RANGE] == 32).all()
    assert (wle.aux[wle.ops != OP_RANGE] == 0).all()
    with pytest.raises(ValueError):
        make_workload(wl_keys, 10, mix={"read": 0.0})
    # custom dict mixes normalize
    wlc = make_workload(wl_keys, 2_000, mix={"read": 3, "insert": 1}, seed=3)
    assert abs(wlc.counts()["insert"] / 2_000 - 0.25) < 0.05


def test_zipfian_skew_exceeds_uniform(wl_keys):
    rng_z = np.random.default_rng(0)
    rng_u = np.random.default_rng(0)
    n = len(wl_keys)
    z = workloads.zipfian_ranks(rng_z, 20_000, n)
    u = workloads.uniform_ranks(rng_u, 20_000, n)
    top_z = np.bincount(z, minlength=n).max()
    top_u = np.bincount(u, minlength=n).max()
    assert top_z > 10 * top_u          # theta=0.99 is heavily skewed
    assert z.min() >= 0 and z.max() < n


def test_hot_set_concentration():
    rng = np.random.default_rng(3)
    r = workloads.hot_set_ranks(rng, 30_000, 10_000,
                                hot_frac=0.01, hot_weight=0.9)
    freq = np.bincount(r, minlength=10_000)
    hot_mass = np.sort(freq)[::-1][:100].sum() / 30_000
    assert 0.8 < hot_mass <= 1.0       # ~90% of accesses on 1% of keys


def test_sequential_ranks_wrap():
    rng = np.random.default_rng(1)
    r = workloads.sequential_ranks(rng, 500, 100, stride=3)
    assert ((np.diff(r) - 3) % 100 == 0).all()
    assert r.max() < 100


def test_present_absent_fractions(wl_keys):
    wl = make_workload(wl_keys, 5_000, mix="read_only", dist="uniform",
                       seed=6, present_frac=0.5)
    present = np.isin(wl.keys, wl_keys).mean()
    assert 0.4 < present < 0.6


def test_make_queries_bitstream_unchanged(wl_keys):
    """`sosd.make_queries` now delegates to repro.workloads; the uniform
    stream must be bit-identical to the historical in-line sampler."""
    m, seed, frac = 3_000, 11, 0.8
    rng = np.random.default_rng(seed + 1)         # the legacy algorithm
    n_present = int(m * frac)
    present = wl_keys[rng.integers(0, len(wl_keys), n_present)]
    lo, hi = int(wl_keys[0]), int(wl_keys[-1])
    absent = rng.integers(max(lo - 1000, 0), hi + 1000, size=m - n_present,
                          dtype=np.uint64)
    legacy = np.concatenate([present, absent])
    rng.shuffle(legacy)
    legacy = legacy.astype(np.uint64)

    np.testing.assert_array_equal(
        sosd.make_queries(wl_keys, m, seed=seed, present_frac=frac), legacy)
    np.testing.assert_array_equal(
        make_point_queries(wl_keys, m, seed=seed + 1, present_frac=frac),
        legacy)


def test_oracle_replay_read_only_matches_searchsorted(wl_keys):
    wl = make_workload(wl_keys, 400, mix="read_only", dist="hot_set", seed=8)
    out = oracle_replay(wl_keys, wl)
    np.testing.assert_array_equal(out, np.searchsorted(wl_keys, wl.keys))


# ---------------------------------------------------------------------------
# delta buffer
# ---------------------------------------------------------------------------
def test_delta_buffer_dedup_and_merge():
    base_np = np.array([10, 20, 30], np.uint64)
    d = DeltaBuffer.empty()
    assert d.count == 0 and int(d.device.shape[0]) == 128
    d, adm = d.with_inserted(base_np, np.array([20, 5, 5, 40], np.uint64))
    np.testing.assert_array_equal(adm, [0, 1, 0, 1])   # in-base, fresh, dup, fresh
    np.testing.assert_array_equal(d.keys_np, [5, 40])
    d2, adm2 = d.with_inserted(base_np, np.array([5], np.uint64))
    np.testing.assert_array_equal(adm2, [0])           # already in delta
    assert d2 is d                                     # no-op reuses snapshot

    snap = d
    d3, _ = d.with_inserted(base_np, np.array([25], np.uint64))
    left = d3.minus(snap)
    np.testing.assert_array_equal(left.keys_np, [25])  # mid-rebuild inserts kept


def test_delta_buffer_pad_growth_and_sentinel():
    base_np = np.array([1], np.uint64)
    d = DeltaBuffer.empty()
    d, adm = d.with_inserted(base_np, np.arange(2, 202, dtype=np.uint64))
    assert adm.sum() == 200 and d.count == 200
    assert int(d.device.shape[0]) == 256               # next pow2 bucket
    dev = np.asarray(d.device)
    assert (dev[200:] == UINT64_MAX).all()
    assert (np.diff(dev[:200].astype(np.float64)) > 0).all()


# ---------------------------------------------------------------------------
# the mutable-index invariant: every LB index type x dataset
# ---------------------------------------------------------------------------
def _step_checked_replay(mi, keys, wl, compact_at=()):
    """Apply the trace op by op; after EVERY op the result must equal the
    naive sorted-array replay.  `compact_at` forces hot-swap compactions
    at those op indices — results must be unaffected."""
    arr = np.asarray(keys, np.uint64).copy()
    for i in range(wl.n_ops):
        k = np.array([wl.keys[i]], np.uint64)
        if wl.ops[i] == OP_INSERT:
            admitted = int(mi.insert(k)[0])
            p = int(np.searchsorted(arr, k[0], side="left"))
            fresh = not (p < arr.size and arr[p] == k[0])
            assert admitted == int(fresh), f"op {i}: admit flag"
            if fresh:
                arr = np.insert(arr, p, k[0])
        else:
            pos = int(mi.lookup(k)[0])
            exp = int(np.searchsorted(arr, k[0], side="left"))
            assert pos == exp, (f"op {i} ({wl.meta}): merged LB {pos} != "
                                f"oracle {exp} (delta={mi.delta_count})")
        if i in compact_at:
            mi.compact()
    return arr


@pytest.mark.parametrize("index", LB_INDEXES)
@pytest.mark.parametrize("dataset", sorted(sosd.DATASETS))
def test_mutable_invariant_every_index_and_dataset(index, dataset):
    keys = sosd.generate(dataset, 2_500, seed=5)
    hyper = {"rmi": dict(branching=128), "pgm": dict(eps=32),
             "radix_spline": dict(eps=16, radix_bits=10)}.get(index, {})
    mi = MutableIndex(keys, index=index, hyper=hyper,
                      compact_threshold=1 << 30)   # compactions forced below
    wl = make_workload(keys, 120, mix="ycsb_a", dist="zipfian", seed=17,
                       present_frac=0.8)
    final = _step_checked_replay(mi, keys, wl, compact_at={40, 90})
    # after the trace the merged view IS the oracle array
    assert mi.view().n_keys == final.size
    gen = mi.compact()
    assert gen is not None and mi.delta_count == 0
    np.testing.assert_array_equal(mi.view().base_np, final)


def test_mutable_index_uint64_max_key():
    keys = np.arange(10, 5_010, dtype=np.uint64)
    mi = MutableIndex(keys, index="rmi", hyper=dict(branching=64),
                      compact_threshold=1 << 30)
    top = np.array([UINT64_MAX], np.uint64)
    assert mi.insert(top)[0] == 1
    assert int(mi.lookup(top)[0]) == len(keys)     # LB of the new last key
    assert mi.insert(top)[0] == 0                  # sentinel-valued, still deduped
    mi.compact()
    assert mi.view().base_np[-1] == UINT64_MAX
    assert int(mi.lookup(top)[0]) == len(keys)


def test_compaction_preserves_inserts_admitted_mid_rebuild():
    """Keys admitted while a compaction is rebuilding must survive the
    publish (the leftover-delta diff) — pinned with a slow builder."""
    keys = sosd.generate("wiki", 4_000, seed=3)
    mi = MutableIndex(keys, index="rmi", hyper=dict(branching=128),
                      compact_threshold=1 << 30)
    gap = int(np.flatnonzero(np.diff(keys) > 2)[0])  # room for two new keys
    first = np.array([keys[gap] + 1], np.uint64)
    assert mi.insert(first)[0] == 1

    in_build, release = threading.Event(), threading.Event()
    real_build = base.REGISTRY["rmi"]

    @base.register("_test_slow_rmi2")
    def slow_build(k, **h):                        # noqa: ANN001
        in_build.set()
        assert release.wait(10.0)
        return real_build(k, **h)

    core_spec.register_schema("_test_slow_rmi2",
                              fields=core_spec.SCHEMAS["rmi"].fields,
                              ladder=[dict()])
    try:
        mi.spec = mi.spec.replace(index="_test_slow_rmi2")
        t = threading.Thread(target=mi.compact)
        t.start()
        assert in_build.wait(10.0)
        late = np.array([keys[gap] + 2], np.uint64)  # admitted mid-rebuild
        assert mi.insert(late)[0] == 1
        release.set()
        t.join(timeout=30.0)
    finally:
        release.set()
        base.REGISTRY.pop("_test_slow_rmi2", None)
        core_spec.SCHEMAS.pop("_test_slow_rmi2", None)
        mi.spec = mi.spec.replace(index="rmi")
    assert mi.delta_count == 1                     # late key survived
    np.testing.assert_array_equal(mi.view().delta.keys_np, late)
    assert first[0] in mi.view().base_np           # snapshot key folded in
    q = np.sort(np.concatenate([first, late]))
    merged = np.sort(np.concatenate([keys, q]))
    np.testing.assert_array_equal(mi.lookup(q),
                                  np.searchsorted(merged, q))


def test_reset_during_compaction_discards_stale_rebuild():
    """A reset() landing mid-rebuild must win: the finished compaction
    detects its snapshot is stale and drops the rebuilt generation
    instead of resurrecting the discarded key set."""
    old_keys = sosd.generate("amzn", 3_000, seed=1)
    new_keys = sosd.generate("osm", 2_000, seed=2)
    mi = MutableIndex(old_keys, index="rmi", hyper=dict(branching=128),
                      compact_threshold=1 << 30)
    mi.insert(np.array([old_keys[0] + 1], np.uint64))

    in_build, release = threading.Event(), threading.Event()
    real_build = base.REGISTRY["rmi"]

    @base.register("_test_slow_rmi3")
    def slow_build(k, **h):                        # noqa: ANN001
        in_build.set()
        assert release.wait(10.0)
        return real_build(k, **h)

    core_spec.register_schema("_test_slow_rmi3",
                              fields=core_spec.SCHEMAS["rmi"].fields,
                              ladder=[dict()])
    results = []
    try:
        mi.spec = mi.spec.replace(index="_test_slow_rmi3")
        t = threading.Thread(target=lambda: results.append(mi.compact()))
        t.start()
        assert in_build.wait(10.0)
        mi.spec = mi.spec.replace(index="rmi")
        mi.reset(new_keys)                         # whole-key-set swap
        release.set()
        t.join(timeout=30.0)
    finally:
        release.set()
        base.REGISTRY.pop("_test_slow_rmi3", None)
        core_spec.SCHEMAS.pop("_test_slow_rmi3", None)
    assert results == [None]                       # rebuild was abandoned
    np.testing.assert_array_equal(mi.view().base_np, new_keys)
    assert mi.delta_count == 0
    q = new_keys[::97]
    np.testing.assert_array_equal(mi.lookup(q),
                                  np.searchsorted(new_keys, q))


def test_workload_generation_over_uint64_max_keys():
    """Key sets containing UINT64_MAX (legal after a compaction folds a
    max-key insert) must not overflow the absent-draw bounds."""
    keys = np.concatenate([np.arange(10, 2_010, dtype=np.uint64),
                           np.array([UINT64_MAX], np.uint64)])
    wl = make_workload(keys, 400, mix="ycsb_a", dist="uniform", seed=1,
                       present_frac=0.5)
    assert wl.n_ops == 400
    q = make_point_queries(keys, 300, seed=2, present_frac=0.5)
    assert q.size == 300 and q.dtype == np.uint64


# ---------------------------------------------------------------------------
# mutable SERVICE: admission-order semantics, in-flight hot swaps
# ---------------------------------------------------------------------------
def test_service_failing_compaction_is_observable():
    keys = sosd.generate("amzn", 4_000, seed=9)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        index="rmi", hyper=dict(branching=128), compact_threshold=8,
        auto_compact=True))
    boom = RuntimeError("rebuild exploded")

    def failing_compact():
        raise boom

    svc.mindex.compact = failing_compact
    svc.insert(np.arange(1, 33, dtype=np.uint64) * 2 + keys[0])
    svc.drain()                                    # insert run spawns compactor
    t = svc._compact_thread
    assert t is not None
    t.join(timeout=10.0)
    assert svc.metrics.snapshot()["compaction_failures"] >= 1
    assert svc.last_compaction_error is boom
    # backoff: the next insert run must NOT respawn immediately
    svc.insert(np.arange(1, 9, dtype=np.uint64) * 3 + keys[0])
    svc.drain()
    assert svc._compact_thread is t                # spawn was skipped
    with pytest.raises(RuntimeError, match="rebuild exploded"):
        svc.force_compact()                        # sync path surfaces it
    assert svc.metrics.snapshot()["compaction_failures"] >= 2
    svc.stop()
def test_service_inflight_batches_across_forced_compaction():
    keys = sosd.generate("osm", 6_000, seed=4)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        index="pgm", hyper=dict(eps=32), max_batch=256, deadline_ms=60_000.0,
        compact_threshold=1 << 30, auto_compact=False))
    wl = make_workload(keys, 500, mix="ycsb_a", dist="hot_set", seed=21,
                       present_frac=0.85)
    # phase 1: put keys in the delta so the forced compaction has work
    head, tail = 200, 500
    futs = []
    i = 0
    while i < head:
        j = min(i + 37, head)
        op = wl.ops[i]
        j = next((k for k in range(i, j) if wl.ops[k] != op), j)
        ks = wl.keys[i:j]
        futs.append(svc.insert(ks) if op == OP_INSERT else svc.submit(ks))
        i = j
    svc.drain()
    assert svc.mindex.delta_count > 0
    # phase 2: admit the rest WITHOUT draining, hot-swap-compact with the
    # batches in flight, then drain — results must match admission order
    while i < tail:
        j = i
        while j < tail and wl.ops[j] == wl.ops[i] and j - i < 41:
            j += 1
        ks = wl.keys[i:j]
        futs.append(svc.insert(ks) if wl.ops[i] == OP_INSERT
                    else svc.submit(ks))
        i = j
    assert svc.batcher.pending_requests > 0        # genuinely in flight
    gen = svc.force_compact()
    assert gen is not None
    svc.drain()
    got = np.concatenate([f.result(30.0) for f in futs])
    expected = oracle_replay(keys, Workload(ops=wl.ops[:tail],
                                            keys=wl.keys[:tail],
                                            aux=wl.aux[:tail]))
    np.testing.assert_array_equal(got, expected)
    assert svc.metrics.snapshot()["compactions"] >= 1
    svc.stop()


def test_service_auto_compaction_under_background_flusher():
    keys = sosd.generate("face", 8_000, seed=6)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        index="rmi", hyper=dict(branching=256), max_batch=128,
        deadline_ms=1.0, compact_threshold=60))
    wl = make_workload(keys, 700, mix="ycsb_a", dist="zipfian", seed=23,
                       present_frac=0.9)
    with svc:
        got = replay_on_service(wl, svc, chunk=32)
    np.testing.assert_array_equal(got, oracle_replay(keys, wl))
    snap = svc.metrics.snapshot()
    assert snap["compactions"] >= 1                # threshold fired
    assert snap["insert_batches"] >= 1
    assert snap["admitted"] == int(got[wl.ops == OP_INSERT].sum())
    assert svc.generation.version >= 1             # hot-swapped >= once


def test_service_range_blend_and_delta_gauge():
    keys = sosd.generate("amzn", 5_000, seed=8)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        index="radix_spline", hyper=dict(eps=16, radix_bits=10),
        max_batch=256, deadline_ms=1.0, compact_threshold=1 << 30))
    wl = make_workload(keys, 300, mix="ycsb_e", dist="sequential", seed=2)
    got = replay_on_service(wl, svc, chunk=64)     # sync mode: drained inline
    np.testing.assert_array_equal(got, oracle_replay(keys, wl))
    snap = svc.metrics.snapshot()
    assert snap["delta_keys"] == svc.mindex.delta_count > 0
    assert 0.0 <= snap["delta_occupancy"] < 1e-3   # huge threshold
    svc.stop()
