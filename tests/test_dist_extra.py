"""Edge-case coverage for repro.dist beyond the seed contract tests.

Seed tests pin the happy paths (test_train_infra.py,
test_pipeline_parallel.py); this module covers the boundaries: degenerate
quantization inputs, bubble-fraction limits, elastic meshes, and the
no-context defaults the single-device tests rely on.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import compression as GC
from repro.dist import sharding as SH
from repro.dist.pipeline_parallel import bubble_fraction, sequential_apply


# ---------------------------------------------------------------------------
# quantize / dequantize round-trip edge cases
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("x", [
    np.zeros(64, np.float32),                      # all-zero: scale floor
    np.full(17, 1e30, np.float32),                 # huge but finite
    np.array([-1e30, 1e30, 0.0], np.float32),      # mixed extreme signs
    np.array([1e-30], np.float32),                 # denormal-adjacent
    np.linspace(-1.0, 1.0, 255).astype(np.float32),
], ids=["zeros", "huge", "mixed-extreme", "tiny", "linspace"])
def test_quantize_roundtrip_edge_cases(x):
    x = jnp.asarray(x)
    c, res = GC.quantize(x)
    deq = GC.dequantize(c)
    # finite everywhere — no overflow/NaN from the scale computation
    assert bool(jnp.isfinite(deq).all())
    assert bool(jnp.isfinite(res).all())
    # exact round-trip: dequantize + residual reconstructs the input
    np.testing.assert_allclose(np.asarray(deq + res), np.asarray(x),
                               rtol=1e-6, atol=1e-38)
    # one-step error bound (allow 1 ulp of the dequantized magnitude)
    ulp = float(jnp.max(jnp.abs(deq))) * 1.2e-7
    assert float(jnp.max(jnp.abs(res))) <= float(c.scale) / 2 + ulp + 1e-38
    # int8 payload really is int8 and inside the symmetric range
    assert c.q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(c.q.astype(jnp.int32)))) <= 127


def test_quantize_zeros_dequantize_to_zeros():
    c, res = GC.quantize(jnp.zeros(8, jnp.float32))
    assert float(jnp.max(jnp.abs(GC.dequantize(c)))) == 0.0
    assert float(jnp.max(jnp.abs(res))) == 0.0


def test_quantize_error_feedback_bf16_input():
    """Error feedback must work in the params' storage dtype too."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, 256), jnp.bfloat16)
    c, res = GC.quantize(x)
    assert res.dtype == x.dtype
    assert bool(jnp.isfinite(GC.dequantize(c)).all())


# ---------------------------------------------------------------------------
# bubble fraction boundaries
# ---------------------------------------------------------------------------
def test_bubble_fraction_boundaries():
    assert bubble_fraction(1, 1) == 0.0           # no pipeline, no bubble
    assert bubble_fraction(2, 1) == 0.5           # single microbatch: P-1 of
    assert bubble_fraction(4, 1) == 0.75          # M+P-1 ticks are idle
    # monotone: more microbatches -> smaller bubble
    fr = [bubble_fraction(4, m) for m in (1, 2, 8, 32, 128)]
    assert all(a > b for a, b in zip(fr, fr[1:]))
    # asymptotics: -> 0 as M -> inf, -> 1 as P -> inf
    assert bubble_fraction(4, 10_000) < 1e-3
    assert bubble_fraction(10_000, 1) > 0.999


def test_sequential_apply_matches_manual_loop():
    rng = np.random.default_rng(1)
    ws = jnp.asarray(rng.normal(0, 0.1, (3, 8, 8)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (2, 4, 8)).astype(np.float32))

    def body(a, w):
        return jnp.tanh(a @ w)

    got = sequential_apply(body, ws, x)
    ref = np.stack([
        np.asarray(body(body(body(x[m], ws[0]), ws[1]), ws[2]))
        for m in range(x.shape[0])])
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# sharding: elastic meshes, no-context defaults, dispatch groups
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


def test_resolve_spec_elastic_mesh_reuses_tables():
    """A shrunk 8x16 mesh resolves through the same 16x16 rule tables."""
    mesh = _FakeMesh(data=8, model=16)
    spec = SH.resolve_spec((256, 4096, 2048), ("batch", "seq", "embed"),
                           mesh, SH.ACT_RULES)
    assert spec == P("data", None, None)
    # joint FSDP group (model, data) now covers 128 shards
    spec = SH.resolve_spec((6144, 16384), ("embed", "mlp"), mesh,
                           SH.PARAM_RULES)
    assert spec == P(None, ("model", "data"))


def test_resolve_spec_unknown_names_replicate():
    mesh = _FakeMesh(data=16, model=16)
    spec = SH.resolve_spec((4, 32, 7), ("layers", None, "nonsense"),
                           mesh, SH.PARAM_RULES)
    assert spec == P(None, None, None)


def test_logical_constraint_no_context_is_identity():
    x = jnp.ones((4, 8))
    assert SH.logical_constraint(x, ("batch", "seq")) is x


def test_dispatch_groups_follows_context():
    assert SH.dispatch_groups(1024) == 1  # no mesh installed
    mesh = _FakeMesh(data=16, model=16)
    with SH.axis_rules(mesh):
        assert SH.dispatch_groups(1024) == 16          # ACT: data only
    with SH.axis_rules(mesh, act_rules=SH.FSDP_ACT_RULES):
        assert SH.dispatch_groups(1024) == 256         # FSDP: data*model
    assert SH.dispatch_groups(1024) == 1  # context restored


def test_axis_rules_nesting_restores_previous():
    m1 = _FakeMesh(data=4)
    m2 = _FakeMesh(data=2, model=2)
    with SH.axis_rules(m1):
        with SH.axis_rules(m2, act_rules=SH.FSDP_ACT_RULES):
            assert SH.dispatch_groups() == 4  # (data, model) of m2
        assert SH.dispatch_groups() == 4      # back to m1: data=4
    assert SH.dispatch_groups() == 1


def test_select_rules_modes():
    class Cfg:
        parallelism = "fsdp"

    act, param = SH.select_rules(Cfg())
    assert act is SH.FSDP_ACT_RULES and param is SH.PARAM_RULES
    Cfg.parallelism = "auto"
    act, param = SH.select_rules(Cfg())
    assert act is SH.ACT_RULES and param is SH.PARAM_RULES


def test_shard_tree_on_real_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32),
              "b": jax.ShapeDtypeStruct((128,), jnp.float32)}
    names = {"w": ("embed", "mlp"), "b": ("mlp",)}
    tree = SH.shard_tree(shapes, names, mesh)
    # trivial axes -> fully replicated NamedShardings, but real ones
    assert tree["w"].spec == P(None, None)
    assert tree["b"].spec == P(None)
    assert tree["w"].mesh is mesh
