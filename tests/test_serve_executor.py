"""Async-executor suite (DESIGN.md §13): the continuous-batching engine
is pinned against the synchronous path and the replay oracle.

Four layers of assurance, strongest first:

  parity     every index × backend cell produces BIT-IDENTICAL results
             under executor="async" and executor="sync" — positions and
             scan windows, through real threads;
  replay     a mixed read/insert/range trace (compactions forced
             mid-trace) replayed on the async mutable service matches
             `oracle_scan_replay` bit-for-bit — the end-to-end
             linearization invariant;
  stress     N concurrent client threads against one started service:
             exactness (immutable), linearization brackets (mutable),
             per-client FIFO completion, no unresolved futures, a warm
             cache actually hitting;
  faults     a dispatch-time failure, a completion-time failure, and an
             insert-apply failure each fail ONLY their own batch's
             futures with the original exception and leave the slot ring
             clean; hot-swap racing an in-flight slot completes against
             the generation the slot pinned; `result(timeout)` expiry
             orphans nothing; `stop()` with a straggler joins cleanly.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import base
from repro.data import sosd
from repro.serve.lookup import (AsyncExecutor, ExecutableCache,
                                LookupService, LookupServiceConfig,
                                MutableLookupService,
                                MutableLookupServiceConfig)
from repro.workloads import replay as replay_mod
from repro.workloads.workload import OP_INSERT, make_workload

UINT64_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


# ---------------------------------------------------------------------------
# shared data (module-scoped: every test reuses one build of the cell)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cell():
    keys = sosd.generate("amzn", 20_000, seed=3)
    q = sosd.make_queries(keys, 2_000, seed=5, present_frac=0.6)
    return keys, q, base.lower_bound_oracle(keys, q)


def _scan_oracle(keys, pos, m):
    w = np.full((pos.size, m), UINT64_MAX, dtype=np.uint64)
    for i, p in enumerate(pos):
        seg = keys[p:p + m]
        w[i, :seg.size] = seg
    return w


def _svc(keys, executor, **over):
    kw = dict(index="rmi", hyper=dict(branching=512), max_batch=256,
              deadline_ms=1.0, executor=executor)
    kw.update(over)
    return LookupService(keys, LookupServiceConfig(**kw))


# ---------------------------------------------------------------------------
# parity: async ≡ sync, bit for bit, across the index × backend matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("index,hyper,backend", [
    ("rmi", dict(branching=512), "jnp"),
    ("rmi", dict(branching=512), "pallas"),
    ("pgm", dict(eps=32), "jnp"),
    ("radix_spline", dict(eps=32, radix_bits=12), "jnp"),
])
def test_async_matches_sync_bit_identical(cell, index, hyper, backend):
    keys, q, lb = cell
    outs = {}
    for executor in ("sync", "async"):
        svc = _svc(keys, executor, index=index, hyper=hyper,
                   backend=backend, warm_scan_lengths=(16,))
        with svc:
            reads = [svc.submit(q[i:i + 97]) for i in range(0, q.size, 97)]
            scans = [svc.scan(q[i:i + 50], 16) for i in range(0, 200, 50)]
            outs[executor] = (
                np.concatenate([f.result(60.0) for f in reads]),
                [f.result(60.0) for f in scans])
    pos_s, scans_s = outs["sync"]
    pos_a, scans_a = outs["async"]
    np.testing.assert_array_equal(pos_a, pos_s)
    np.testing.assert_array_equal(pos_s, lb)
    for (ps, ws), (pa, wa) in zip(scans_s, scans_a):
        np.testing.assert_array_equal(pa, ps)
        np.testing.assert_array_equal(wa, ws)
    w0 = scans_a[0][1]
    np.testing.assert_array_equal(w0, _scan_oracle(keys, lb[:50], 16))


def test_async_replay_matches_oracle_with_compactions(cell):
    """Mixed trace, async executor, compactions racing the slot ring:
    positions, admitted flags, AND scan windows equal the oracle's."""
    keys, _, _ = cell
    wl = make_workload(keys, 600,
                       mix={"read": 0.5, "insert": 0.3, "range": 0.2},
                       seed=17, range_len=16)
    want, want_win = replay_mod.oracle_scan_replay(keys, wl)
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        index="pgm", hyper=dict(eps=32), max_batch=256, deadline_ms=1.0,
        executor="async", compact_threshold=512, warm_scan_lengths=(16,)))
    with svc:
        got, got_win = replay_mod.replay_on_service(
            wl, svc, chunk=48, compact_every=200, scan_ranges=True)
        # every future resolved => every insert applied; fold whatever
        # delta remains (an EMPTY delta here means a compaction already
        # fired mid-trace) so the swap path is exercised either way
        assert (want[wl.ops == OP_INSERT] == 1).any()
        if svc.mindex.delta_count:
            svc.force_compact()
        assert svc.metrics.snapshot()["compactions"] >= 1
        # post-compaction reads stay exact against the merged oracle
        merged = np.union1d(keys, wl.keys[(wl.ops == OP_INSERT)
                                          & (want == 1)])
        probe = wl.keys[wl.ops != OP_INSERT][:300]
        np.testing.assert_array_equal(
            svc.lookup(probe, timeout=60.0),
            base.lower_bound_oracle(merged, probe))
    np.testing.assert_array_equal(got, want)
    assert set(got_win) == set(want_win)
    for i in want_win:
        np.testing.assert_array_equal(got_win[i], want_win[i])


# ---------------------------------------------------------------------------
# stress: concurrent clients against one started service
# ---------------------------------------------------------------------------
def test_stress_concurrent_reads_and_scans_exact(cell):
    keys, q, lb = cell
    svc = _svc(keys, "async", warm_scan_lengths=(8,))
    n_threads, errs = 6, []

    def client(t):
        try:
            rng = np.random.default_rng(t)
            for _ in range(30):
                lo = int(rng.integers(0, q.size - 64))
                n = int(rng.integers(1, 64))
                if t % 3 == 0:
                    f = svc.scan(q[lo:lo + n], 8)
                    pos, win = f.result(60.0)
                    np.testing.assert_array_equal(pos, lb[lo:lo + n])
                    np.testing.assert_array_equal(
                        win, _scan_oracle(keys, lb[lo:lo + n], 8))
                else:
                    f = svc.submit(q[lo:lo + n])
                    np.testing.assert_array_equal(
                        f.result(60.0), lb[lo:lo + n])
        except BaseException as e:   # noqa: BLE001 — surface in main thread
            errs.append(e)

    with svc:
        ts = [threading.Thread(target=client, args=(t,))
              for t in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    assert not errs, errs
    snap = svc.metrics.snapshot()
    # the §13 observability contract: a warm cache HITS under steady
    # traffic, and the decomposed latencies are populated
    assert snap["cache_hit_rate"] > 0.0
    assert snap["warm_compiles"] > 0
    assert snap["p99_request_ms"] > 0.0
    assert snap["p99_queue_ms"] > 0.0
    assert svc._async._inflight == 0
    assert svc._async._ring.empty()


def test_stress_mutable_concurrent_writers_bracketed(cell):
    """Readers race two disjoint insert streams: every read result is
    bracketed by LB(base) <= got <= LB(base ∪ all inserts) (inserts only
    ever shift LB up), every insert is admitted exactly once, and no
    future is left pending."""
    keys, q, _ = cell
    half = keys[::2].copy()
    fresh = np.setdiff1d(keys[1::2], half)[:2_000]
    lo_lb = base.lower_bound_oracle(half, q)
    hi_lb = base.lower_bound_oracle(np.union1d(half, fresh), q)
    svc = MutableLookupService(half, MutableLookupServiceConfig(
        index="pgm", hyper=dict(eps=32), max_batch=256, deadline_ms=1.0,
        executor="async", compact_threshold=768))
    errs, admitted = [], []

    def writer(lo):
        try:
            part = fresh[lo::2]
            futs = [svc.insert(part[i:i + 100])
                    for i in range(0, part.size, 100)]
            admitted.append(sum(int(f.result(60.0).sum()) for f in futs))
        except BaseException as e:   # noqa: BLE001
            errs.append(e)

    def reader(t):
        try:
            rng = np.random.default_rng(100 + t)
            for _ in range(25):
                lo = int(rng.integers(0, q.size - 64))
                n = int(rng.integers(1, 64))
                got = svc.submit(q[lo:lo + n]).result(60.0)
                assert np.all(lo_lb[lo:lo + n] <= got)
                assert np.all(got <= hi_lb[lo:lo + n])
        except BaseException as e:   # noqa: BLE001
            errs.append(e)

    with svc:
        ts = ([threading.Thread(target=writer, args=(w,)) for w in range(2)]
              + [threading.Thread(target=reader, args=(t,))
                 for t in range(3)])
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    assert not errs, errs
    assert sum(admitted) == fresh.size          # set semantics, no loss
    merged = np.union1d(half, fresh)
    np.testing.assert_array_equal(svc.lookup(q[:500]),
                                  base.lower_bound_oracle(merged, q[:500]))


def test_fifo_completion_per_client(cell):
    """Completion order is admission order: in ANY snapshot, the done
    set is a prefix.  Reading newest -> oldest with completion racing,
    a done future must never be followed by a pending older one."""
    keys, q, _ = cell
    svc = _svc(keys, "async", max_batch=64)
    with svc:
        # slow the (already warmed) read executable a little so
        # completion is observably gradual
        bucket = svc.dispatcher.padded_size(64)
        ckey = ((svc.generation.version,), "read", 0, bucket)
        real = svc.exec_cache._exes[ckey]
        svc.exec_cache._exes[ckey] = (
            lambda *a: (time.sleep(0.003), real(*a))[1])
        futs = [svc.submit(q[i * 32:(i + 1) * 32]) for i in range(40)]
        deadline = time.perf_counter() + 60.0
        while not futs[-1].done():
            saw_done = False
            for f in reversed(futs):
                d = f.done()
                assert not (saw_done and not d), "per-client FIFO violated"
                saw_done = saw_done or d
            assert time.perf_counter() < deadline
    assert all(f.done() for f in futs)


def test_double_buffering_overlaps_inflight_slots(cell):
    """With completion artificially slow, the dispatch thread keeps
    launching: observed in-flight slot depth must exceed one (the whole
    point of the ring) and never exceed the configured bound."""
    keys, q, lb = cell
    svc = _svc(keys, "async", max_batch=64, slots=3)
    real_finalize = svc.dispatcher.finalize
    svc.dispatcher.finalize = (
        lambda out, m, **kw: (time.sleep(0.02),
                              real_finalize(out, m, **kw))[1])
    with svc:
        futs = [svc.submit(q[i * 64:(i + 1) * 64]) for i in range(12)]
        got = np.concatenate([f.result(60.0) for f in futs])
    np.testing.assert_array_equal(got, lb[:12 * 64])
    snap = svc.metrics.snapshot()
    assert snap["max_inflight_slots"] >= 2
    # bound = ring capacity + one slot mid-completion (popped) + one
    # launch blocked entering the full ring: in-flight memory is bounded
    assert snap["max_inflight_slots"] <= 3 + 2
    assert snap["mean_inflight_slots"] > 0.0


# ---------------------------------------------------------------------------
# drain/stop: nothing admitted is ever left unresolved
# ---------------------------------------------------------------------------
def test_inline_drain_resolves_everything_and_empties_ring(cell):
    """No threads at all: drain() on a never-started async service
    launches AND completes every admission — including past the slot
    bound (more batches in flight than slots forces the inline
    oldest-first completion path)."""
    keys, q, lb = cell
    svc = _svc(keys, "async", max_batch=64, slots=2)
    futs = [svc.submit(q[i * 64:(i + 1) * 64]) for i in range(10)]
    svc.drain()
    assert all(f.done() for f in futs)
    got = np.concatenate([f.result(1.0) for f in futs])
    np.testing.assert_array_equal(got, lb[:640])
    assert svc._async._inflight == 0
    assert svc._async._ring.empty()


def test_stop_resolves_everything_admitted(cell):
    keys, q, lb = cell
    svc = _svc(keys, "async", max_batch=128)
    svc.start()
    futs = [svc.submit(q[i * 50:(i + 1) * 50]) for i in range(30)]
    svc.stop()                      # immediate: no settle wait first
    assert all(f.done() for f in futs)
    got = np.concatenate([f.result(1.0) for f in futs])
    np.testing.assert_array_equal(got, lb[:1500])
    # the service stays usable synchronously after stop()
    np.testing.assert_array_equal(svc.lookup(q[:40]), lb[:40])


def test_result_timeout_orphans_nothing(cell):
    """A client timing out on `result` must not orphan the request:
    the executor still resolves it, and drain() does not deadlock."""
    keys, q, lb = cell
    svc = _svc(keys, "async")
    fut = svc.submit(q[:64])        # not started: nothing will flush yet
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)
    svc.drain()                     # must terminate, resolving the future
    np.testing.assert_array_equal(fut.result(1.0), lb[:64])


def test_stop_with_straggler_joins_cleanly(cell):
    """A slot stuck in a slow executable when stop() lands: the join
    must complete in bounded time WITH the straggler's future resolved
    correctly (completion loop runs the ring dry before the sentinel)."""
    keys, q, lb = cell
    svc = _svc(keys, "async", max_batch=64)
    svc.start()
    bucket = svc.dispatcher.padded_size(64)
    ckey = ((svc.generation.version,), "read", 0, bucket)
    real = svc.exec_cache._exes[ckey]
    svc.exec_cache._exes[ckey] = (
        lambda *a: (time.sleep(0.5), real(*a))[1])
    fut = svc.submit(q[:64])
    t0 = time.perf_counter()
    svc.stop()
    assert time.perf_counter() - t0 < 30.0
    np.testing.assert_array_equal(fut.result(1.0), lb[:64])


# ---------------------------------------------------------------------------
# executable cache: hits, warm accounting, invalidation-on-swap
# ---------------------------------------------------------------------------
def test_cache_hits_after_warmup_no_steady_state_misses(cell):
    """After `start()`'s warm-up, fixed-shape traffic NEVER misses:
    every batch is a hit against a pre-compiled executable, and warm-up
    itself is accounted separately (it must not inflate the hit rate)."""
    keys, q, lb = cell
    svc = _svc(keys, "async", max_batch=128)
    with svc:
        futs = [svc.submit(q[i * 128:(i + 1) * 128]) for i in range(8)]
        for f in futs:
            f.result(60.0)
    snap = svc.metrics.snapshot()
    assert snap["warm_compiles"] > 0
    assert snap["cache_misses"] == 0
    assert snap["cache_hits"] >= 8
    assert snap["cache_hit_rate"] == 1.0


def test_hot_swap_invalidates_cache_and_rewarms(cell):
    """Publish -> stale generations' executables evicted (only entries
    keyed by the new version survive) -> traffic against the new key
    set is exact and hits again once re-warmed."""
    keys, q, _ = cell
    svc = _svc(keys, "async", max_batch=128)
    with svc:
        svc.lookup(q[:128], timeout=60.0)
        assert len(svc.exec_cache) > 0
        new_keys = keys[::2].copy()
        gen = svc.swap_keys(new_keys)
        with svc.exec_cache._mu:
            assert all(k[0][0] == gen.version
                       for k in svc.exec_cache._exes)
        lb2 = base.lower_bound_oracle(new_keys, q[:300])
        np.testing.assert_array_equal(svc.lookup(q[:300], timeout=60.0), lb2)


def test_hot_swap_races_inflight_slot_old_generation_wins(cell):
    """A slot launched before the swap completes against the generation
    it pinned — the swap is invisible to in-flight work (§9.3 semantics
    carried over to the ring)."""
    keys, q, lb = cell
    svc = _svc(keys, "async")
    fut = svc.submit(q[:100])
    svc._async._drain_launches()        # launched against the OLD plan
    new_keys = keys[::4].copy()
    svc.swap_keys(new_keys)             # swap while the slot is in flight
    svc._async._complete_ring_inline()
    np.testing.assert_array_equal(fut.result(1.0), lb[:100])   # old gen
    # and the NEXT batch sees the new generation
    lb_new = base.lower_bound_oracle(new_keys, q[:100])
    np.testing.assert_array_equal(svc.lookup(q[:100], timeout=60.0), lb_new)


def test_executable_cache_unit_semantics():
    cache = ExecutableCache()
    ctx_key = (7,)
    # duck-typed: only .key/.bind/.instrumented are read
    ctx = type("C", (), {})()
    ctx.key, ctx.bind, ctx.instrumented = ctx_key, (), False
    fn = lambda q: q                # no .lower: stored as-is  # noqa: E731
    got = cache.get(ctx, "read", 0, 128, lambda: fn, dispatcher=None,
                    warm=True)
    assert got is fn
    assert cache.counters() == (0, 0)       # warm never counts hit/miss
    assert cache.warm_compiles == 1
    assert cache.get(ctx, "read", 0, 128, lambda: fn, None) is fn
    assert cache.counters() == (1, 0)       # serving hit
    cache.get(ctx, "read", 0, 256, lambda: fn, None)
    assert cache.counters() == (1, 1)       # new bucket: serving miss
    ctx2 = type("C", (), {})()
    ctx2.key, ctx2.bind, ctx2.instrumented = (8,), (), False
    cache.get(ctx2, "read", 0, 128, lambda: fn, None)
    assert len(cache) == 3
    assert cache.invalidate(keep_version=8) == 2    # both v7 entries die
    assert len(cache) == 1
    assert cache.invalidate() == 1                  # full clear
    assert cache.hit_rate == pytest.approx(1 / 3)


def test_async_executor_requires_double_buffering():
    with pytest.raises(ValueError, match="slots"):
        AsyncExecutor(service=None, slots=1)
    with pytest.raises(ValueError, match="executor"):
        LookupService(np.arange(1, 100, dtype=np.uint64),
                      LookupServiceConfig(executor="turbo"))


# ---------------------------------------------------------------------------
# fault injection: failures are request-scoped, never engine-scoped
# ---------------------------------------------------------------------------
class Boom(RuntimeError):
    pass


def test_launch_failure_fails_only_that_batch(cell):
    """An executable-resolution failure mid-dispatch fails exactly that
    batch's futures with the ORIGINAL exception; the ring stays clean
    and the very next batch succeeds."""
    keys, q, lb = cell
    svc = _svc(keys, "async", max_batch=64)
    with svc:
        boom = Boom("resolution exploded")
        real_get = svc.exec_cache.get
        fired = threading.Event()

        def poisoned(ctx, kind, aux, bucket, make_fn, dispatcher,
                     warm=False):
            if not warm and not fired.is_set():
                fired.set()
                raise boom
            return real_get(ctx, kind, aux, bucket, make_fn, dispatcher,
                            warm=warm)

        svc.exec_cache.get = poisoned
        bad = svc.submit(q[:64])
        with pytest.raises(Boom) as ei:
            bad.result(60.0)
        assert ei.value is boom                 # original exception object
        good = svc.submit(q[64:128])
        np.testing.assert_array_equal(good.result(60.0), lb[64:128])
    assert svc._async._inflight == 0
    assert svc._async._ring.empty()


def test_completion_failure_fails_only_that_slot(cell):
    """A device-side failure surfacing at finalize fails that slot's
    futures; the completion loop keeps serving later slots."""
    keys, q, lb = cell
    svc = _svc(keys, "async", max_batch=64)
    with svc:
        bucket = svc.dispatcher.padded_size(64)
        ckey = ((svc.generation.version,), "read", 0, bucket)
        real = svc.exec_cache._exes[ckey]
        svc.exec_cache._exes[ckey] = lambda *a: None   # finalize will choke
        bad = svc.submit(q[:64])
        with pytest.raises(BaseException):
            bad.result(60.0)
        svc.exec_cache._exes[ckey] = real
        good = svc.submit(q[:64])
        np.testing.assert_array_equal(good.result(60.0), lb[:64])


def test_insert_failure_fails_only_that_run(cell):
    """An insert-apply failure (delta layer raising) fails the insert
    run's futures with the original exception; reads before and after
    keep completing, and a later insert succeeds."""
    keys, q, _ = cell
    half = keys[::2].copy()
    lb_half = base.lower_bound_oracle(half, q[:64])
    svc = MutableLookupService(half, MutableLookupServiceConfig(
        index="pgm", hyper=dict(eps=32), max_batch=128, deadline_ms=1.0,
        executor="async", auto_compact=False))
    fresh = np.setdiff1d(keys[1::2], half)[:50]
    with svc:
        boom = Boom("delta exploded")
        real_insert = svc.mindex.insert
        fired = threading.Event()

        def poisoned(ks):
            if not fired.is_set():
                fired.set()
                raise boom
            return real_insert(ks)

        svc.mindex.insert = poisoned
        r0 = svc.submit(q[:64])
        bad = svc.insert(fresh)
        r1 = svc.submit(q[:64])
        np.testing.assert_array_equal(r0.result(60.0), lb_half)
        with pytest.raises(Boom) as ei:
            bad.result(60.0)
        assert ei.value is boom
        np.testing.assert_array_equal(r1.result(60.0), lb_half)
        ok = svc.insert(fresh)
        assert int(ok.result(60.0).sum()) == fresh.size
    merged = np.union1d(half, fresh)
    np.testing.assert_array_equal(
        svc.lookup(q[:64]), base.lower_bound_oracle(merged, q[:64]))
