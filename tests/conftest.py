"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on ONE device;
only launch/dryrun.py requests 512 placeholder devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def datasets():
    from repro.data import sosd

    n = 60_000
    return {name: sosd.generate(name, n, seed=7) for name in sosd.DATASETS}


@pytest.fixture(scope="session")
def queries(datasets):
    from repro.data import sosd

    return {name: sosd.make_queries(keys, 8_000, seed=11, present_frac=0.6)
            for name, keys in datasets.items()}
