"""Paper Fig. 17: single-threaded build times across dataset sizes.

Paper claims to reproduce: RS fastest learned build (one pass), PGM next,
RMI slowest; btree-style (sampled array) cheapest of all.
"""
from __future__ import annotations

import os
import time

from benchmarks import _common as C


def run(sizes=(100_000, 400_000), ds="amzn", out_dir="benchmarks/results"):
    from repro.core import spec as S
    from repro.data import sosd

    configs = [S.IndexSpec("rmi", dict(branching=4096)),
               S.IndexSpec("pgm", dict(eps=64)),
               S.IndexSpec("radix_spline", dict(eps=32, radix_bits=16)),
               S.IndexSpec("btree", dict(sample=8)),
               S.IndexSpec("rbs", dict(radix_bits=16)),
               S.IndexSpec("robin_hash", dict(load_factor=0.5))]
    rows = []
    for n in sizes:
        keys = sosd.generate(ds, n, seed=1)
        for sp in configs:
            sp = sp.validated()   # validate OUTSIDE the timed region
            t0 = time.perf_counter()
            S.build(sp, keys)
            t1 = time.perf_counter()
            rows.append([ds, n, sp.index, round(t1 - t0, 4)])
    C.emit(rows, header=["dataset", "n_keys", "index", "build_seconds"],
           path=os.path.join(out_dir, "build_times.csv"))
    return rows


if __name__ == "__main__":
    run()
