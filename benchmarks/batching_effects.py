"""Paper Fig. 14/15 analogue: caching & pipelining sensitivity.

CPU version: warm-vs-cold cache and memory fences.  TPU/JAX version: the
same effects appear as (a) query-batch amortization — a tight loop of tiny
dispatches vs one fused batch (dispatch+DMA latency is the 'memory round
trip'), and (b) forced synchronization between lookups (block_until_ready
per sub-batch = the memory-fence analogue: no overlap between lookups).
Expectation mirroring the paper: the FASTEST structures lose the most from
forced synchronization (their compute no longer hides dispatch latency).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks import _common as C


def run(ds="amzn", out_dir="benchmarks/results", backend=None):
    import jax
    import jax.numpy as jnp
    from repro.core.spec import IndexSpec

    keys = C.dataset(ds)
    q = C.queries(ds)
    data_jnp = jnp.asarray(keys)
    rows = []
    for sp in [IndexSpec("rmi", dict(branching=4096)),
               IndexSpec("pgm", dict(eps=64)),
               IndexSpec("radix_spline", dict(eps=32, radix_bits=16)),
               IndexSpec("btree", dict(sample=8)),
               IndexSpec("rbs", dict(radix_bits=16))]:
        b = C.build_index(sp, keys)
        name = b.name
        fn = C.full_lookup_fn(b, data_jnp, backend=backend)
        q_jnp = jnp.asarray(q)
        fused = C.time_lookup(fn, q_jnp)
        # "fenced": 64 sub-batches, each synchronized before the next
        sub = np.array_split(q, 64)
        subs = [jnp.asarray(s) for s in sub]
        fn(subs[0])  # compile for the sub-shape
        jax.block_until_ready(fn(subs[0]))
        t0 = time.perf_counter()
        for s in subs:
            jax.block_until_ready(fn(s))
        fenced = time.perf_counter() - t0
        rows.append([ds, name,
                     round(C.ns_per_lookup(fused, len(q)), 2),
                     round(C.ns_per_lookup(fenced, len(q)), 2),
                     round(fenced / fused, 2)])
    C.emit(rows, header=["dataset", "index", "ns_fused", "ns_fenced",
                         "slowdown"],
           path=os.path.join(out_dir, "batching_effects.csv"))
    return rows


if __name__ == "__main__":
    run(backend=C.backend_arg())
