"""Mixed-workload sweep: mutable indexes under index x mix x skew.

The axis the source paper explicitly could not open ("read-only
in-memory workloads ... uniformly-sampled keys", its §8 limitation):
this sweep drives the MUTABLE lookup service — delta-buffered inserts,
merged reads, threshold-triggered hot-swap compaction — with seeded
`repro.workloads` traces across

    index type x operation mix (YCSB-A/B/C/E) x key-access skew,

emitting one JSON row per cell: ops/sec, admitted inserts, compaction
count and latency, peak delta occupancy, and ``verified_vs_oracle`` —
EVERY per-op result (read positions and admitted flags) compared
against a plain sorted-array `oracle_replay`, which crosses every
compaction the run performed.  Rows also carry the §15 index-health
columns (``drift_tv`` against the current generation's build
distribution, ``disp_p99_ratio`` live-vs-build displacement,
``compaction_debt``, and any ``alerts_firing`` at cell end) — the
skewed mixes are exactly where the drift detector earns its keep.  Thresholds are sized so insert-carrying
cells compact at least once; read-only cells pin the zero-write
regression path.

    PYTHONPATH=src python benchmarks/mixed_workload.py [--smoke]

Env: ``SOSD_N`` (base keys), ``MIXED_OPS`` (trace length per cell).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/mixed_workload.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import _common as C

#: (mix, distribution) cells — the YCSB ladder crossed with the skews
#: that flip learned-index conclusions (zipfian hot keys, hot-set
#: residency, scan-heavy E).
MIX_POINTS = [
    ("ycsb_c", "uniform"),     # the paper's own regime, as the baseline
    ("ycsb_b", "zipfian"),     # read-mostly, skewed
    ("ycsb_a", "zipfian"),     # write-heavy, skewed
    ("ycsb_b", "hot_set"),
    ("ycsb_e", "sequential"),  # range blend over scan starts
]

INDEX_NAMES = ["rmi", "pgm", "radix_spline"]
DATASETS = ["amzn", "osm"]

N_OPS = int(os.environ.get("MIXED_OPS", 6_000))


def _run_cell(ds: str, spec, mix: str, dist: str, n_ops: int,
              n_keys: int, backend: str = "jnp", tuner=None):
    from repro import workloads
    from repro.serve.lookup import (MutableLookupService,
                                    MutableLookupServiceConfig)

    keys = C.dataset(ds, n=n_keys)
    wl = workloads.make_workload(keys, n_ops, mix=mix, dist=dist,
                                 seed=13, present_frac=0.9)
    n_ins = wl.counts()["insert"]
    # threshold: insert-carrying mixes cross it at least once mid-trace
    threshold = max(16, n_ins // 2) if n_ins else 1 << 30

    t0 = time.perf_counter()
    svc = MutableLookupService(keys, MutableLookupServiceConfig(
        spec=spec.replace(backend=backend), tuner=tuner,
        max_batch=1024, deadline_ms=2.0, compact_threshold=threshold))
    build_s = time.perf_counter() - t0

    # scan-carrying mixes (YCSB-E) execute ranges END-TO-END as op kind
    # "scan": each range materializes its window through the plan's
    # windowed gather and is verified against the numpy scan oracle.
    has_ranges = wl.counts()["range"] > 0
    t0 = time.perf_counter()
    with svc:                       # background flusher + auto compaction
        res = workloads.replay_on_service(wl, svc, chunk=128,
                                          scan_ranges=has_ranges)
    replay_s = time.perf_counter() - t0

    got, windows = res if has_ranges else (res, {})
    expected, exp_windows = workloads.oracle_scan_replay(
        keys, wl, scan_windows=has_ranges)
    verified = bool(np.array_equal(got, expected)) and all(
        np.array_equal(windows[i], exp_windows[i]) for i in exp_windows)
    snap = svc.metrics.snapshot()
    svc.check_alerts(window_s=3600.0)
    firing = svc.alerts.firing()
    h = svc.health_snapshot(window_s=3600.0)
    final_spec = svc.mindex.spec     # tuner may have retuned at compaction
    return {
        "dataset": ds,
        "index": spec.index,
        "final_spec": final_spec.to_dict(),
        "retuned": final_spec != spec.replace(backend=backend).validated(),
        "mix": mix,
        "dist": dist,
        "n_keys": int(len(keys)),
        "n_ops": wl.n_ops,
        **{f"n_{k}": v for k, v in wl.counts().items()},
        "admitted": snap["admitted"],
        "compactions": snap["compactions"],
        "mean_compaction_ms": round(snap["mean_compaction_ms"], 3),
        "delta_threshold": threshold if n_ins else 0,
        "build_s": round(build_s, 4),
        "ops_per_s": round(wl.n_ops / replay_s, 1),
        "mean_batch_ms": round(snap["mean_batch_ms"], 4),
        "mean_insert_ms": round(snap["mean_insert_ms"], 4),
        "n_scan_windows": len(windows),
        "backend": backend,
        "verified_vs_oracle": verified,
        # §15 index-health columns for the CURRENT (post-compaction)
        # generation: drift against the rebuilt key distribution, live
        # vs build-time displacement, and leftover compaction debt
        "disp_p99": round(h.get("disp_p99", 0.0), 1),
        "disp_p99_ratio": round(h.get("disp_p99_ratio", 0.0), 3),
        "bound_utilization_p99": round(
            h.get("bound_utilization_p99", 0.0), 4),
        "drift_tv": round(h.get("drift_tv", 0.0), 4),
        "compaction_debt": round(h.get("compaction_debt", 0.0), 4),
        "alerts_firing": firing,
    }


def run(out_dir: str = "benchmarks/results", n_ops: int = N_OPS,
        n_keys: int = C.N_KEYS, datasets=None, indexes=None,
        mix_points=None, backend=None, spec=None, autotune=None):
    """``spec`` pins ONE IndexSpec for every cell; ``autotune`` (a byte
    budget) both picks the per-dataset starting spec AND hands the
    tuner to the service so compactions retune against the delta-merged
    key set (DESIGN.md §12.4)."""
    from repro.core.spec import Tuner
    from repro.serve.lookup import default_spec

    backend = backend or C.BACKEND
    rows = []
    for ds in (datasets or DATASETS):
        tuner = None
        if spec is not None:
            cells = [spec]
        elif autotune is not None:
            tuner = Tuner(names=tuple(indexes or INDEX_NAMES),
                          max_bytes=autotune)
            cells = [C.tuned_spec(ds, autotune,
                                  names=tuple(indexes or INDEX_NAMES),
                                  n=n_keys).spec]
        else:
            cells = [default_spec(i) for i in (indexes or INDEX_NAMES)]
        for sp in cells:
            for mix, dist in (mix_points or MIX_POINTS):
                r = _run_cell(ds, sp, mix, dist, n_ops, n_keys,
                              backend=backend, tuner=tuner)
                rows.append(r)
                print(f"{ds:5s} {r['index']:12s} {mix:7s} {dist:10s} "
                      f"{r['ops_per_s']/1e3:8.1f} kops/s  "
                      f"compactions={r['compactions']}  "
                      f"admitted={r['admitted']}  "
                      f"retuned={r['retuned']}  "
                      f"drift={r['drift_tv']:.2f}  "
                      f"verified={r['verified_vs_oracle']}", flush=True)
    path = os.path.join(out_dir, "mixed_workload.json"
                        if autotune is None else
                        "mixed_workload_autotune.json")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {path}")
    n_bad = sum(not r["verified_vs_oracle"] for r in rows)
    if n_bad:
        raise SystemExit(f"{n_bad}/{len(rows)} cells NOT verified vs oracle")
    return rows


def smoke(backend=None):
    """CI cell: insert-heavy zipfian trace on one index, threshold low
    enough to force at least one compaction; fails on any unverified op
    or on a run that never compacted."""
    rows = run(n_ops=min(N_OPS, 2_000), n_keys=min(C.N_KEYS, 20_000),
               datasets=["amzn"], indexes=["rmi"],
               mix_points=[("ycsb_a", "zipfian")], backend=backend)
    if rows[0]["compactions"] < 1:
        raise SystemExit("smoke cell performed no compaction")
    return rows


if __name__ == "__main__":
    _ns = C.bench_args()
    if _ns.smoke:
        smoke(_ns.backend)
    else:
        run(backend=_ns.backend, spec=_ns.spec, autotune=_ns.autotune)
