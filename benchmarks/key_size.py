"""Paper Fig. 10 / §4.2.2: 32-bit vs 64-bit keys.

The paper found 32-bit floats lose precision ("caused floating point
errors"); our f32 kernel path fixes that with re-verified error tables
(kernels/rmi_lookup), so we additionally benchmark kernel-path lookups on
both widths — the beyond-paper column.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import _common as C


def _to_32bit(keys: np.ndarray) -> np.ndarray:
    scaled = (keys.astype(np.float64) / keys.max() * (2**31 - 1)).astype(np.uint64)
    return np.unique(scaled)


def run(ds="amzn", out_dir="benchmarks/results", backend=None):
    import jax.numpy as jnp
    from repro.core.spec import IndexSpec
    from repro.data import sosd
    from repro.kernels.rmi_lookup import ops as rops

    keys64 = C.dataset(ds)
    keys32 = _to_32bit(keys64)
    rows = []
    for width, keys in (("64bit", keys64), ("32bit", keys32)):
        q = sosd.make_queries(keys, C.N_QUERIES, seed=3)
        data_jnp, q_jnp = jnp.asarray(keys), jnp.asarray(q)
        for sp in [IndexSpec("rmi", dict(branching=4096)),
                   IndexSpec("pgm", dict(eps=64)),
                   IndexSpec("radix_spline", dict(eps=32, radix_bits=16)),
                   IndexSpec("btree", dict(sample=8))]:
            b = C.build_index(sp, keys)
            fn = C.full_lookup_fn(b, data_jnp, backend=backend)
            secs = C.time_lookup(fn, q_jnp)
            rows.append([width, b.name, b.size_bytes,
                         round(C.ns_per_lookup(secs, len(q)), 2), "f64-core"])
        # kernel path (f32 inference, verified error tables)
        st = rops.prepare_f32_state(keys, branching=4096)
        lb = np.searchsorted(keys, q)
        import jax
        kfn = jax.jit(lambda qq: rops.rmi_lookup(st, data_jnp, qq,
                                                 interpret=True))
        got = np.asarray(kfn(q_jnp))
        assert (got == lb).all(), "f32 kernel path must stay exact"
        rows.append([width, "rmi_kernel_f32", int(st.a2.nbytes * 2
                                                  + st.err.nbytes),
                     "n/a(interpret)", "f32-kernel-verified-exact"])
    C.emit(rows, header=["key_width", "index", "size_bytes", "ns_per_lookup",
                         "note"],
           path=os.path.join(out_dir, "key_size.csv"))
    return rows


if __name__ == "__main__":
    run(backend=C.backend_arg())
