"""Run every paper-table benchmark; print ``name,us_per_call,derived`` CSV.

One line per benchmark module (aggregate timing) plus detailed CSVs under
benchmarks/results/.  ``SOSD_N`` / ``SOSD_Q`` env vars scale the workload
(defaults keep single-core CPU runtime reasonable).
"""
from __future__ import annotations

import os
import sys
import time

# runnable both as `python -m benchmarks.run` and `python benchmarks/run.py`
# (repo root for the `benchmarks` package, src/ for `repro` when PYTHONPATH
# wasn't exported)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
if "repro" not in sys.modules:
    sys.path.insert(0, os.path.join(_ROOT, "src"))

os.environ.setdefault("SOSD_N", "200000")
os.environ.setdefault("SOSD_Q", "50000")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=("jnp", "pallas"),
                    default=os.environ.get("SOSD_BACKEND", "jnp"),
                    help="LookupPlan backend for every lookup benchmark "
                         "(pallas = kernel path, interpret mode on CPU)")
    ap.add_argument("--autotune", type=int, default=None, metavar="BYTES",
                    help="add the budget-tuner rows: per-dataset "
                         "spec+backend selection under this hard byte "
                         "budget (pareto_autotune), and compaction-retuned "
                         "mixed-workload cells")
    args = ap.parse_args()
    # _common reads the env at import; set it before the imports below
    os.environ["SOSD_BACKEND"] = args.backend

    from benchmarks import (batching_effects, build_times, explain, key_size,
                            mixed_workload, moe_dispatch, pareto,
                            parallel_scaling, scaling, search_fn,
                            serve_throughput)

    print(f"# backend={args.backend}")
    print("name,us_per_call,derived")
    jobs = [
        ("pareto_fig7", pareto.run, lambda rows: pareto.pareto_summary(rows)),
        ("scaling_fig9", scaling.run, lambda rows: f"{len(rows)}pts"),
        ("key_size_fig10", key_size.run, lambda rows: f"{len(rows)}pts"),
        ("search_fn_fig11", search_fn.run, lambda rows: f"{len(rows)}pts"),
        ("explain_fig12", lambda: explain.run()[1],
         lambda s: f"R2={s['multi_metric_r2']}"),
        ("batching_fig14_15", batching_effects.run,
         lambda rows: f"max_slowdown={max(r[-1] for r in rows)}"),
        ("parallel_fig16", parallel_scaling.run, lambda rows: f"{len(rows)}pts"),
        ("build_times_fig17", build_times.run, lambda rows: f"{len(rows)}pts"),
        ("moe_dispatch_technique", moe_dispatch.run,
         lambda rows: "; ".join(f"{r[0]}:{r[2]}x" for r in rows
                                if r[1] == "dense/sorted-flop-ratio")),
        ("serve_throughput", serve_throughput.run,
         lambda rows: f"verified={sum(r['verified_vs_core'] for r in rows)}"
                      f"/{len(rows)}"),
        ("mixed_workload", mixed_workload.run,
         lambda rows: f"verified={sum(r['verified_vs_oracle'] for r in rows)}"
                      f"/{len(rows)};compactions="
                      f"{sum(r['compactions'] for r in rows)}"),
    ]
    if args.autotune is not None:
        jobs.append((
            "pareto_autotune",
            lambda: pareto.run_autotune(budget=args.autotune),
            lambda rows: "; ".join(f"{r[0]}:{r[1]}@{r[3]}B" for r in rows)))
        jobs.append((
            "mixed_workload_autotuned",
            lambda: mixed_workload.run(autotune=args.autotune),
            lambda rows: f"verified="
                         f"{sum(r['verified_vs_oracle'] for r in rows)}"
                         f"/{len(rows)};retuned="
                         f"{sum(r['retuned'] for r in rows)}"))
    for name, fn, derive in jobs:
        t0 = time.perf_counter()
        result = fn()
        us = (time.perf_counter() - t0) * 1e6
        try:
            derived = derive(result)
        except Exception:  # noqa: BLE001
            derived = "?"
        print(f"{name},{us:.0f},{str(derived).replace(',', ';')}", flush=True)

    # roofline table if the dry-run artifacts exist
    path = "benchmarks/results/dryrun_single_pod.json"
    if os.path.exists(path):
        from benchmarks import roofline

        print("\n== roofline (single pod 16x16) ==")
        print(roofline.table(path))


if __name__ == "__main__":
    main()
