"""Paper Fig. 12/13 + §4.3: explanatory analysis.

Regression of measured lookup latency on the TPU-era counter analogues
(bytes_touched, probes, flops — DESIGN.md §7) plus size/log2_err; the
paper's claims to reproduce: (a) no single metric explains performance,
(b) the data-movement metric has the largest explanatory power,
(c) size and log2_err are subsumed by the movement/probe metrics.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import _common as C


def run(datasets=("amzn", "face", "osm", "wiki"), out_dir="benchmarks/results",
        backend=None):
    import jax.numpy as jnp
    from repro.core import analysis, base, tuning

    records = []
    for ds in datasets:
        keys = C.dataset(ds)
        q = C.queries(ds)
        data_jnp, q_jnp = jnp.asarray(keys), jnp.asarray(q)
        lb = np.searchsorted(keys, q)
        for build in tuning.sweep(keys, names=("rmi", "pgm", "radix_spline",
                                               "btree", "rbs"),
                                  max_configs=5):
            lo, hi = build.lookup(build.state, q_jnp)
            widths = np.maximum(np.asarray(hi) - np.asarray(lo) + 1, 1)
            fn = C.full_lookup_fn(build, data_jnp, backend=backend)
            secs = C.time_lookup(fn, q_jnp)
            rec = analysis.describe(build, widths)
            rec["dataset"] = ds
            rec["ns_per_lookup"] = C.ns_per_lookup(secs, len(q))
            records.append(rec)

    rows = [[r["dataset"], r["name"], r["size_bytes"],
             round(r["log2_err"], 2), r["probes"], r["bytes_touched"],
             r["flops"], round(r["ns_per_lookup"], 1)] for r in records]
    C.emit(rows, header=["dataset", "index", "size_bytes", "log2_err",
                         "probes", "bytes_touched", "flops", "ns_per_lookup"],
           path=os.path.join(out_dir, "explain.csv"))

    multi = analysis.regress(records)
    singles = analysis.single_metric_r2(records)
    with_size = analysis.regress(
        records, x_keys=("bytes_touched", "probes", "flops",
                         "size_bytes", "log2_err"))
    summary = {
        "multi_metric_r2": round(multi["r2"], 3),
        "multi_coefs": {k: round(v, 3) for k, v in multi["coef"].items()},
        "single_metric_r2": {k: round(v, 3) for k, v in singles.items()},
        "plus_size_log2err_r2": round(with_size["r2"], 3),
        "n_points": multi["n"],
    }
    print("explain summary:", summary, flush=True)
    return records, summary


if __name__ == "__main__":
    run(backend=C.backend_arg())
