"""Trip-count-aware cost attribution over compiled (post-SPMD) HLO text.

Motivation (measured, see EXPERIMENTS.md §Dry-run): XLA:CPU's
``compiled.cost_analysis()`` counts a while-loop body ONCE, so any model
compiled with scan-over-layers under-reports FLOPs by ~L and hides remat
recompute entirely.  The compiled HLO text, however, contains everything
needed to do it right:

  * every computation body, with result/operand shapes per instruction,
  * ``while`` ops carrying ``backend_config={"known_trip_count":{"n":..}}``
    and their ``body=%comp`` reference,
  * fusion/call/conditional references.

We parse the text, build the call graph, and propagate multipliers from
ENTRY: dot FLOPs (2 * prod(output dims) * prod(contracting dims)) and
collective wire bytes (max of operand/result bytes) are accumulated with
while-trip multipliers.  Shapes in compiled HLO are per-device shard
shapes, so every number is already per-device.

Elementwise FLOPs are ignored (dot-dominated workloads; the roofline
compute term is an MXU term).  This is the tool the §Roofline/§Perf tables
are built from.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "token": 0,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")
_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)")
_CALLEE = re.compile(r"(?:body|to_apply|calls)=(%?[\w.\-]+)")
_COND = re.compile(r"condition=(%?[\w.\-]+)")
_COND_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_INT_CONST = re.compile(r"^[su]\d+\[\]\s+constant\((\d+)\)")
_COMPARE = re.compile(r"compare\((.*?)\),\s*direction=(LT|LE)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.dot_flops = 0.0
        self.coll_bytes: Dict[str, float] = {}
        self.coll_counts: Dict[str, int] = {}
        # (callee, multiplier) — multiplier is the while trip count
        self.calls: List[Tuple[str, float]] = []
        # scalar integer constants defined in this computation, and the
        # loop bound recovered from a ROOT `compare(i, const), LT` — the
        # trip-count source on XLA versions that don't annotate `while`
        # with backend_config known_trip_count (counter starts at 0).
        self.int_consts: Dict[str, int] = {}
        self.cond_bound: Optional[float] = None


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    shapes: Dict[str, str] = {}

    for raw in text.splitlines():
        # computation header: "%name (args...) -> type {" at column 0
        # (args may contain nested parens for tuple types)
        if ((raw.startswith("%") or raw.startswith("ENTRY"))
                and raw.rstrip().endswith("{") and "->" in raw):
            nm = _COMP_NAME.match(raw)
            name = nm.group(1).lstrip("%")
            cur = Computation(name)
            comps[name] = cur
            shapes = {}
            if raw.startswith("ENTRY"):
                entry = name
            # record non-tuple parameter shapes from the header signature
            for pm in re.finditer(r"(%?[\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])",
                                  raw):
                shapes[pm.group(1).lstrip("%")] = pm.group(2)
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(raw)
        if not m:
            continue
        result_name = m.group(1).lstrip("%")
        rhs = m.group(2)
        # result type = leading type expression of rhs
        tm = re.match(r"^((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))",
                      rhs)
        rtype = tm.group(1) if tm else ""
        shapes[result_name] = rtype

        # parameters declared inline:  %p = f32[..] parameter(0)
        if " parameter(" in rhs or rhs.startswith("parameter("):
            continue

        opm = re.search(r"([a-z0-9\-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)

        km = _INT_CONST.match(rhs)
        if km:
            cur.int_consts[result_name] = int(km.group(1))
        if op == "compare":
            pm = _COMPARE.search(rhs)
            if pm:
                for tok in re.findall(r"%?([\w.\-]+)", pm.group(1)):
                    if tok in cur.int_consts:
                        cur.cond_bound = float(
                            cur.int_consts[tok]
                            + (1 if pm.group(2) == "LE" else 0))

        if op == "dot":
            out_dims = _first_shape_dims(rtype) or []
            out_prod = 1
            for d in out_dims:
                out_prod *= d
            # lhs operand name; operands may be printed bare ("dot(x, y)")
            # or typed ("dot(f32[64,256]{1,0} %x, ...)") depending on the
            # XLA version — prefer the first %-token, fall back to bare.
            lhs_name = None
            am = re.search(r"dot\((.*)\)", rhs)
            if am:
                pct = re.findall(r"%([\w.\-]+)", am.group(1))
                if pct:
                    lhs_name = pct[0]
                else:
                    bm = re.match(r"([\w.\-]+)", am.group(1))
                    lhs_name = bm.group(1) if bm else None
            contract = 1
            cm = _CONTRACT.search(rhs)
            if cm and lhs_name and lhs_name in shapes:
                lhs_dims = _first_shape_dims(shapes[lhs_name]) or []
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            cur.dot_flops += 2.0 * out_prod * contract
        elif any(op.startswith(c) for c in _COLLECTIVES):
            kind = next(c for c in _COLLECTIVES if op.startswith(c))
            # wire bytes ~ max(result, operand) per-device bytes
            operand_bytes = 0
            args = re.search(r"\((.*)\)", rhs)
            if args:
                for an in re.findall(r"%?([\w.\-]+)", args.group(1)):
                    if an in shapes:
                        operand_bytes += _shape_bytes(shapes[an])
            nbytes = max(_shape_bytes(rtype), operand_bytes)
            cur.coll_bytes[kind] = cur.coll_bytes.get(kind, 0.0) + nbytes
            cur.coll_counts[kind] = cur.coll_counts.get(kind, 0) + 1

        if op == "while":
            tc = _TRIP.search(rhs)
            trip = float(tc.group(1)) if tc else 1.0
            if tc is None:
                cm = _COND.search(rhs)
                cond = comps.get(cm.group(1).lstrip("%")) if cm else None
                if cond is not None and cond.cond_bound is not None:
                    trip = cond.cond_bound
            for cal in _CALLEE.findall(rhs):
                cur.calls.append((cal.lstrip("%"), trip))
        elif op == "conditional":
            bm = _COND_BRANCHES.search(rhs)
            if bm:
                for cal in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                    cur.calls.append((cal, 1.0))
        else:
            for cal in _CALLEE.findall(rhs):
                cur.calls.append((cal.lstrip("%"), 1.0))

    return comps, entry


def analyze(text: str) -> Dict:
    """Returns trip-count-weighted per-device totals for the program."""
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    memo: Dict[str, Dict] = {}
    active: set = set()

    def visit(name: str) -> Dict:
        if name in memo:
            return memo[name]
        if name not in comps or name in active:
            return {"flops": 0.0, "coll": {}, "counts": {}}
        active.add(name)
        c = comps[name]
        total = {"flops": c.dot_flops,
                 "coll": dict(c.coll_bytes),
                 "counts": dict(c.coll_counts)}
        for callee, mult in c.calls:
            sub = visit(callee)
            total["flops"] += mult * sub["flops"]
            for k, v in sub["coll"].items():
                total["coll"][k] = total["coll"].get(k, 0.0) + mult * v
            for k, v in sub["counts"].items():
                total["counts"][k] = total["counts"].get(k, 0) + mult * v
        active.discard(name)
        memo[name] = total
        return total

    out = visit(entry)
    out["coll_total"] = sum(out["coll"].values())
    return out


def main():
    import sys

    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read()), indent=1))


if __name__ == "__main__":
    main()
