"""Paper Fig. 9: dataset-size scaling (logarithmic slowdown expected)."""
from __future__ import annotations

import os

import numpy as np

from benchmarks import _common as C


def run(sizes=(100_000, 200_000, 400_000, 800_000), ds="amzn",
        out_dir="benchmarks/results", backend=None):
    import jax.numpy as jnp
    from repro.core.spec import IndexSpec
    from repro.data import sosd

    configs = [IndexSpec("rmi", dict(branching=4096)),
               IndexSpec("pgm", dict(eps=64)),
               IndexSpec("radix_spline", dict(eps=32, radix_bits=16)),
               IndexSpec("btree", dict(sample=8)),
               IndexSpec("binary_search")]
    rows = []
    for n in sizes:
        keys = sosd.generate(ds, n, seed=1)
        q = sosd.make_queries(keys, C.N_QUERIES, seed=2)
        data_jnp, q_jnp = jnp.asarray(keys), jnp.asarray(q)
        for sp in configs:
            b = C.build_index(sp, keys)
            name = b.name
            fn = C.full_lookup_fn(b, data_jnp, backend=backend)
            secs = C.time_lookup(fn, q_jnp)
            rows.append([ds, n, name, b.size_bytes,
                         round(C.ns_per_lookup(secs, len(q)), 2)])
    C.emit(rows, header=["dataset", "n_keys", "index", "size_bytes",
                         "ns_per_lookup"],
           path=os.path.join(out_dir, "scaling.csv"))
    return rows


if __name__ == "__main__":
    run(backend=C.backend_arg())
