"""Shared benchmark infra: timing, dataset cache, CSV emission.

Numbers here are REAL wall-clock measurements of the JAX index structures
on this host (relative comparisons across structures; the paper's absolute
ns/lookup are Xeon numbers and ours is a batched-throughput regime — see
DESIGN.md §7 change-log)."""
from __future__ import annotations

import functools
import os
import time
from typing import Callable, Dict

import numpy as np

N_KEYS = int(os.environ.get("SOSD_N", 400_000))
N_QUERIES = int(os.environ.get("SOSD_Q", 100_000))
REPEATS = int(os.environ.get("SOSD_REPEATS", 3))
#: Lookup-plan backend axis ("jnp" | "pallas") — every lookup benchmark
#: accepts --backend / SOSD_BACKEND and threads it through the plan IR.
BACKEND = os.environ.get("SOSD_BACKEND", "jnp")


@functools.lru_cache(maxsize=None)
def dataset(name: str, n: int = N_KEYS, seed: int = 1):
    from repro.data import sosd

    return sosd.generate(name, n, seed=seed)


@functools.lru_cache(maxsize=None)
def queries(name: str, m: int = N_QUERIES, seed: int = 2):
    from repro.data import sosd

    return sosd.make_queries(dataset(name), m, seed=seed, present_frac=0.8)


def time_lookup(fn: Callable, *args, repeats: int = REPEATS) -> float:
    """Best-of-k wall time of a jitted callable, seconds."""
    import jax

    out = fn(*args)  # compile + warm
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def full_lookup_fn(build, data_jnp, last_mile=None, backend=None):
    """jit'd end-to-end lookup: lower the build to its `LookupPlan`
    (repro.core.plan) and compile for the requested backend (default:
    the --backend / SOSD_BACKEND axis).  ``last_mile`` None defers to
    the build's own hyperparameter (binary unless the index chose
    otherwise — ibtree's interpolation probe must actually run)."""
    from repro.core import plan

    return plan.lower(build, data_jnp, last_mile=last_mile).compile(
        backend=backend or BACKEND)


def build_index(spec, keys, hyper=None):
    """Build one index through THE entry point (`repro.core.spec.build`).

    ``spec`` is an `IndexSpec` or an index name (then ``hyper`` holds
    the partial hyperparameters) — either way the build is validated
    and carries its spec."""
    from repro.core import spec as S

    return S.build(S.coerce(spec, hyper), keys)


def parse_spec(text):
    """`--spec` / SOSD_SPEC value: inline IndexSpec JSON, or @file.json."""
    from repro.core import spec as S

    if text.startswith("@"):
        with open(text[1:]) as f:
            text = f.read()
    return S.IndexSpec.from_json(text).validated()


#: Default hard byte budget when --autotune is passed with no value.
AUTOTUNE_DEFAULT_BYTES = 1 << 20


def tuned_spec(ds: str, budget: int, names=None, backends=("jnp",),
               max_configs=None, n: int = None, seed: int = 0):
    """Tune one dataset under a byte budget (cached per cell): the
    per-dataset spec+backend the --autotune axes run with."""
    key = (ds, budget, tuple(names or ()), tuple(backends), max_configs,
           n or N_KEYS, seed)
    res = _TUNED.get(key)
    if res is None:
        from repro.core.spec import Tuner

        res = Tuner(names=names, max_bytes=budget, backends=backends,
                    max_configs=max_configs, seed=seed).tune(
                        dataset(ds, n=n or N_KEYS))
        _TUNED[key] = res
    return res


_TUNED: Dict = {}


def backend_arg(argv=None):
    """Parse --backend from argv (benchmark __main__s); also updates the
    module-level default so nested helpers pick it up."""
    return bench_args(argv).backend


def bench_args(argv=None):
    """Shared benchmark axes: ``--backend`` (plan backend), ``--spec``
    (IndexSpec JSON or @file — run ONE declarative spec instead of the
    hand-rolled cells), ``--autotune [MAX_BYTES]`` (let the budget
    tuner pick the per-dataset spec), ``--smoke`` (tiny CI cell).
    Env fallbacks: SOSD_BACKEND / SOSD_SPEC / SOSD_AUTOTUNE."""
    import argparse

    global BACKEND
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default=BACKEND)
    ap.add_argument("--spec", default=os.environ.get("SOSD_SPEC"))
    ap.add_argument("--autotune", nargs="?",
                    const=str(AUTOTUNE_DEFAULT_BYTES),
                    default=os.environ.get("SOSD_AUTOTUNE"))
    ap.add_argument("--smoke", action="store_true")
    ns, _ = ap.parse_known_args(argv)
    BACKEND = ns.backend
    ns.spec = parse_spec(ns.spec) if ns.spec else None
    ns.autotune = int(ns.autotune) if ns.autotune is not None else None
    return ns


def emit(rows, header=None, path=None):
    lines = []
    if header:
        lines.append(",".join(header))
    for r in rows:
        lines.append(",".join(str(x) for x in r))
    text = "\n".join(lines)
    print(text, flush=True)
    if path:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(text + "\n")
    return text


def ns_per_lookup(seconds: float, m: int) -> float:
    return seconds / m * 1e9
