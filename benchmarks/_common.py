"""Shared benchmark infra: timing, dataset cache, CSV emission.

Numbers here are REAL wall-clock measurements of the JAX index structures
on this host (relative comparisons across structures; the paper's absolute
ns/lookup are Xeon numbers and ours is a batched-throughput regime — see
DESIGN.md §7 change-log)."""
from __future__ import annotations

import functools
import os
import time
from typing import Callable, Dict

import numpy as np

N_KEYS = int(os.environ.get("SOSD_N", 400_000))
N_QUERIES = int(os.environ.get("SOSD_Q", 100_000))
REPEATS = int(os.environ.get("SOSD_REPEATS", 3))
#: Lookup-plan backend axis ("jnp" | "pallas") — every lookup benchmark
#: accepts --backend / SOSD_BACKEND and threads it through the plan IR.
BACKEND = os.environ.get("SOSD_BACKEND", "jnp")


@functools.lru_cache(maxsize=None)
def dataset(name: str, n: int = N_KEYS, seed: int = 1):
    from repro.data import sosd

    return sosd.generate(name, n, seed=seed)


@functools.lru_cache(maxsize=None)
def queries(name: str, m: int = N_QUERIES, seed: int = 2):
    from repro.data import sosd

    return sosd.make_queries(dataset(name), m, seed=seed, present_frac=0.8)


def time_lookup(fn: Callable, *args, repeats: int = REPEATS) -> float:
    """Best-of-k wall time of a jitted callable, seconds."""
    import jax

    out = fn(*args)  # compile + warm
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def full_lookup_fn(build, data_jnp, last_mile: str = "binary",
                   backend=None):
    """jit'd end-to-end lookup: lower the build to its `LookupPlan`
    (repro.core.plan) and compile for the requested backend (default:
    the --backend / SOSD_BACKEND axis)."""
    from repro.core import plan

    return plan.lower(build, data_jnp, last_mile=last_mile).compile(
        backend=backend or BACKEND)


def backend_arg(argv=None):
    """Parse --backend from argv (benchmark __main__s); also updates the
    module-level default so nested helpers pick it up."""
    import argparse

    global BACKEND
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default=BACKEND)
    ns, _ = ap.parse_known_args(argv)
    BACKEND = ns.backend
    return ns.backend


def emit(rows, header=None, path=None):
    lines = []
    if header:
        lines.append(",".join(header))
    for r in rows:
        lines.append(",".join(str(x) for x in r))
    text = "\n".join(lines)
    print(text, flush=True)
    if path:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(text + "\n")
    return text


def ns_per_lookup(seconds: float, m: int) -> float:
    return seconds / m * 1e9
