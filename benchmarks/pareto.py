"""Paper Fig. 7 / Table 2: size x lookup-latency Pareto analysis.

For each dataset, sweep each structure's size ladder, measure batched
end-to-end lookup time, report all points + the Pareto frontier, and check
the paper's headline claims (learned structures Pareto-competitive on
amzn/face/wiki; rbs strong on osm; hash fastest point lookups).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks import _common as C


def run(datasets=("amzn", "face", "osm", "wiki"), out_dir="benchmarks/results",
        backend=None):
    import jax.numpy as jnp
    from repro.core import base, tuning

    rows = []
    for ds in datasets:
        keys = C.dataset(ds)
        q = C.queries(ds)
        data_jnp = jnp.asarray(keys)
        q_jnp = jnp.asarray(q)
        lb = np.searchsorted(keys, q)
        for build in tuning.sweep(keys, names=("rmi", "pgm", "radix_spline",
                                               "btree", "rbs", "binary_search")):
            fn = C.full_lookup_fn(build, data_jnp, backend=backend)
            secs = C.time_lookup(fn, q_jnp)
            got = np.asarray(fn(q_jnp))
            exact = bool((got == lb).all())
            rows.append([ds, build.name, json.dumps(build.hyper).replace(",", ";"),
                         build.size_bytes,
                         round(C.ns_per_lookup(secs, len(q)), 2), exact])
        # hash baseline: point lookups only (Table 2 companion)
        hb = base.REGISTRY["robin_hash"](keys, load_factor=0.5)
        import jax
        hfn = jax.jit(lambda qq: hb.lookup(hb.state, qq))
        present = keys[np.random.default_rng(0).integers(0, len(keys), len(q))]
        secs = C.time_lookup(hfn, jnp.asarray(present))
        rows.append([ds, "robin_hash", "{'load_factor': 0.5}",
                     hb.size_bytes, round(C.ns_per_lookup(secs, len(q)), 2),
                     True])
    C.emit(rows, header=["dataset", "index", "hyper", "size_bytes",
                         "ns_per_lookup", "exact"],
           path=os.path.join(out_dir, "pareto.csv"))
    return rows


def pareto_summary(rows):
    """Per-dataset Pareto frontier membership by family."""
    from repro.core.base import pareto_front

    out = {}
    for ds in sorted({r[0] for r in rows}):
        pts = [(r[3], r[4], r[1]) for r in rows
               if r[0] == ds and r[1] != "robin_hash"]
        front = pareto_front(pts)
        out[ds] = sorted({name for _, _, name in front})
    return out


if __name__ == "__main__":
    rows = run(backend=C.backend_arg())
    print("\npareto frontier families:", pareto_summary(rows))
