"""Paper Fig. 7 / Table 2: size x lookup-latency Pareto analysis.

For each dataset, sweep each structure's schema-generated spec ladder
(`repro.core.tuning` — every build goes through the declarative
`IndexSpec` entry point), measure batched end-to-end lookup time,
report all points + the Pareto frontier, and check the paper's
headline claims (learned structures Pareto-competitive on
amzn/face/wiki; rbs strong on osm; hash fastest point lookups).

Axes:
    --spec JSON|@file    benchmark ONE declarative spec per dataset
    --autotune [BYTES]   per-dataset budget tuning: the `spec.Tuner`
                         picks spec+backend under a hard byte budget
                         (both plan backends measured); fails nonzero
                         if the chosen build violates the budget
    --smoke              tiny autotune cell (2 indexes, capped ladders)
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/pareto.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import _common as C


def run(datasets=("amzn", "face", "osm", "wiki"), out_dir="benchmarks/results",
        backend=None, spec=None):
    import jax.numpy as jnp
    from repro.core import tuning

    rows = []
    for ds in datasets:
        keys = C.dataset(ds)
        q = C.queries(ds)
        data_jnp = jnp.asarray(keys)
        q_jnp = jnp.asarray(q)
        lb = np.searchsorted(keys, q)
        specs = [spec] if spec is not None else tuning.spec_sweep()
        for sp in specs:
            # an explicit --spec declares its own backend; sweep cells
            # run on the --backend axis.  The recorded spec must name
            # what was MEASURED, so the CSV row reproduces the cell.
            be = sp.backend if spec is not None else (backend or C.BACKEND)
            build = C.build_index(sp, keys)
            fn = C.full_lookup_fn(build, data_jnp, backend=be)
            secs = C.time_lookup(fn, q_jnp)
            got = np.asarray(fn(q_jnp))
            exact = bool((got == lb).all())
            measured = sp.replace(
                backend=be, last_mile=build.hyper.get("last_mile"))
            rows.append([ds, build.name, measured.to_json().replace(",", ";"),
                         build.size_bytes,
                         round(C.ns_per_lookup(secs, len(q)), 2), exact])
        # hash baseline: point lookups only (Table 2 companion)
        hb = C.build_index("robin_hash", keys, dict(load_factor=0.5))
        import jax
        hfn = jax.jit(lambda qq: hb.lookup(hb.state, qq))
        present = keys[np.random.default_rng(0).integers(0, len(keys), len(q))]
        secs = C.time_lookup(hfn, jnp.asarray(present))
        rows.append([ds, "robin_hash",
                     hb.meta["spec"].to_json().replace(",", ";"),
                     hb.size_bytes, round(C.ns_per_lookup(secs, len(q)), 2),
                     True])
    C.emit(rows, header=["dataset", "index", "spec", "size_bytes",
                         "ns_per_lookup", "exact"],
           path=os.path.join(out_dir, "pareto.csv"))
    return rows


def pareto_summary(rows):
    """Per-dataset Pareto frontier membership by family."""
    from repro.core.base import pareto_front

    out = {}
    for ds in sorted({r[0] for r in rows}):
        pts = [(r[3], r[4], r[1]) for r in rows
               if r[0] == ds and r[1] != "robin_hash"]
        front = pareto_front(pts)
        out[ds] = sorted({name for _, _, name in front})
    return out


def run_autotune(budget: int, datasets=("amzn", "face", "osm", "wiki"),
                 out_dir="benchmarks/results", smoke=False,
                 backends=("jnp", "pallas")):
    """Budget-tuned Pareto companion: one chosen spec per dataset.

    Each cell runs the `spec.Tuner` under a HARD ``budget`` bytes cap
    (backend picked by measurement across ``backends``), verifies the
    tuned build returns exact LB ranks, and re-checks the byte budget
    on the BUILT index — a tuner that returns a spec violating its own
    budget exits nonzero (the CI contract)."""
    import jax.numpy as jnp
    from repro.core.spec import Tuner

    names = ("rmi", "pgm") if smoke else None
    rows = []
    for ds in datasets if not smoke else datasets[:1]:
        keys = C.dataset(ds)
        q = C.queries(ds)
        res = Tuner(names=names, max_bytes=budget, backends=backends,
                    max_configs=3 if smoke else None).tune(keys)
        build = res.build
        fn = C.full_lookup_fn(build, jnp.asarray(keys),
                              backend=res.spec.backend)
        got = np.asarray(fn(jnp.asarray(q)))
        exact = bool((got == np.searchsorted(keys, q)).all())
        within = build.size_bytes <= budget
        rows.append([ds, res.spec.index, res.spec.to_json().replace(",", ";"),
                     build.size_bytes, budget,
                     round(min(c.cost_ns for c in res.frontier), 1)
                     if res.frontier else "",
                     {k: round(v, 1) for k, v in res.backend_ns.items()},
                     len(res.evaluated), exact, within])
    C.emit(rows, header=["dataset", "index", "spec", "size_bytes",
                         "budget_bytes", "frontier_min_cost_ns",
                         "backend_ns", "n_evaluated", "exact",
                         "within_budget"],
           path=os.path.join(out_dir, "pareto_autotune.csv"))
    bad = [r for r in rows if not (r[-1] and r[-2])]
    if bad:
        raise SystemExit(
            f"{len(bad)}/{len(rows)} autotuned cells violated the byte "
            f"budget or returned inexact lookups: {bad}")
    return rows


if __name__ == "__main__":
    ns = C.bench_args()
    if ns.autotune is not None:
        run_autotune(budget=ns.autotune, smoke=ns.smoke)
    else:
        rows = run(backend=ns.backend, spec=ns.spec)
        print("\npareto frontier families:", pareto_summary(rows))
