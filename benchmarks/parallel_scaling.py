"""Paper Fig. 16 analogue: throughput under parallel load.

The paper varies threads; a TPU varies (a) the query batch per dispatch,
(b) the index size at fixed load (Fig. 16b), and (c) the device count —
queries sharded over a `data` mesh axis through repro.dist, every device
running the fused lookup on its shard (DESIGN.md §7 change-log).
Throughput = lookups/second of the fused batched pipeline; the
cache-miss-per-second proxy is bytes_touched * throughput.

Mode (c) uses every local device (1 on this CPU container — the row then
records the sharded-path overhead; on a TPU slice or with
``--xla_force_host_platform_device_count`` it records real scaling).

Mode (d), enabled by ``--topology routed`` (or ``both`` for the A/B),
measures the range-routed shard mesh (DESIGN.md §16) against broadcast
dispatch through the full serving stack: per-shard tuned indexes,
scatter/gather micro-batching, and the per-device-work reduction
O(batch) -> O(batch/shards).  Rows carry per-device keys and request
p99 so the routed-vs-broadcast column is a like-for-like comparison.
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # `python benchmarks/parallel_scaling.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import _common as C

#: Shard count of the routed topology cells (SERVE_SHARDS env overrides).
N_SHARDS = int(os.environ.get("SERVE_SHARDS", 4))


def _shard_queries(q, mesh):
    """Place the query batch sharded over the mesh's data axis via the
    dist layer's activation rules; jit picks the sharding up from the
    input, so the lookup fn itself is the shared _common one."""
    import jax
    from repro.dist import sharding as SH

    return jax.device_put(q, SH.act_sharding(q.shape, ("batch",), mesh))


def _topology_cell(keys, q, sp, shards, backend, batch=4096):
    """One serving-stack cell: throughput, per-device keys, request p99."""
    import numpy as np
    from repro.serve.lookup import LookupService, LookupServiceConfig

    import time

    svc = LookupService(keys, LookupServiceConfig(
        spec=sp, max_batch=batch, deadline_ms=0.0, executor="sync",
        backend=backend or C.BACKEND, shards=shards))
    per_req = 64
    m = (len(q) // per_req) * per_req
    svc.lookup(np.asarray(q[:per_req]))        # compile + warm every lane
    t0 = time.perf_counter()
    for i in range(0, m, per_req):
        svc.lookup(np.asarray(q[i:i + per_req]))
    secs = time.perf_counter() - t0
    snap = svc.metrics.snapshot()
    dev_keys = per_req / max(svc.dispatcher.n_shards, 1)
    return (m / secs, dev_keys, snap["p99_request_ms"])


def _topology_rows(ds, keys, q, backend, topology):
    """Mode (d): routed shard mesh vs broadcast through the serving
    stack (DESIGN.md §16).  Emits one row per (index, topology) with
    per-device keys and request p99 in the trailing columns."""
    from repro.core.spec import IndexSpec

    rows = []
    shard_axis = {"routed": [N_SHARDS], "both": [1, N_SHARDS]}[topology]
    for sp in [IndexSpec("rmi", dict(branching=1024)),
               IndexSpec("pgm", dict(eps=64))]:
        ab = {}
        for shards in shard_axis:
            topo = "routed" if shards > 1 else "broadcast"
            tput, dev_keys, p99 = _topology_cell(keys, q, sp, shards,
                                                 backend)
            ab[topo] = tput
            rows.append(["topology_" + topo, sp.index, shards,
                         round(tput / 1e6, 3), "",
                         round(dev_keys, 1), round(p99, 3)])
        if len(ab) == 2:
            print(f"  A/B {sp.index}: routed/broadcast throughput "
                  f"{ab['routed'] / ab['broadcast']:.2f}x, per-device "
                  f"keys {1 / N_SHARDS:.2f}x", flush=True)
    return rows


def run(ds="amzn", out_dir="benchmarks/results", backend=None,
        topology="broadcast"):
    import numpy as np
    import jax.numpy as jnp
    from repro.core import analysis
    from repro.core.spec import IndexSpec

    keys = C.dataset(ds)
    q = C.queries(ds)
    data_jnp = jnp.asarray(keys)
    rows = []
    # (a) batch scaling
    for sp in [IndexSpec("rmi", dict(branching=4096)),
               IndexSpec("pgm", dict(eps=64)),
               IndexSpec("radix_spline", dict(eps=32, radix_bits=16)),
               IndexSpec("rbs", dict(radix_bits=16))]:
        b = C.build_index(sp, keys)
        fn = C.full_lookup_fn(b, data_jnp, backend=backend)
        for m in (1_000, 10_000, 100_000):
            qm = jnp.asarray(q[:m])
            secs = C.time_lookup(fn, qm)
            rows.append(["batch_scaling", b.name, m,
                         round(m / secs / 1e6, 3), ""])
    # (b) size vs throughput at fixed load
    for name, ladder in [("rmi", [dict(branching=2**i) for i in (8, 12, 16)]),
                         ("pgm", [dict(eps=e) for e in (512, 64, 16)]),
                         ("btree", [dict(sample=s) for s in (64, 8, 1)])]:
        for hyper in ladder:
            b = C.build_index(IndexSpec(name, hyper), keys)
            fn = C.full_lookup_fn(b, data_jnp, backend=backend)
            qm = jnp.asarray(q)
            secs = C.time_lookup(fn, qm)
            lo, hi = b.lookup(b.state, qm)
            widths = np.maximum(np.asarray(hi) - np.asarray(lo) + 1, 1)
            rec = analysis.describe(b, widths)
            thpt = len(q) / secs
            rows.append(["size_scaling", name, b.size_bytes,
                         round(thpt / 1e6, 3),
                         round(rec["bytes_touched"] * thpt / 1e9, 2)])
    # (c) sharded dispatch: queries split over the data mesh axis
    import jax

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    for sp in [IndexSpec("rmi", dict(branching=4096)),
               IndexSpec("pgm", dict(eps=64))]:
        b = C.build_index(sp, keys)
        fn = C.full_lookup_fn(b, data_jnp, backend=backend)
        m = (len(q) // n_dev) * n_dev
        qm = _shard_queries(jnp.asarray(q[:m]), mesh)
        secs = C.time_lookup(fn, qm)
        rows.append(["sharded_dispatch", b.name, n_dev,
                     round(m / secs / 1e6, 3), ""])
    # (d) serving topology A/B: routed shard mesh vs broadcast
    if topology in ("routed", "both"):
        rows += _topology_rows(ds, keys, q, backend, topology)
    rows = [r + [""] * (7 - len(r)) for r in rows]
    C.emit(rows, header=["mode", "index", "x", "mlookups_per_s",
                         "gbytes_touched_per_s", "per_device_keys",
                         "p99_request_ms"],
           path=os.path.join(out_dir, "parallel_scaling.csv"))
    return rows


if __name__ == "__main__":
    import argparse

    _ap = argparse.ArgumentParser(add_help=False)
    _ap.add_argument("--topology", choices=("broadcast", "routed", "both"),
                     default="broadcast",
                     help="add mode (d): serve-stack cells comparing the "
                          "range-routed shard mesh to broadcast dispatch")
    _opts, _ = _ap.parse_known_args()
    run(backend=C.backend_arg(), topology=_opts.topology)
