"""Paper Fig. 16 analogue: throughput under parallel load.

The paper varies threads; a TPU varies (a) the query batch per dispatch
and (b) the index size at fixed load (Fig. 16b).  Throughput here =
lookups/second of the fused batched pipeline; the cache-miss-per-second
proxy is bytes_touched * throughput.
"""
from __future__ import annotations

import os

from benchmarks import _common as C


def run(ds="amzn", out_dir="benchmarks/results"):
    import numpy as np
    import jax.numpy as jnp
    from repro.core import analysis, base

    keys = C.dataset(ds)
    q = C.queries(ds)
    data_jnp = jnp.asarray(keys)
    rows = []
    # (a) batch scaling
    for name, hyper in [("rmi", dict(branching=4096)),
                        ("pgm", dict(eps=64)),
                        ("radix_spline", dict(eps=32, radix_bits=16)),
                        ("rbs", dict(radix_bits=16))]:
        b = base.REGISTRY[name](keys, **hyper)
        fn = C.full_lookup_fn(b, data_jnp)
        for m in (1_000, 10_000, 100_000):
            qm = jnp.asarray(q[:m])
            secs = C.time_lookup(fn, qm)
            rows.append(["batch_scaling", name, m,
                         round(m / secs / 1e6, 3), ""])
    # (b) size vs throughput at fixed load
    for name, ladder in [("rmi", [dict(branching=2**i) for i in (8, 12, 16)]),
                         ("pgm", [dict(eps=e) for e in (512, 64, 16)]),
                         ("btree", [dict(sample=s) for s in (64, 8, 1)])]:
        for hyper in ladder:
            b = base.REGISTRY[name](keys, **hyper)
            fn = C.full_lookup_fn(b, data_jnp)
            qm = jnp.asarray(q)
            secs = C.time_lookup(fn, qm)
            lo, hi = b.lookup(b.state, qm)
            widths = np.maximum(np.asarray(hi) - np.asarray(lo) + 1, 1)
            rec = analysis.describe(b, widths)
            thpt = len(q) / secs
            rows.append(["size_scaling", name, b.size_bytes,
                         round(thpt / 1e6, 3),
                         round(rec["bytes_touched"] * thpt / 1e9, 2)])
    C.emit(rows, header=["mode", "index", "x", "mlookups_per_s",
                         "gbytes_touched_per_s"],
           path=os.path.join(out_dir, "parallel_scaling.csv"))
    return rows


if __name__ == "__main__":
    run()
