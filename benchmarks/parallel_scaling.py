"""Paper Fig. 16 analogue: throughput under parallel load.

The paper varies threads; a TPU varies (a) the query batch per dispatch,
(b) the index size at fixed load (Fig. 16b), and (c) the device count —
queries sharded over a `data` mesh axis through repro.dist, every device
running the fused lookup on its shard (DESIGN.md §7 change-log).
Throughput = lookups/second of the fused batched pipeline; the
cache-miss-per-second proxy is bytes_touched * throughput.

Mode (c) uses every local device (1 on this CPU container — the row then
records the sharded-path overhead; on a TPU slice or with
``--xla_force_host_platform_device_count`` it records real scaling).
"""
from __future__ import annotations

import os

from benchmarks import _common as C


def _shard_queries(q, mesh):
    """Place the query batch sharded over the mesh's data axis via the
    dist layer's activation rules; jit picks the sharding up from the
    input, so the lookup fn itself is the shared _common one."""
    import jax
    from repro.dist import sharding as SH

    return jax.device_put(q, SH.act_sharding(q.shape, ("batch",), mesh))


def run(ds="amzn", out_dir="benchmarks/results", backend=None):
    import numpy as np
    import jax.numpy as jnp
    from repro.core import analysis
    from repro.core.spec import IndexSpec

    keys = C.dataset(ds)
    q = C.queries(ds)
    data_jnp = jnp.asarray(keys)
    rows = []
    # (a) batch scaling
    for sp in [IndexSpec("rmi", dict(branching=4096)),
               IndexSpec("pgm", dict(eps=64)),
               IndexSpec("radix_spline", dict(eps=32, radix_bits=16)),
               IndexSpec("rbs", dict(radix_bits=16))]:
        b = C.build_index(sp, keys)
        fn = C.full_lookup_fn(b, data_jnp, backend=backend)
        for m in (1_000, 10_000, 100_000):
            qm = jnp.asarray(q[:m])
            secs = C.time_lookup(fn, qm)
            rows.append(["batch_scaling", b.name, m,
                         round(m / secs / 1e6, 3), ""])
    # (b) size vs throughput at fixed load
    for name, ladder in [("rmi", [dict(branching=2**i) for i in (8, 12, 16)]),
                         ("pgm", [dict(eps=e) for e in (512, 64, 16)]),
                         ("btree", [dict(sample=s) for s in (64, 8, 1)])]:
        for hyper in ladder:
            b = C.build_index(IndexSpec(name, hyper), keys)
            fn = C.full_lookup_fn(b, data_jnp, backend=backend)
            qm = jnp.asarray(q)
            secs = C.time_lookup(fn, qm)
            lo, hi = b.lookup(b.state, qm)
            widths = np.maximum(np.asarray(hi) - np.asarray(lo) + 1, 1)
            rec = analysis.describe(b, widths)
            thpt = len(q) / secs
            rows.append(["size_scaling", name, b.size_bytes,
                         round(thpt / 1e6, 3),
                         round(rec["bytes_touched"] * thpt / 1e9, 2)])
    # (c) sharded dispatch: queries split over the data mesh axis
    import jax

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    for sp in [IndexSpec("rmi", dict(branching=4096)),
               IndexSpec("pgm", dict(eps=64))]:
        b = C.build_index(sp, keys)
        fn = C.full_lookup_fn(b, data_jnp, backend=backend)
        m = (len(q) // n_dev) * n_dev
        qm = _shard_queries(jnp.asarray(q[:m]), mesh)
        secs = C.time_lookup(fn, qm)
        rows.append(["sharded_dispatch", b.name, n_dev,
                     round(m / secs / 1e6, 3), ""])
    C.emit(rows, header=["mode", "index", "x", "mlookups_per_s",
                         "gbytes_touched_per_s"],
           path=os.path.join(out_dir, "parallel_scaling.csv"))
    return rows


if __name__ == "__main__":
    run(backend=C.backend_arg())
