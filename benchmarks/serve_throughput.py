"""Serve-layer throughput: the lookup SERVICE under streaming load.

The paper's §7 multi-thread study (and SOSD after it) makes
throughput-under-parallel-load the decisive metric for learned indexes
in systems.  This benchmark drives `repro.serve.lookup.LookupService` —
async admission, deadline/size micro-batching, sharded fused dispatch —
with a stream of small requests and sweeps

    micro-batch budget x index type x dataset,

emitting one JSON row per cell: achieved lookups/sec, batch latency
(mean/p99), batcher occupancy, and `verified_vs_core` — the service's
positions compared bit-for-bit against a direct single-device
`repro.core` fused lookup on the same query stream.

Small max_batch buys latency at an occupancy/throughput cost; large
max_batch amortizes dispatch overhead — the serving-layer analogue of
the paper's Fig. 14 batching study.  On 1 CPU device the sharded path
measures its own overhead; with more devices (or
``--xla_force_host_platform_device_count``) it measures real scaling.

    PYTHONPATH=src python benchmarks/serve_throughput.py
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serve_throughput.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import _common as C

#: (max_batch keys per dispatch, keys per client request)
BATCH_POINTS = [(512, 32), (4096, 256)]

#: index types swept, at the shared serving-default hyperparameters
#: (repro.serve.lookup.default_spec — same table the serve driver uses)
INDEX_NAMES = ["rmi", "pgm", "radix_spline"]

DATASETS = ["amzn", "face", "osm", "wiki"]

#: queries per cell — enough batches for a latency distribution, small
#: enough that the 24-cell sweep stays CPU-container friendly.
N_SERVE_Q = int(os.environ.get("SERVE_Q", min(C.N_QUERIES, 10_000)))


def _run_cell(ds: str, spec, max_batch: int, request_keys: int,
              backend: str = "jnp"):
    import jax.numpy as jnp
    from repro.serve.lookup import LookupService, LookupServiceConfig

    keys = C.dataset(ds)
    q = C.queries(ds)[:N_SERVE_Q]

    t0 = time.perf_counter()
    svc = LookupService(keys, LookupServiceConfig(
        spec=spec.replace(backend=backend),
        max_batch=max_batch, deadline_ms=2.0))
    build_s = time.perf_counter() - t0

    chunks = [q[i:i + request_keys] for i in range(0, len(q), request_keys)]
    with svc:                       # background flusher
        futs = [svc.submit(c) for c in chunks]
        outs = [f.result(timeout=120.0) for f in futs]
    got = np.concatenate(outs)

    # verify against a direct single-device plan lookup on the JNP
    # backend — cross-backend when the service runs pallas, and reusing
    # the generation's own plan (per-plan compile cache, no re-lowering)
    direct = np.asarray(
        svc.generation.plan.compile(backend="jnp")(jnp.asarray(q)),
        dtype=np.int64)
    verified = bool(np.array_equal(got, direct))

    snap = svc.metrics.snapshot()
    return {
        "dataset": ds,
        "index": spec.index,
        "spec": svc.generation.spec.to_dict(),
        "max_batch": max_batch,
        "backend": backend,
        "request_keys": request_keys,
        "n_keys": int(len(keys)),
        "n_queries": int(len(q)),
        "n_shards": svc.dispatcher.n_shards,
        "build_s": round(build_s, 4),
        "lookups_per_s": round(snap["lookups_per_s"], 1),
        "mean_batch_ms": round(snap["mean_batch_ms"], 4),
        "p99_batch_ms": round(snap["p99_batch_ms"], 4),
        "mean_occupancy": round(snap["mean_occupancy"], 4),
        "batches": snap["batches"],
        "verified_vs_core": verified,
    }


def run(out_dir: str = "benchmarks/results", backend=None, spec=None,
        autotune=None):
    """Sweep the service.  ``spec`` pins ONE declarative IndexSpec for
    every cell; ``autotune`` (a byte budget) lets the `spec.Tuner` pick
    the per-dataset spec+backend instead of the serving defaults."""
    from repro.serve.lookup import default_spec

    backend = backend or C.BACKEND
    rows = []
    for ds in DATASETS:
        if spec is not None:
            cells = [spec]
        elif autotune is not None:
            res = C.tuned_spec(ds, autotune, names=tuple(INDEX_NAMES),
                               backends=("jnp", "pallas"))
            cells = [res.spec]
        else:
            cells = [default_spec(i) for i in INDEX_NAMES]
        for sp in cells:
            be = sp.backend if (autotune is not None
                                and spec is None) else backend
            for max_batch, request_keys in BATCH_POINTS:
                r = _run_cell(ds, sp, max_batch, request_keys, backend=be)
                rows.append(r)
                print(f"{ds:5s} {r['index']:12s} batch={max_batch:5d} "
                      f"{r['lookups_per_s']/1e3:9.1f} klookups/s  "
                      f"p99={r['p99_batch_ms']:8.2f}ms  occ="
                      f"{r['mean_occupancy']:.2f}  "
                      f"verified={r['verified_vs_core']}", flush=True)
    path = os.path.join(out_dir, "serve_throughput.json")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {path}")
    n_bad = sum(not r["verified_vs_core"] for r in rows)
    if n_bad:
        raise SystemExit(f"{n_bad}/{len(rows)} cells NOT verified vs core")
    return rows


if __name__ == "__main__":
    _ns = C.bench_args()
    run(backend=_ns.backend, spec=_ns.spec, autotune=_ns.autotune)
